//! Spot-revocation walkthrough: the Fault Tolerance + Dynamic Scheduler
//! modules handling preemptions during a long TIL run (the §5.6 scenario),
//! with the full event trace printed.
//!
//! ```bash
//! cargo run --release --example spot_revocation [k_r_hours] [seed]
//! ```

use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::simul::SimTime;
use multi_fedls::trace::TIL_EXTENDED_ROUNDS;

fn main() -> anyhow::Result<()> {
    let k_r_hours: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2.0);
    let seed: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(5);

    println!("=== TIL on all-spot VMs, k_r = {k_r_hours} h (Table 5 scenario) ===\n");
    let mut cfg = SimConfig::new(multi_fedls::apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = TIL_EXTENDED_ROUNDS;
    cfg.revocation_mean_secs = Some(k_r_hours * 3600.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    let out = simulate(&cfg)?;
    for e in &out.events {
        println!("[{}] {}", e.at.hms(), e.what);
    }
    println!(
        "\n{} revocations handled; {} rounds completed; FL exec {}; total {}; cost ${:.2}",
        out.n_revocations,
        out.rounds_completed,
        SimTime::from_secs(out.fl_exec_secs).hms(),
        SimTime::from_secs(out.total_secs).hms(),
        out.total_cost
    );

    // Comparison: the same job without failures on on-demand VMs.
    let mut od = SimConfig::new(multi_fedls::apps::til(), Scenario::AllOnDemand, seed);
    od.n_rounds = TIL_EXTENDED_ROUNDS;
    od.checkpoints_enabled = false;
    let od_out = simulate(&od)?;
    println!(
        "all on-demand, no checkpoints: {} / ${:.2}",
        SimTime::from_secs(od_out.total_secs).hms(),
        od_out.total_cost
    );
    let saving = (od_out.total_cost - out.total_cost) / od_out.total_cost * 100.0;
    println!("spot saving: {saving:.1}% (negative = spot cost more after revocation overheads)");
    Ok(())
}
