//! End-to-end driver: REAL federated training through the full stack.
//!
//! Five FEMNIST silos (the paper's §5.1 Cross-Silo adaptation, synthetic
//! data) each train the conv + fused-dense model via the AOT-compiled
//! JAX/Pallas artifacts executed from rust over PJRT; the server runs
//! FedAvg, checkpoints every 2 rounds through the Fault Tolerance module,
//! and logs the global loss curve. All three layers compose: L3 rust
//! coordinator → PJRT runtime → L2 JAX model → L1 Pallas kernels.
//!
//! ```bash
//! make artifacts && cargo run --release --example femnist_e2e
//! ```

use std::path::Path;

use multi_fedls::coordinator::real::{run, RealRunConfig};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let rounds: u32 = std::env::var("ROUNDS").ok().and_then(|s| s.parse().ok()).unwrap_or(8);
    let scale: f64 = std::env::var("SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.08);

    let ckpt_dir = std::env::temp_dir().join("mfls-femnist-e2e");
    let cfg = RealRunConfig {
        app: multi_fedls::apps::femnist(),
        rounds,
        local_epochs: 1,
        data_scale: scale,
        seed: 7,
        server_ckpt_every: Some(2),
        checkpoint_dir: Some(ckpt_dir.clone()),
    };
    println!(
        "federated FEMNIST: {} clients, {} rounds, ~{} samples/client, artifacts from {artifacts}/",
        cfg.app.n_clients(),
        cfg.rounds,
        (cfg.app.train_samples[0] as f64 * cfg.data_scale) as u32,
    );
    let t0 = std::time::Instant::now();
    let out = run(Path::new(&artifacts), &cfg)?;
    println!("\nround  loss     accuracy  round-secs");
    for r in &out.history {
        println!("{:>5}  {:<7.4}  {:<8.4}  {:.2}", r.round, r.loss, r.accuracy, r.wall_secs);
    }
    let first = &out.history[0];
    let last = out.history.last().unwrap();
    println!(
        "\nloss {:.4} → {:.4} ({:.1}% ↓), accuracy {:.3} → {:.3}, wall {:.1}s",
        first.loss,
        last.loss,
        (1.0 - last.loss / first.loss) * 100.0,
        first.accuracy,
        last.accuracy,
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(last.loss < first.loss, "loss did not decrease");
    println!("checkpoints in {}", ckpt_dir.display());
    Ok(())
}
