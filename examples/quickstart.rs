//! Quickstart: the Multi-FedLS pipeline end-to-end on the CloudLab
//! environment — Pre-Scheduling → Initial Mapping → simulated execution —
//! for the paper's TIL use-case application (§5.4).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use multi_fedls::apps;
use multi_fedls::cloud::{tables, Market};
use multi_fedls::cloudsim::{MultiCloud, RevocationModel};
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::presched::PreScheduler;
use multi_fedls::simul::SimTime;

fn main() -> anyhow::Result<()> {
    // 1. The environment: Table 2's CloudLab catalog (two simulated clouds).
    let mc = MultiCloud::new(
        tables::cloudlab(),
        tables::cloudlab_ground_truth(),
        RevocationModel::none(),
        42,
    );
    println!(
        "environment: {} providers, {} regions, {} VM types",
        mc.catalog.providers.len(),
        mc.catalog.regions.len(),
        mc.catalog.vm_types.len()
    );

    // 2. Pre-Scheduling (§4.1): dummy-app slowdowns.
    let slowdowns = PreScheduler::new(&mc).measure_defaults();
    let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
    println!(
        "pre-scheduling: sl_inst(vm126) = {:.3} (Table 3: 0.045)",
        slowdowns.sl_inst(vm126)
    );

    // 3. Initial Mapping (§4.2): exact MILP solve for the TIL job.
    let app = apps::til();
    let job = app.profile();
    let problem = MappingProblem {
        catalog: &mc.catalog,
        slowdowns: &slowdowns,
        job: &job,
        alpha: 0.5,
        market: Market::OnDemand,
        budget_round: f64::INFINITY,
        deadline_round: f64::INFINITY,
    };
    let sol = multi_fedls::mapping::exact::solve(&problem).expect("feasible mapping");
    println!(
        "initial mapping: server={}, clients={:?}",
        mc.catalog.vm(sol.mapping.server).id,
        sol.mapping
            .clients
            .iter()
            .map(|&v| mc.catalog.vm(v).id.clone())
            .collect::<Vec<_>>()
    );
    println!(
        "predicted: {} FL time, ${:.2} for {} rounds (paper predicted 22:38 / $15.44)",
        SimTime::from_secs(sol.eval.makespan * 10.0).hms(),
        sol.eval.total_cost * 10.0,
        job.n_rounds,
    );

    // 4. Execute (simulated time, no failures): §5.4 validation.
    let mut cfg = SimConfig::new(app, Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;
    let out = simulate(&cfg)?;
    println!(
        "simulated:  FL exec {}, total {} (incl. {} boot), cost ${:.2}",
        SimTime::from_secs(out.fl_exec_secs).hms(),
        SimTime::from_secs(out.total_secs).hms(),
        SimTime::from_secs(tables::BOOT_CLOUDLAB_SECS).hms(),
        out.total_cost
    );
    println!("paper measured: 24:47 FL time, $16.18");
    Ok(())
}
