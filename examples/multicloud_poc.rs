//! The §5.7 proof-of-concept on the real-world AWS+GCP catalog (Table 9):
//! 2 TIL clients (one silo per cloud), Initial Mapping picks the placement,
//! then on-demand vs all-spot executions are compared — the paper's headline
//! result (−56.92% cost for +5.44% time).
//!
//! ```bash
//! cargo run --release --example multicloud_poc
//! ```

use multi_fedls::coordinator::{run_trials, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;

fn main() -> anyhow::Result<()> {
    let app = multi_fedls::apps::til_aws_gcp();
    println!(
        "AWS + GCP proof of concept: {} clients, {} rounds, regions us-east-1 / us-central1 / us-west1",
        app.n_clients(),
        app.n_rounds
    );

    let mut od = SimConfig::new(app.clone(), Scenario::AllOnDemand, 90);
    od.checkpoints_enabled = false;
    let od_stats = run_trials(&od, 3, 90)?;
    println!(
        "\non-demand : revoc {:.2}  time {}  cost ${:.2}   (paper: 2:00:18, $3.28)",
        od_stats.revocations.mean,
        od_stats.exec_hms(),
        od_stats.cost.mean
    );

    let mut spot = SimConfig::new(app, Scenario::AllSpot, 91);
    spot.revocation_mean_secs = Some(7200.0);
    spot.dynsched_policy = DynSchedPolicy::different_vm();
    spot.max_revocations_per_task = Some(1); // §5.6.1 observed regime
    let spot_stats = run_trials(&spot, 3, 91)?;
    println!(
        "all-spot  : revoc {:.2}  time {}  cost ${:.2}   (paper: 1.33 revoc, 2:06:51, $1.41)",
        spot_stats.revocations.mean,
        spot_stats.exec_hms(),
        spot_stats.cost.mean
    );

    let cost_reduction = (od_stats.cost.mean - spot_stats.cost.mean) / od_stats.cost.mean * 100.0;
    let time_increase = (spot_stats.total_secs.mean - od_stats.total_secs.mean)
        / od_stats.total_secs.mean
        * 100.0;
    println!(
        "\ncost reduction {cost_reduction:.2}% for a {time_increase:.2}% time increase \
         (paper: 56.92% / 5.44%)"
    );
    Ok(())
}
