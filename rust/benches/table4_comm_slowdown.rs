//! Regenerates Table 4 (communication slowdowns) and times the network-model
//! measurement pass.
use std::time::Duration;

fn main() {
    let (table, json) = multi_fedls::trace::table4();
    table.print();
    println!("{}", json.to_string_compact());
    multi_fedls::util::bench::bench("presched::table4", Duration::from_secs(2), 10, || {
        multi_fedls::util::bench::black_box(multi_fedls::trace::table4());
    });
}
