//! Telemetry overhead guard: the same 80-round Table 5 simulation with
//! telemetry off, fully on, and events-only. The off/on gap is the cost of
//! recording the extra typed events plus the single post-hoc span/metrics
//! pass — it must stay in the noise floor of the simulation itself (the
//! hot loop carries no span state; see `src/telemetry/span.rs`).
use std::time::Duration;

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::telemetry::TelemetrySpec;
use multi_fedls::util::bench::{bench, black_box};

fn table5_cfg(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, seed);
    cfg.n_rounds = 80;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

fn main() {
    let off = table5_cfg(50);
    bench("sim::til-80r telemetry=off", Duration::from_secs(3), 10, || {
        black_box(simulate(&off).unwrap());
    });

    let mut on = table5_cfg(50);
    on.telemetry = TelemetrySpec::on();
    bench("sim::til-80r telemetry=on (spans+metrics)", Duration::from_secs(3), 10, || {
        black_box(simulate(&on).unwrap());
    });

    let mut events_only = table5_cfg(50);
    events_only.telemetry =
        TelemetrySpec { enabled: true, spans: false, metrics: false };
    bench("sim::til-80r telemetry=events-only", Duration::from_secs(3), 10, || {
        black_box(simulate(&events_only).unwrap());
    });
}
