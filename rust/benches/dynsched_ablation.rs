//! Regenerates the Dynamic Scheduler ablation: Algorithms 1–3 vs the
//! restart-same-type baseline on the Table 5 configuration (TIL, all-spot,
//! different-VM policy, 3-trial averages).
fn main() {
    let (table, json) = multi_fedls::trace::dynsched_ablation();
    table.print();
    println!("{}", json.to_string_compact());
}
