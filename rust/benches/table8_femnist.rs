//! Regenerates Table 8: FEMNIST failure simulation (100 rounds × 100
//! epochs, 5 clients), k_r ∈ {1h, 2h}.
fn main() {
    let (table, json) = multi_fedls::trace::table8();
    table.print();
    println!("{}", json.to_string_compact());
}
