//! Regenerates the Initial Mapping ablation: exact vs MILP vs the
//! cheapest/fastest/random/single-cloud baselines on the Table 5
//! configuration (TIL, all-spot, k_r = 2 h, 3-trial averages).
fn main() {
    let (table, json) = multi_fedls::trace::mapper_ablation();
    table.print();
    println!("{}", json.to_string_compact());
}
