//! Regenerates the §5.7 AWS/GCP proof of concept: on-demand vs all-spot
//! with k_r = 2 h (paper headline: −56.92% cost, +5.44% time).
fn main() {
    let (table, json) = multi_fedls::trace::poc_aws_gcp();
    table.print();
    println!("{}", json.to_string_compact());
}
