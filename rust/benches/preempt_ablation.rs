//! Regenerates the workload-scheduling ablation: no-preempt vs
//! priority-preempt vs fair-share on a contended AWS+GCP workload (four
//! GPU-bound low-priority jobs plus one high-priority late arrival).
fn main() {
    let (table, json) = multi_fedls::trace::preempt_ablation();
    table.print();
    println!("{}", json.to_string_compact());
}
