//! Regenerates Fig. 2: server-checkpoint overhead vs interval X, plus the
//! per-round client checkpoint overhead (§5.5).
fn main() {
    let (table, json) = multi_fedls::trace::fig2();
    table.print();
    println!("{}", json.to_string_compact());
}
