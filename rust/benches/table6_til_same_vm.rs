//! Regenerates Table 6: TIL failure simulation with the CloudLab policy
//! (revoked type may be re-selected immediately).
fn main() {
    let (table, json) = multi_fedls::trace::table6();
    table.print();
    println!("{}", json.to_string_compact());
}
