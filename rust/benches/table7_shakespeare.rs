//! Regenerates Table 7: Shakespeare failure simulation (20 rounds × 20
//! epochs, 8 clients), k_r ∈ {1h, 2h}.
fn main() {
    let (table, json) = multi_fedls::trace::table7();
    table.print();
    println!("{}", json.to_string_compact());
}
