//! Regenerates Table 3 (execution slowdowns) and times the Pre-Scheduling
//! measurement pass.
use std::time::Duration;

fn main() {
    let (table, json) = multi_fedls::trace::table3();
    table.print();
    println!("{}", json.to_string_compact());
    multi_fedls::util::bench::bench("presched::table3", Duration::from_secs(2), 10, || {
        multi_fedls::util::bench::black_box(multi_fedls::trace::table3());
    });
}
