//! Regenerates Table 5: TIL failure simulation, restart on a *different* VM
//! type (AWS-style revoked-type blocking), k_r ∈ {2h, 4h}, 3-trial averages.
fn main() {
    let (table, json) = multi_fedls::trace::table5();
    table.print();
    println!("{}", json.to_string_compact());
}
