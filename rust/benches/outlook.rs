//! Outlook subsystem benchmarks: query latency for the forecast primitives
//! (windowed expected price, integrated-hazard survival, deferral search)
//! plus the outlook-ablation study regen (3-trial averages).
use std::time::Duration;

use multi_fedls::market::{MarketSpec, PriceSpec, RevocationSpec};
use multi_fedls::outlook::{MarketOutlook, OutlookSpec};
use multi_fedls::util::bench::{bench, black_box};

fn main() {
    let (table, json) = multi_fedls::trace::outlook_ablation();
    table.print();
    println!("{}", json.to_string_compact());

    // A busy price series (96 steps ≈ a day at 15-min granularity) under a
    // seasonal hazard: the worst realistic case for every query primitive.
    let steps: Vec<(f64, f64)> =
        (0..96).map(|i| (i as f64 * 900.0, 1.0 + 0.5 * f64::from(i % 7))).collect();
    let market = MarketSpec {
        price: PriceSpec::Steps(steps),
        revocation: RevocationSpec::Seasonal {
            mean_secs: 7200.0,
            period_secs: 14_400.0,
            amplitude: 0.8,
            phase_secs: 0.0,
        },
        ..MarketSpec::default()
    };
    let spec = OutlookSpec { enabled: true, horizon_secs: Some(14_400.0), bid_risk: 0.1, defer: true };
    let o = MarketOutlook::new(&market, Some(7200.0), spec, 7200.0);

    bench("outlook::expected_price_factor", Duration::from_secs(2), 1000, || {
        black_box(o.expected_price_factor(1234.5, 14_400.0));
    });
    bench("outlook::survival", Duration::from_secs(2), 1000, || {
        black_box(o.survival(1234.5, 1234.5 + 14_400.0));
    });
    bench("outlook::expected_revocations", Duration::from_secs(2), 1000, || {
        black_box(o.expected_revocations(0.0, 86_400.0));
    });
    bench("outlook::advise_bid", Duration::from_secs(2), 1000, || {
        black_box(o.advise_bid(1234.5, 14_400.0));
    });
    bench("outlook::best_start_offset", Duration::from_secs(2), 200, || {
        black_box(o.best_start_offset(21_600.0, 14_400.0));
    });
}
