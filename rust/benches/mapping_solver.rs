//! Initial Mapping solver benchmarks: exact-solver latency across α values
//! and the solver-quality comparison vs greedy/random/single-cloud
//! baselines (§5.4 + DESIGN.md ablation).
use std::time::Duration;

use multi_fedls::cloud::{tables, Market};
use multi_fedls::cloudsim::{MultiCloud, RevocationModel};
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::presched::PreScheduler;
use multi_fedls::util::bench::{bench, black_box};

fn main() {
    let (table, json) = multi_fedls::trace::mapping_comparison();
    table.print();
    println!("{}", json.to_string_compact());

    let (table, json) = multi_fedls::trace::alpha_sweep();
    table.print();
    println!("{}", json.to_string_compact());

    let mc = MultiCloud::new(
        tables::cloudlab(),
        tables::cloudlab_ground_truth(),
        RevocationModel::none(),
        1,
    );
    let sl = PreScheduler::new(&mc).measure_defaults();
    for (name, app) in [
        ("til(4 clients)", multi_fedls::apps::til()),
        ("shakespeare(8 clients)", multi_fedls::apps::shakespeare()),
        ("femnist(5 clients)", multi_fedls::apps::femnist()),
    ] {
        let job = app.profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        bench(&format!("mapping::exact {name}"), Duration::from_secs(2), 20, || {
            black_box(multi_fedls::mapping::exact::solve(&p));
        });
    }

    // Dynamic Scheduler (Algorithm 3) selection latency — the operation on
    // the revocation critical path.
    let job = multi_fedls::apps::til().profile();
    let p = MappingProblem {
        catalog: &mc.catalog,
        slowdowns: &sl,
        job: &job,
        alpha: 0.5,
        market: Market::Spot,
        spot_price_factor: 1.0,
        budget_round: 1e9,
        deadline_round: 1e9,
        outlook: None,
    };
    let map = multi_fedls::dynsched::CurrentMap {
        server: mc.catalog.vm_by_id("vm121").unwrap(),
        clients: vec![mc.catalog.vm_by_id("vm126").unwrap(); 4],
    };
    let all: Vec<_> = mc.catalog.vm_ids().collect();
    let market = multi_fedls::market::MarketSpec::default();
    bench("dynsched::select_instance", Duration::from_secs(2), 100, || {
        black_box(multi_fedls::dynsched::select_instance(
            &multi_fedls::dynsched::RevocationCtx {
                problem: &p,
                map: &map,
                faulty: multi_fedls::dynsched::FaultyTask::Client(0),
                candidates: &all,
                revoked: map.clients[0],
                policy: multi_fedls::dynsched::DynSchedPolicy::different_vm(),
                at: multi_fedls::simul::SimTime::ZERO,
                remaining_secs: 0.0,
                market: multi_fedls::market::MarketView::new(&market),
            },
        ));
    });
}
