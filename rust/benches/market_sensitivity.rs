//! Regenerates the market-sensitivity study: the Table 5 configuration
//! under exponential / Weibull / seasonal / trace-replay revocations and
//! volatile / bid-priced spot prices (3-trial averages).
fn main() {
    let (table, json) = multi_fedls::trace::market_sensitivity();
    table.print();
    println!("{}", json.to_string_compact());
}
