//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! FedAvg aggregation (native vs via the AOT fedavg artifact when present),
//! checkpoint encode/save, DES simulation throughput, RNG.
use std::time::Duration;

use multi_fedls::coordinator::{Scenario, SimConfig};
use multi_fedls::fl::{ClientUpdate, FedAvg, Strategy};
use multi_fedls::ft::Checkpoint;
use multi_fedls::simul::Rng;
use multi_fedls::util::bench::{bench, black_box};

fn main() {
    // --- FedAvg over TIL-sized models (170k params × 4 clients) ---
    let p = 170_514;
    let updates: Vec<ClientUpdate> = (0..4)
        .map(|c| ClientUpdate {
            client: c,
            weights: vec![c as f32; p],
            n_samples: 948,
        })
        .collect();
    bench("fedavg::native 4x170k", Duration::from_secs(2), 20, || {
        black_box(FedAvg.aggregate(&updates));
    });

    // Same aggregation through the AOT Pallas artifact (ablation). The
    // interpret-mode Pallas HLO takes ~35 s per aggregation on CPU (see
    // EXPERIMENTS.md §Perf — this is why the L3 hot path uses the native
    // implementation), so the measurement is opt-in.
    let art_path = std::path::Path::new("artifacts/til_fedavg.hlo.txt");
    if std::env::var("MFLS_BENCH_PJRT_FEDAVG").is_ok() && art_path.exists() {
        let engine = multi_fedls::runtime::Engine::cpu().expect("engine");
        let exe = engine.load_hlo_text(art_path).expect("compile");
        let stacked: Vec<f32> = updates.iter().flat_map(|u| u.weights.iter().copied()).collect();
        let weights: Vec<f32> = updates.iter().map(|u| u.n_samples as f32).collect();
        bench("fedavg::pjrt-pallas 4x170k", Duration::from_secs(1), 2, || {
            black_box(
                exe.run_f32(&[(&stacked, &[4, p as i64]), (&weights, &[4])])
                    .expect("exec"),
            );
        });
    } else {
        println!("(set MFLS_BENCH_PJRT_FEDAVG=1 with artifacts built for the ~35 s/iter PJRT fedavg ablation)");
    }

    // --- checkpoint encode (504 MB-class model scaled to 170k params) ---
    let ckpt = Checkpoint { round: 10, weights: vec![0.5; p] };
    bench("checkpoint::encode 170k", Duration::from_secs(2), 20, || {
        black_box(ckpt.encode());
    });

    // --- end-to-end DES simulation throughput (80-round TIL with spot) ---
    bench("sim::til-80-rounds-spot", Duration::from_secs(5), 5, || {
        let mut cfg = SimConfig::new(multi_fedls::apps::til(), Scenario::AllSpot, 7);
        cfg.n_rounds = 80;
        cfg.revocation_mean_secs = Some(7200.0);
        black_box(multi_fedls::coordinator::simulate(&cfg).unwrap());
    });

    // --- RNG throughput ---
    let mut rng = Rng::seeded(1);
    bench("rng::xoshiro 1e6 draws", Duration::from_secs(1), 10, || {
        let mut acc = 0u64;
        for _ in 0..1_000_000 {
            acc ^= rng.next_u64();
        }
        black_box(acc);
    });
}
