//! Workload-level dynamic scheduling: priorities, checkpoint-preemption,
//! and cross-tenant fairness.
//!
//! Builds one contended workload — four low-priority jobs whose per-round
//! deadline forces GPU placements (saturating all 8 GPUs of the AWS+GCP
//! environment from t = 0) plus a high-priority job arriving mid-execution —
//! and runs it under all three built-in `WorkloadScheduler` policies:
//!
//! * `no-preempt`   — the high-priority job waits for a capacity release;
//! * `priority-preempt` — it checkpoint-preempts the lowest-priority running
//!   job, which later *resumes* from its checkpointed rounds (the §4.3
//!   restore path — nothing re-executed with client checkpoints on);
//! * `fair-share`   — tenants take admission slots by weighted service.
//!
//! ```bash
//! cargo run --release --example priority_preemption
//! ```

use multi_fedls::apps;
use multi_fedls::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use multi_fedls::coordinator::{Scenario, SimConfig};
use multi_fedls::simul::SimTime;
use multi_fedls::workload::{JobRequest, Workload};

fn gpu_job(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
    cfg.deadline_round = 4000.0; // CPU types are ~20x slower: GPUs only
    cfg
}

fn build(scheduler: SchedulerPolicy) -> Workload {
    let mut jobs: Vec<JobRequest> = (0..4)
        .map(|i| {
            let mut j = JobRequest::new(format!("low-{i}"), 0.0, gpu_job(10 + i as u64));
            j.tenant = if i < 2 { "acme".into() } else { "zeta".into() };
            j
        })
        .collect();
    let mut hi = JobRequest::new("high", 3000.0, gpu_job(99));
    hi.priority = 10;
    hi.tenant = "acme".into();
    jobs.push(hi);
    Workload { name: "preempt-demo".into(), jobs, admission: AdmissionPolicy::Fifo, scheduler }
}

fn main() -> anyhow::Result<()> {
    for policy in
        [SchedulerPolicy::NoPreempt, SchedulerPolicy::PriorityPreempt, SchedulerPolicy::FairShare]
    {
        let out = build(policy).run()?;
        println!("=== scheduler = {} ===", policy.key());
        for j in &out.jobs {
            let admitted = j
                .admitted_at
                .map_or("rejected".to_string(), |t| SimTime::from_secs(t).hms());
            let done = j
                .completed_at
                .map_or("-".to_string(), |t| SimTime::from_secs(t).hms());
            println!(
                "  {:<7} admitted {:>9}  done {:>9}  rounds {:>2}  preemptions {}  lost {}",
                j.name, admitted, done, j.rounds_completed, j.preemptions, j.rounds_lost
            );
        }
        println!(
            "  makespan {}  mean wait {}  total ${:.2}  preemptions {}\n",
            SimTime::from_secs(out.stats.makespan_secs).hms(),
            SimTime::from_secs(out.stats.mean_wait_secs).hms(),
            out.stats.total_cost,
            out.stats.preemptions
        );
    }
    Ok(())
}
