//! Market-aware scheduling with the Outlook subsystem.
//!
//! Builds a [`MarketOutlook`] for a volatile step-price spot market and
//! queries the forecast primitives directly (windowed expected price,
//! survival over a horizon, bid advice, deferral search), then runs the
//! same TIL job outlook-off and outlook-aware to show the deferred start
//! dodging the price spike — the `multi-fedls experiment outlook-ablation`
//! scenario in miniature.
//!
//! ```bash
//! cargo run --release --example market_aware
//! ```

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig};
use multi_fedls::market::{MarketSpec, PriceSpec, RevocationSpec};
use multi_fedls::outlook::{MarketOutlook, OutlookSpec};
use multi_fedls::simul::SimTime;

fn main() -> anyhow::Result<()> {
    // A spot market with a 1.8× price spike after one hour, a 0.6× trough
    // from three hours on, and a seasonal (diurnal) revocation process.
    let market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8), (10_800.0, 0.6)]),
        revocation: RevocationSpec::Seasonal {
            mean_secs: 7200.0,
            period_secs: 14_400.0,
            amplitude: 0.8,
            phase_secs: 0.0,
        },
        ..MarketSpec::default()
    };
    let spec =
        OutlookSpec { enabled: true, horizon_secs: Some(14_400.0), bid_risk: 0.1, defer: true };
    let outlook = MarketOutlook::new(&market, Some(7200.0), spec.clone(), 7200.0);

    // 1. The forecast primitives, straight off the outlook.
    println!("price now            {:.2}×", outlook.price_factor_at(0.0));
    println!(
        "expected over 4 h    {:.3}× (exact integral over the steps)",
        outlook.expected_price_factor(0.0, 14_400.0)
    );
    println!(
        "survival over 2 h    {:.1}% (seasonal hazard, closed form)",
        outlook.survival(0.0, 7200.0) * 100.0
    );
    match outlook.advise_bid(0.0, 7200.0) {
        Some(bid) => println!("advised bid          {bid:.2}× the on-demand-relative base"),
        None => println!("advised bid          none (revocation risk alone exceeds bid_risk)"),
    }
    let defer = outlook.best_start_offset(8.0 * 3600.0, 14_400.0);
    println!("best start offset    {} into the run window", SimTime::from_secs(defer).hms());

    // 2. The same market end to end: outlook-off pays the spike, the
    //    outlook-aware run defers provisioning to the trough. Deterministic
    //    (no revocations) so the cost gap is exactly the price-factor gap.
    let mut off = SimConfig::new(apps::til(), Scenario::AllSpot, 42);
    off.n_rounds = 12;
    off.market = MarketSpec { revocation: RevocationSpec::Exponential, ..market.clone() };
    let mut aware = off.clone();
    aware.outlook = spec;

    let a = simulate(&off)?;
    let b = simulate(&aware)?;
    println!(
        "\noutlook-off    FL {}  total {}  ${:.2}",
        SimTime::from_secs(a.fl_exec_secs).hms(),
        SimTime::from_secs(a.total_secs).hms(),
        a.total_cost
    );
    println!(
        "outlook-aware  FL {}  total {}  ${:.2}",
        SimTime::from_secs(b.fl_exec_secs).hms(),
        SimTime::from_secs(b.total_secs).hms(),
        b.total_cost
    );
    if let Some(ev) = b.events.iter().find(|e| e.what().contains("deferred")) {
        println!("deferred start: {} — {}", ev.at.hms(), ev.what());
    }
    println!(
        "outlook-aware saves ${:.2} ({:.1}%) on this market",
        a.total_cost - b.total_cost,
        (a.total_cost - b.total_cost) / a.total_cost * 100.0
    );
    Ok(())
}
