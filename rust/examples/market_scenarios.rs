//! Driving one FL job through different spot-market models.
//!
//! Runs the extended TIL job (all-spot, Table 5 shape) under four markets —
//! the paper's exponential `k_r` clock, a diurnal seasonal process, a
//! deterministic interruption-trace replay, and a volatile price-step
//! market with bid-priced VMs — and reports how revocations, makespan, and
//! segment-accurately billed cost move with the market model alone (the
//! scheduler stack is identical in every run).
//!
//! ```bash
//! cargo run --release --example market_scenarios
//! ```

use multi_fedls::apps;
use multi_fedls::coordinator::{simulate, Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::DynSchedPolicy;
use multi_fedls::market::{MarketSpec, PriceSpec, RevocationSpec};
use multi_fedls::simul::SimTime;

fn base_cfg() -> SimConfig {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
    cfg.n_rounds = 40;
    cfg.revocation_mean_secs = Some(7200.0);
    cfg.dynsched_policy = DynSchedPolicy::different_vm();
    cfg.max_revocations_per_task = Some(1);
    cfg
}

fn report(label: &str, out: &SimOutcome) {
    println!(
        "{label:<14} revocations={:<2} FL {}  total {}  ${:.2}",
        out.n_revocations,
        SimTime::from_secs(out.fl_exec_secs).hms(),
        SimTime::from_secs(out.total_secs).hms(),
        out.total_cost
    );
}

fn main() -> anyhow::Result<()> {
    // 1. The paper's market: exponential k_r = 2 h at constant price.
    //    (MarketSpec::default() — bit-identical to the pre-market simulator.)
    let cfg = base_cfg();
    report("exponential", &simulate(&cfg)?);

    // 2. Seasonal: same average rate, but interruption pressure peaks once
    //    per 4 h period (think business-hours demand).
    let mut cfg = base_cfg();
    cfg.market = MarketSpec {
        revocation: RevocationSpec::Seasonal {
            mean_secs: 7200.0,
            period_secs: 14_400.0,
            amplitude: 0.8,
            phase_secs: 0.0,
        },
        ..MarketSpec::default()
    };
    report("seasonal", &simulate(&cfg)?);

    // 3. Trace replay: recorded interruption instants hit every spot VM
    //    alive at them — fully deterministic, like replaying a provider's
    //    interruption history export.
    let mut cfg = base_cfg();
    cfg.market = MarketSpec {
        revocation: RevocationSpec::Trace { times: vec![4000.0, 4300.0, 16_000.0] },
        ..MarketSpec::default()
    };
    report("trace-replay", &simulate(&cfg)?);

    // 4. Volatile prices + a bid: the spot price steps to 1.8× during a
    //    demand spike, outbidding our 1.5× bid (revocation at the step
    //    edge), and billing charges each VM-second at the price in effect.
    let mut cfg = base_cfg();
    cfg.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (9000.0, 1.8), (18_000.0, 0.7)]),
        bid_factor: Some(1.5),
        ..MarketSpec::default()
    };
    report("bid-priced", &simulate(&cfg)?);

    println!("\nsame scheduler stack, same seeds — only the market model changed");
    Ok(())
}
