//! A multi-job campaign through the first-class `Workload` API.
//!
//! Four TIL jobs share the AWS+GCP proof-of-concept environment (4 GPUs per
//! provider): three spot "production" jobs and one on-demand "batch" job
//! with a per-round deadline. The workload runs twice — FIFO admission, then
//! shortest-makespan-first — and prints per-job admission/wait/completion
//! times plus the workload summary, demonstrating shared-quota admission,
//! queuing, and the budget/deadline plumbing end-to-end.
//!
//! ```bash
//! cargo run --release --example multi_job_campaign
//! ```

use multi_fedls::apps;
use multi_fedls::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use multi_fedls::coordinator::{Scenario, SimConfig};
use multi_fedls::simul::SimTime;
use multi_fedls::workload::{JobRequest, Workload};

fn jobs() -> Vec<JobRequest> {
    let mut out = Vec::new();
    // Three spot jobs with revocations, arriving 10 minutes apart.
    for i in 0..3u64 {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 100 + i);
        cfg.revocation_mean_secs = Some(7200.0);
        cfg.max_revocations_per_task = Some(1);
        out.push(JobRequest::new(format!("prod-{i}"), 600.0 * i as f64, cfg));
    }
    // One on-demand job that must finish each round within 20 minutes.
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 200);
    cfg.checkpoints_enabled = false;
    cfg.deadline_round = 1200.0;
    out.push(JobRequest::new("batch", 300.0, cfg));
    out
}

fn run(admission: AdmissionPolicy) -> anyhow::Result<()> {
    let workload = Workload {
        name: "example".into(),
        jobs: jobs(),
        admission,
        scheduler: SchedulerPolicy::NoPreempt,
    };
    let out = workload.run()?;
    println!("=== admission = {admission:?} ===");
    println!(
        "{:<8} {:>10} {:>10} {:>12} {:>10} {:>7}",
        "job", "arrival", "admitted", "completed", "cost ($)", "revoc."
    );
    for j in &out.jobs {
        match j.admitted_at {
            Some(at) => println!(
                "{:<8} {:>10} {:>10} {:>12} {:>10.2} {:>7}",
                j.name,
                SimTime::from_secs(j.arrival_secs).hms(),
                SimTime::from_secs(at).hms(),
                SimTime::from_secs(j.completed_at.unwrap_or(0.0)).hms(),
                j.cost,
                j.revocations,
            ),
            None => println!("{:<8} rejected (budget/deadline/quota)", j.name),
        }
    }
    let s = &out.stats;
    println!(
        "admitted {} (queued {}), rejected {}; makespan {}, mean wait {}, total ${:.2}\n",
        s.admitted,
        s.queued,
        s.rejected,
        SimTime::from_secs(s.makespan_secs).hms(),
        SimTime::from_secs(s.mean_wait_secs).hms(),
        s.total_cost,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    run(AdmissionPolicy::Fifo)?;
    run(AdmissionPolicy::ShortestMakespanFirst)?;
    Ok(())
}
