//! Decision provenance: why did the scheduler do what it did?
//!
//! Every scheduling decision the framework takes — initial-mapping solves,
//! dynamic-scheduler replacements, workload admissions and retries,
//! rejections, preemption-victim selections, outlook deferrals — leaves a
//! `telemetry::DecisionRecord`: the chosen option, the full ranked
//! candidate table with a typed elimination reason per loser, and (for
//! provisioning decisions) the exact downstream billed cost.
//!
//! This example rebuilds the contended preemption workload from the
//! `priority_preemption` example with telemetry on, then answers three
//! questions straight from the in-memory provenance (the same data
//! `multi-fedls explain` reads back out of a `--trace-out` JSONL file):
//!
//! 1. what decisions were taken, in cluster-clock order;
//! 2. who was preempted for the high-priority job, and who else was
//!    considered (the ranked victim table);
//! 3. what each decision and each job actually cost (VM spans).
//!
//! ```bash
//! cargo run --release --example explain_decisions
//! ```

use multi_fedls::apps;
use multi_fedls::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
use multi_fedls::coordinator::{Scenario, SimConfig};
use multi_fedls::telemetry::{DecisionKind, TelemetrySpec};
use multi_fedls::workload::{JobRequest, Workload};

fn gpu_job(seed: u64) -> SimConfig {
    let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
    cfg.deadline_round = 4000.0; // CPU types are ~20x slower: GPUs only
    cfg.telemetry = TelemetrySpec::on(); // record decisions + spans
    cfg
}

fn build() -> Workload {
    let mut jobs: Vec<JobRequest> = (0..4)
        .map(|i| {
            let mut j = JobRequest::new(format!("low-{i}"), 0.0, gpu_job(10 + i as u64));
            j.tenant = if i < 2 { "acme".into() } else { "zeta".into() };
            j
        })
        .collect();
    let mut hi = JobRequest::new("high", 3000.0, gpu_job(99));
    hi.priority = 10;
    hi.tenant = "acme".into();
    jobs.push(hi);
    Workload {
        name: "explain-demo".into(),
        jobs,
        admission: AdmissionPolicy::Fifo,
        scheduler: SchedulerPolicy::PriorityPreempt,
    }
}

fn main() -> anyhow::Result<()> {
    let out = build().run()?;

    // 1. The decision log, in cluster-clock order. Each line is one
    //    DecisionRecord::render(): kind, chosen option, candidate count,
    //    the reason sentence, and the attributed downstream cost.
    println!("=== every decision, in order ===");
    for d in &out.decisions {
        println!("{}", d.render());
    }

    // 2. The victim selection, with its full ranked candidate table: the
    //    chosen victim has no elimination reason; every loser carries one
    //    (quota-exhausted for protected jobs, dominated otherwise).
    println!("\n=== who got preempted, and who else was considered ===");
    for d in &out.decisions {
        if d.kind == DecisionKind::PreemptionVictim {
            print!("{}", d.render_full());
        }
    }

    // 3. Cost attribution from the VM spans: per-job billed VM cost, which
    //    reconciles with each job record's `vm_cost` (egress excluded).
    println!("\n=== what each job's VMs were billed ===");
    for rec in &out.jobs {
        let billed: f64 = out
            .vm_spans
            .iter()
            .filter(|v| v.job.as_deref() == Some(rec.name.as_str()))
            .map(|v| v.billed_cost)
            .sum();
        println!(
            "  {:<7} vm ${:>8.4} (record ${:.4})  total ${:>8.4}  preemptions {}",
            rec.name, billed, rec.vm_cost, rec.cost, rec.preemptions
        );
    }
    println!(
        "\n{} decisions, {} vm spans, {} trace events",
        out.decisions.len(),
        out.vm_spans.len(),
        out.trace.len()
    );
    Ok(())
}
