//! Plugging custom modules into the `Framework` pipeline.
//!
//! Implements `InitialMapper` for a "cost-only" policy that reuses the
//! exact solver with the cost/makespan weight forced to α = 1.0 — i.e. a
//! user who always wants the cheapest feasible placement, whatever the
//! job's configured trade-off — and runs the TIL use case (§5.4) through
//! three stacks: the default exact mapper, the custom module, and the
//! built-in cheapest-rate baseline selected by `MapperKind`.
//!
//! Also implements a custom `DynScheduler` against the `RevocationCtx`
//! context struct: the single argument carries the mapping problem, the
//! current placement, the revocation instant, and a read-only `MarketView`
//! of the job's price series, so a replacement policy can be market-aware
//! without any signature change — here, restarts during a spot price spike
//! ban the revoked type even when the configured policy would allow it.
//!
//! ```bash
//! cargo run --release --example custom_mapper
//! ```

use multi_fedls::apps;
use multi_fedls::cloud::VmTypeId;
use multi_fedls::coordinator::{Scenario, SimConfig, SimOutcome};
use multi_fedls::dynsched::{self, DynSchedPolicy, RevocationCtx, Selection};
use multi_fedls::framework::{DynScheduler, Framework, InitialMapper};
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::mapping::{self, MapperKind, MappingSolution};
use multi_fedls::market::{MarketSpec, PriceSpec};
use multi_fedls::simul::SimTime;

/// A drop-in Initial Mapping module: exact solve with α pinned to 1.0
/// (pure cost), whatever the job spec's α says.
struct CostOnlyMapper;

impl InitialMapper for CostOnlyMapper {
    fn name(&self) -> &'static str {
        "cost-only-exact"
    }

    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        let cost_only = MappingProblem {
            catalog: p.catalog,
            slowdowns: p.slowdowns,
            job: p.job,
            alpha: 1.0,
            market: p.market,
            spot_price_factor: p.spot_price_factor,
            budget_round: p.budget_round,
            deadline_round: p.deadline_round,
            outlook: p.outlook,
        };
        let sol = mapping::exact::solve(&cost_only)?;
        // Re-evaluate under the caller's α so reported objectives stay
        // comparable with the other mappers.
        let eval = p.evaluate(&sol.mapping);
        Some(MappingSolution {
            mapping: sol.mapping,
            eval,
            nodes: sol.nodes,
            defer_secs: sol.defer_secs,
        })
    }
}

/// A drop-in Dynamic Scheduler: Algorithm 3, but price-aware — when the
/// spot price at the revocation instant has spiked above 1.2× the base
/// rate, the revoked type is removed from the candidate set regardless of
/// the configured policy (a spiking type is the likeliest next eviction).
/// `RevocationCtx` is `Copy`, so overriding one field is one struct literal.
struct PriceAwareDynSched;

impl DynScheduler for PriceAwareDynSched {
    fn name(&self) -> &'static str {
        "price-aware"
    }

    fn select(&self, ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
        let policy = if ctx.market.price_factor_at(ctx.at) > 1.2 {
            DynSchedPolicy::different_vm()
        } else {
            ctx.policy
        };
        dynsched::select_instance(&RevocationCtx { policy, ..*ctx })
    }
}

fn report(label: &str, out: &SimOutcome) {
    println!(
        "{label:<18} server={:<6} clients={:?}  FL {}  total {}  ${:.2}",
        out.initial_server,
        out.initial_clients,
        SimTime::from_secs(out.fl_exec_secs).hms(),
        SimTime::from_secs(out.total_secs).hms(),
        out.total_cost
    );
}

fn main() -> anyhow::Result<()> {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;

    // 1. The paper's default stack (balanced α = 0.5, exact solver).
    let default_out = Framework::default_stack().run(&cfg)?;
    report("exact (default)", &default_out);

    // 2. Same pipeline, custom mapper plugged into the builder.
    let custom = Framework::builder().mapper(CostOnlyMapper).build();
    let custom_out = custom.run(&cfg)?;
    report("cost-only custom", &custom_out);

    // 3. Module selection via configuration instead of code: any job spec
    //    can say `mapper = "cheapest"`.
    let mut greedy_cfg = cfg.clone();
    greedy_cfg.mapper = MapperKind::Cheapest;
    let greedy_out = Framework::default_stack().run(&greedy_cfg)?;
    report("cheapest (cfg)", &greedy_out);

    println!(
        "\ncost-only saves ${:.2}/job vs the balanced mapping, at {:+.1}% FL time",
        default_out.total_cost - custom_out.total_cost,
        (custom_out.fl_exec_secs - default_out.fl_exec_secs) / default_out.fl_exec_secs * 100.0
    );

    // 4. A custom Dynamic Scheduler on a spot run with a price spike: the
    //    context struct hands the policy the price series (`ctx.market`),
    //    so replacements made during the spike ban the revoked type.
    let mut spot_cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 42);
    spot_cfg.checkpoints_enabled = false;
    spot_cfg.revocation_mean_secs = Some(7200.0);
    spot_cfg.dynsched_policy = DynSchedPolicy::same_vm_allowed();
    spot_cfg.market = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.5)]),
        ..MarketSpec::default()
    };
    let spot_out = Framework::builder().dynsched(PriceAwareDynSched).build().run(&spot_cfg)?;
    report("price-aware spot", &spot_out);
    println!("spot run saw {} revocation(s) under the price-aware policy", spot_out.n_revocations);
    Ok(())
}
