//! Lexical scanner for the lint pass.
//!
//! A hand-rolled tokenizer (no `syn` — the offline build allows `anyhow`
//! only) that walks a Rust source file once and produces a *masked* view of
//! it: string contents, char-literal contents, and both comment forms are
//! blanked to spaces while everything else is kept verbatim, with newlines
//! preserved so line numbers map 1:1 onto the original file. Rules then
//! match plain substrings against the masked code without false positives
//! from prose, doc comments, or test fixtures embedded in string literals.
//!
//! Handled literal forms:
//!
//! * `// line comments` (captured separately — `lint:allow` annotations
//!   live here) and nested `/* block comments */`;
//! * `"strings"` with `\` escapes, including multi-line strings;
//! * raw strings `r"…"` / `r#"…"#` (any number of hashes) and their
//!   byte-string variants `b"…"` / `br#"…"#`;
//! * char literals `'c'`, `'\n'`, `'\u{1F600}'` — disambiguated from
//!   lifetimes (`'a`, `'static`), which stay part of the code.
//!
//! The scanner also marks every line that falls inside a
//! `#[cfg(test)] mod … { … }` region (tracked by brace depth on the masked
//! code), so rules that only guard production paths can skip test code.

/// One `//` comment, with the 1-indexed line it starts on.
pub struct LineComment {
    pub line: usize,
    pub text: String,
}

/// The masked view of one source file.
pub struct FileScan {
    /// Masked source, split into lines (index 0 = line 1). Strings, char
    /// literals, and comments are blanked; code is verbatim.
    pub code_lines: Vec<String>,
    /// `test_line[i]` — line `i + 1` is inside a `#[cfg(test)]` region.
    pub test_line: Vec<bool>,
    /// Every `//` comment in the file (annotation parsing happens upstream).
    pub comments: Vec<LineComment>,
}

impl FileScan {
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_line.get(line - 1).copied().unwrap_or(false)
    }
}

/// Scan `text` into its masked view. Total: one pass over the chars, then
/// one pass over the masked lines for `#[cfg(test)]` regions.
pub fn scan(text: &str) -> FileScan {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(text.len());
    let mut comments: Vec<LineComment> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    // True when the previous code char could end an identifier — used to
    // tell a raw-string prefix `r"` from an identifier ending in `r`.
    let mut prev_ident = false;

    while i < n {
        let c = chars[i];

        // `//` line comment — captured for annotation parsing, masked out.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut txt = String::new();
            while i < n && chars[i] != '\n' {
                txt.push(chars[i]);
                code.push(' ');
                i += 1;
            }
            comments.push(LineComment { line, text: txt });
            prev_ident = false;
            continue;
        }

        // `/* … */` block comment, nesting allowed (as in Rust).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            code.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    code.push_str("  ");
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    code.push_str("  ");
                    i += 2;
                } else {
                    mask_char(&mut code, chars[i], &mut line);
                    i += 1;
                }
            }
            prev_ident = false;
            continue;
        }

        // Raw (byte) strings: r"…", r#"…"#, br"…", br#"…"#.
        if !prev_ident && (c == 'r' || (c == 'b' && i + 1 < n && chars[i + 1] == 'r')) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                for k in i..=j {
                    code.push(chars[k]); // keep the r#…" prefix as code
                }
                i = j + 1;
                loop {
                    if i >= n {
                        break;
                    }
                    if chars[i] == '"' {
                        let mut k = i + 1;
                        let mut h = 0usize;
                        while k < n && h < hashes && chars[k] == '#' {
                            h += 1;
                            k += 1;
                        }
                        if h == hashes {
                            code.push('"');
                            for _ in 0..hashes {
                                code.push('#');
                            }
                            i = k;
                            break;
                        }
                    }
                    mask_char(&mut code, chars[i], &mut line);
                    i += 1;
                }
                prev_ident = false;
                continue;
            }
            // Not a raw string after all: fall through, treat as plain code.
        }

        // Ordinary (byte) string literal.
        if c == '"' || (c == 'b' && !prev_ident && i + 1 < n && chars[i + 1] == '"') {
            if c == 'b' {
                code.push('b');
                i += 1;
            }
            code.push('"');
            i += 1;
            while i < n {
                let d = chars[i];
                if d == '\\' && i + 1 < n {
                    // Mask the escape pair, keeping line counts for `\` at
                    // end-of-line string continuations.
                    mask_char(&mut code, chars[i], &mut line);
                    mask_char(&mut code, chars[i + 1], &mut line);
                    i += 2;
                    continue;
                }
                if d == '"' {
                    code.push('"');
                    i += 1;
                    break;
                }
                mask_char(&mut code, d, &mut line);
                i += 1;
            }
            prev_ident = false;
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // Escaped char literal: '\n', '\'', '\u{…}'.
                code.push('\'');
                i += 1;
                while i < n {
                    let d = chars[i];
                    if d == '\\' && i + 1 < n {
                        code.push_str("  ");
                        i += 2;
                        continue;
                    }
                    if d == '\'' {
                        code.push('\'');
                        i += 1;
                        break;
                    }
                    mask_char(&mut code, d, &mut line);
                    i += 1;
                }
            } else if i + 2 < n && chars[i + 1] != '\'' && chars[i + 2] == '\'' {
                // Simple one-char literal 'x' (covers '"' and non-ASCII).
                code.push('\'');
                code.push(' ');
                code.push('\'');
                i += 3;
            } else {
                // A lifetime ('a, 'static) — the quote and the following
                // identifier chars are ordinary code.
                code.push('\'');
                i += 1;
            }
            prev_ident = false;
            continue;
        }

        // Plain code char.
        code.push(c);
        if c == '\n' {
            line += 1;
            prev_ident = false;
        } else {
            prev_ident = c.is_ascii_alphanumeric() || c == '_';
        }
        i += 1;
    }

    let code_lines: Vec<String> = code.lines().map(str::to_string).collect();
    let test_line = mark_test_lines(&code_lines);
    FileScan { code_lines, test_line, comments }
}

/// Mask one literal/comment char: newlines survive (they carry line
/// structure), everything else becomes a space.
fn mask_char(code: &mut String, c: char, line: &mut usize) {
    if c == '\n' {
        code.push('\n');
        *line += 1;
    } else {
        code.push(' ');
    }
}

/// Mark lines inside `#[cfg(test)] mod … { … }` regions by tracking brace
/// depth on the masked code: the attribute arms a pending flag, the next
/// `{` opens the region, and it closes when depth returns to its start.
fn mark_test_lines(code_lines: &[String]) -> Vec<bool> {
    let mut out = vec![false; code_lines.len()];
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut region_floor: Option<i64> = None;
    for (idx, l) in code_lines.iter().enumerate() {
        if region_floor.is_none() && l.contains("#[cfg(test)]") {
            pending = true;
        }
        if region_floor.is_some() || pending {
            out[idx] = true;
        }
        for ch in l.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        region_floor = Some(depth - 1);
                        pending = false;
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(floor) = region_floor {
                        if depth <= floor {
                            region_floor = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_strings_and_comments() {
        let src = "let a = \"HashMap\"; // HashMap here\nlet b = 1; /* HashMap */\n";
        let s = scan(src);
        assert!(!s.code_lines[0].contains("HashMap"));
        assert!(!s.code_lines[1].contains("HashMap"));
        assert!(s.code_lines[0].contains("let a ="));
        assert_eq!(s.comments.len(), 1);
        assert_eq!(s.comments[0].line, 1);
        assert!(s.comments[0].text.contains("HashMap here"));
    }

    #[test]
    fn masks_raw_strings_and_multiline() {
        let src = "let a = r#\"Instant::now\nline2 HashMap\"#;\nlet c = 2;\n";
        let s = scan(src);
        assert!(!s.code_lines[0].contains("Instant::now"));
        assert!(!s.code_lines[1].contains("HashMap"));
        assert!(s.code_lines[2].contains("let c = 2;"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        // '"' must not open a string; 'a is a lifetime, not a char literal.
        let src = "fn f<'a>(x: &'a str) -> char { let q = '\"'; let h = \"HashMap\"; q }\n";
        let s = scan(src);
        assert!(!s.code_lines[0].contains("HashMap"));
        assert!(s.code_lines[0].contains("fn f<'a>"));
        assert!(s.code_lines[0].contains("char"));
    }

    #[test]
    fn escaped_char_literal() {
        let src = "let nl = '\\n'; let quote = '\\''; let x = \"ok\";\n";
        let s = scan(src);
        assert!(s.code_lines[0].contains("let nl ="));
        assert!(!s.code_lines[0].contains("ok"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner HashMap */ still comment */ let x = 1;\n";
        let s = scan(src);
        assert!(!s.code_lines[0].contains("HashMap"));
        assert!(s.code_lines[0].contains("let x = 1;"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}\n";
        let s = scan(src);
        assert!(!s.is_test_line(1));
        assert!(s.is_test_line(2));
        assert!(s.is_test_line(3));
        assert!(s.is_test_line(4));
        assert!(s.is_test_line(5));
        assert!(!s.is_test_line(6));
    }
}
