//! Determinism & invariant lint pass (`multi-fedls lint`).
//!
//! The repo's core guarantee — bit-identical campaign output for any
//! `--jobs` worker count, resume byte-parity, reproducible Fig. 2 /
//! Table 5 regenerations — used to rest on convention alone. This module
//! makes it structural, in the style of rustc's `src/tools/tidy`: a
//! dependency-free static-analysis pass (hand-rolled tokenizer in
//! [`scan`], no `syn`; the offline build allows `anyhow` only) with a
//! rule registry ([`RULES`]) and three frontends that all call
//! [`lint_tree`]:
//!
//! 1. `multi-fedls lint [--json] [--src DIR]` — the CLI (nonzero exit on
//!    any violation, machine-readable with `--json`);
//! 2. `rust/tests/lint.rs` — a `#[test]` so plain offline `cargo test`
//!    gates every commit;
//! 3. the `determinism lint` CI job.
//!
//! ## The rules
//!
//! * **hash-iter** — bans `HashMap`/`HashSet` in the simulation-state
//!   modules (`cloudsim`, `presched`, `framework`, `workload`, `market`,
//!   `sweep`, `dynsched`, `mapping`). Hash iteration order is randomized
//!   per process, so a map whose order reaches output, fingerprints, or
//!   RNG consumption silently breaks run-to-run and `--jobs` parity. Use
//!   `BTreeMap`/`BTreeSet` or a sorted collect.
//! * **wall-clock** — bans `Instant::now`, `SystemTime::now`, and
//!   `thread_rng` everywhere except `util::bench` (measures real elapsed
//!   time by design) and `coordinator::real` (reports real round
//!   timings). Simulated paths take time from the discrete-event clock
//!   and randomness from the seeded `simul::Rng`; callers that need real
//!   timings inject a clock handle (see `fl::FlConfig::clock`).
//! * **float-eq** — bans bare `==`/`!=` against float literals in
//!   `solver`, `mapping`, and `cloudsim::billing`, where costs are
//!   compared with the repo-wide 1e-9 epsilon convention
//!   (`(a - b).abs() < 1e-9`). Exact-representation luck is not a
//!   contract; epsilon comparisons are.
//! * **spec-unwrap** — bans `unwrap()`/`expect(`/panicking macros in the
//!   TOML-parse paths (`*/spec.rs`, `cloud/catalog.rs`) where user-written
//!   config input flows: a malformed spec must come back as an `anyhow`
//!   error naming the offending key, never a panic.
//! * **unknown-key** — every spec-table parser file must call the shared
//!   `util::tomlmini::reject_unknown_keys` helper, so typo'd keys are
//!   rejected by name instead of silently ignored.
//!
//! Test code (`#[cfg(test)]` regions) is exempt from hash-iter, float-eq,
//! and spec-unwrap — tests may hash-dedup, compare exact floats, and
//! unwrap freely.
//!
//! ## Allow annotations
//!
//! A rule is suppressed for one line by a comment on that line or the
//! line directly above, of the form `lint:allow(hash-iter) -- keyed by
//! opaque id, order never observed` (i.e. `lint:allow(<rule>)`, then
//! ` -- `, then a free-text reason). The reason is **mandatory**: a
//! reason-less or malformed annotation is itself reported under the
//! `allow-syntax` rule and does not suppress anything, so it can never
//! pass CI. Prefer fixing the violation; annotate only when the flagged
//! pattern is provably harmless and say why.

pub mod rules;
pub mod scan;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::Context as _;

use crate::util::json::Json;

/// One finding: a rule fired at `file:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Registry name of the rule (e.g. `hash-iter`).
    pub rule: &'static str,
    /// Path relative to the scanned source root, `/`-separated.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Registry entry: rule name + one-line rationale (shown by the CLI).
pub struct RuleInfo {
    pub name: &'static str,
    pub summary: &'static str,
}

/// Every rule the pass knows, including the `allow-syntax` meta-rule that
/// polices the annotations themselves.
pub const RULES: [RuleInfo; 6] = [
    RuleInfo {
        name: "hash-iter",
        summary: "no HashMap/HashSet in simulation-state modules \
                  (iteration order reaches output/RNG)",
    },
    RuleInfo {
        name: "wall-clock",
        summary: "no Instant::now/SystemTime::now/thread_rng outside \
                  util::bench and coordinator::real",
    },
    RuleInfo {
        name: "float-eq",
        summary: "no bare ==/!= on float literals in solver/mapping/\
                  cloudsim::billing (1e-9 epsilon convention)",
    },
    RuleInfo {
        name: "spec-unwrap",
        summary: "no unwrap/expect/panics in TOML-parse paths \
                  (*/spec.rs, cloud/catalog.rs)",
    },
    RuleInfo {
        name: "unknown-key",
        summary: "every spec-table parser calls the shared \
                  tomlmini::reject_unknown_keys helper",
    },
    RuleInfo {
        name: "allow-syntax",
        summary: "allow annotations must name a known rule and carry a \
                  `-- <reason>` string",
    },
];

/// A parsed, well-formed allow annotation.
struct Allow {
    line: usize,
    rule: String,
}

/// Lint one file's source under its `src/`-relative path. Applies every
/// rule, then filters findings suppressed by a well-formed allow
/// annotation on the same line or the line directly above; malformed
/// annotations come back as `allow-syntax` findings.
pub fn lint_source(rel_path: &str, text: &str) -> Vec<Violation> {
    let scanned = scan::scan(text);
    let (allows, mut violations) = parse_allows(rel_path, &scanned);
    for v in rules::check_all(rel_path, &scanned) {
        let suppressed =
            allows.iter().any(|a| a.rule == v.rule && (a.line == v.line || a.line + 1 == v.line));
        if !suppressed {
            violations.push(v);
        }
    }
    violations.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    violations
}

/// Extract allow annotations from `//` comments. An annotation is a
/// comment whose body (after the comment markers) starts with
/// `lint:allow(`; prose that merely mentions the syntax mid-sentence or
/// in backticks is ignored.
fn parse_allows(rel: &str, scanned: &scan::FileScan) -> (Vec<Allow>, Vec<Violation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    let mut syntax_err = |line: usize, message: String| {
        bad.push(Violation { rule: "allow-syntax", file: rel.to_string(), line, message });
    };
    for c in &scanned.comments {
        let body = c.text.trim_start_matches('/').trim_start_matches('!').trim_start();
        let Some(rest) = body.strip_prefix("lint:allow") else { continue };
        let Some(rest) = rest.strip_prefix('(') else {
            syntax_err(c.line, "malformed allow annotation: expected `lint:allow(<rule>)`".into());
            continue;
        };
        let Some(close) = rest.find(')') else {
            syntax_err(c.line, "malformed allow annotation: missing `)`".into());
            continue;
        };
        let rule_name = rest[..close].trim();
        if !RULES.iter().any(|r| r.name == rule_name) {
            syntax_err(c.line, format!("allow annotation names unknown rule `{rule_name}`"));
            continue;
        }
        let after = rest[close + 1..].trim_start();
        match after.strip_prefix("--").map(str::trim) {
            Some(reason) if !reason.is_empty() => {
                allows.push(Allow { line: c.line, rule: rule_name.to_string() });
            }
            _ => syntax_err(
                c.line,
                format!(
                    "allow annotation without a reason — write \
                     `lint:allow({rule_name}) -- <why this is safe>`"
                ),
            ),
        }
    }
    (allows, bad)
}

/// Result of linting a whole source tree.
pub struct LintReport {
    pub files_scanned: usize,
    pub violations: Vec<Violation>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Machine-readable shape for `multi-fedls lint --json`.
    pub fn to_json(&self) -> Json {
        let violations: Vec<Json> = self
            .violations
            .iter()
            .map(|v| {
                Json::obj()
                    .set("rule", v.rule)
                    .set("file", v.file.as_str())
                    .set("line", v.line)
                    .set("message", v.message.as_str())
            })
            .collect();
        let rules: Vec<Json> = RULES
            .iter()
            .map(|r| Json::obj().set("name", r.name).set("summary", r.summary))
            .collect();
        Json::obj()
            .set("files_scanned", self.files_scanned)
            .set("violations", Json::Arr(violations))
            .set("rules", Json::Arr(rules))
    }
}

/// Lint every `.rs` file under `src_root` (recursively, in sorted path
/// order so output is deterministic).
pub fn lint_tree(src_root: &Path) -> anyhow::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files)
        .with_context(|| format!("walking {}", src_root.display()))?;
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        violations.extend(lint_source(&rel, &text));
    }
    violations.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(LintReport { files_scanned: files.len(), violations })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries = std::fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|ext| ext == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_on_same_or_previous_line_suppresses() {
        let src = "// lint:allow(hash-iter) -- keyed by opaque id, order never observed\n\
                   fn f() { let m = HashMap::new(); }\n";
        assert!(lint_source("cloudsim/fake.rs", src).is_empty());
        let trailing = "fn f() { let m = HashMap::new(); } \
                        // lint:allow(hash-iter) -- order never observed\n";
        assert!(lint_source("cloudsim/fake.rs", trailing).is_empty());
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "// lint:allow(wall-clock) -- wrong rule\n\
                   fn f() { let m = HashMap::new(); }\n";
        let v = lint_source("cloudsim/fake.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "hash-iter");
    }

    #[test]
    fn reasonless_allow_is_flagged_and_inert() {
        let src = "// lint:allow(hash-iter)\nfn f() { let m = HashMap::new(); }\n";
        let rules_hit: Vec<_> = lint_source("cloudsim/fake.rs", src)
            .into_iter()
            .map(|v| v.rule)
            .collect();
        assert!(rules_hit.contains(&"allow-syntax"));
        assert!(rules_hit.contains(&"hash-iter"));
    }

    #[test]
    fn unknown_rule_in_allow_is_flagged() {
        let src = "// lint:allow(no-such-rule) -- whatever\nfn f() {}\n";
        let v = lint_source("cloudsim/fake.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "allow-syntax");
    }

    #[test]
    fn prose_mentioning_the_syntax_is_ignored() {
        // Doc prose that cites the annotation form mid-sentence (e.g. in
        // backticks) must not parse as an annotation.
        let src = "//! Suppress with `lint:allow(hash-iter) -- reason` comments.\nfn f() {}\n";
        assert!(lint_source("cloudsim/fake.rs", src).is_empty());
    }

    #[test]
    fn display_format_is_file_line_rule() {
        let v = Violation {
            rule: "hash-iter",
            file: "cloudsim/fake.rs".to_string(),
            line: 7,
            message: "msg".to_string(),
        };
        assert_eq!(v.to_string(), "cloudsim/fake.rs:7: [hash-iter] msg");
    }
}
