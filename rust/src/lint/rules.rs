//! The lint rules.
//!
//! Each rule is a plain function over the masked view of one file
//! ([`scan::FileScan`]) plus its path *relative to `src/`* (e.g.
//! `cloudsim/mod.rs`) — the path decides which rules apply, so the rules
//! are trivially testable against inline fixtures under any fake path.
//! Matching is lexical (substring with identifier boundaries) on code that
//! has strings and comments already blanked out, which is exactly the
//! rustc-`tidy` trade-off: no type information, near-zero false positives
//! in practice, and the `lint:allow` escape hatch for the rest.

use super::scan::FileScan;
use super::Violation;

/// Modules whose state reaches campaign output, fingerprints, or RNG
/// consumption: map iteration order here must be deterministic.
pub const HASH_ITER_MODULES: [&str; 10] = [
    "cloudsim",
    "presched",
    "framework",
    "workload",
    "market",
    "sweep",
    "dynsched",
    "mapping",
    "outlook",
    "telemetry",
];

/// The only files allowed to read wall-clock time or OS randomness: the
/// bench harness (measures real elapsed time by design) and the
/// real-compute coordinator (reports real round timings).
pub const WALL_CLOCK_EXEMPT: [&str; 2] = ["util/bench.rs", "coordinator/real.rs"];

/// Files where solver/billing costs live: bare float `==`/`!=` here must
/// use the 1e-9 epsilon convention instead.
pub const FLOAT_EQ_MODULES: [&str; 2] = ["solver", "mapping"];
pub const FLOAT_EQ_FILES: [&str; 1] = ["cloudsim/billing.rs"];

/// TOML-parse paths where user-written spec input flows: parse errors must
/// be `anyhow` errors naming the offending key, never panics.
pub const SPEC_PARSE_FILES: [&str; 4] =
    ["market/spec.rs", "sweep/spec.rs", "workload/spec.rs", "cloud/catalog.rs"];

/// Files hosting a spec-table parser, each of which must call the shared
/// `tomlmini::reject_unknown_keys` helper at least once.
pub const UNKNOWN_KEY_FILES: [&str; 7] = [
    "market/spec.rs",
    "sweep/spec.rs",
    "workload/spec.rs",
    "cloud/catalog.rs",
    "coordinator/mod.rs",
    "outlook/spec.rs",
    "telemetry/spec.rs",
];

/// Run every rule over one scanned file. Allow-annotation filtering
/// happens in the caller ([`super::lint_source`]).
pub fn check_all(rel: &str, scan: &FileScan) -> Vec<Violation> {
    let mut out = Vec::new();
    check_hash_iter(rel, scan, &mut out);
    check_wall_clock(rel, scan, &mut out);
    check_float_eq(rel, scan, &mut out);
    check_spec_unwrap(rel, scan, &mut out);
    check_unknown_key(rel, scan, &mut out);
    out
}

/// `hash-iter`: no `HashMap`/`HashSet` in simulation-state modules.
fn check_hash_iter(rel: &str, scan: &FileScan, out: &mut Vec<Violation>) {
    let module = top_module(rel);
    if !HASH_ITER_MODULES.contains(&module) {
        return;
    }
    for (idx, code) in scan.code_lines.iter().enumerate() {
        let line = idx + 1;
        if scan.is_test_line(line) {
            continue;
        }
        for tok in ["HashMap", "HashSet"] {
            if find_token(code, tok).is_some() {
                out.push(Violation {
                    rule: "hash-iter",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "`{tok}` in simulation-state module `{module}` — iteration \
                         order is nondeterministic and can reach output or RNG \
                         consumption; use BTreeMap/BTreeSet or a sorted collect"
                    ),
                });
            }
        }
    }
}

/// `wall-clock`: no `Instant::now` / `SystemTime::now` / `thread_rng`
/// outside the bench harness and the real-compute coordinator.
fn check_wall_clock(rel: &str, scan: &FileScan, out: &mut Vec<Violation>) {
    if WALL_CLOCK_EXEMPT.contains(&rel) {
        return;
    }
    for (idx, code) in scan.code_lines.iter().enumerate() {
        let line = idx + 1;
        for tok in ["Instant::now", "SystemTime::now", "thread_rng"] {
            if find_token(code, tok).is_some() {
                out.push(Violation {
                    rule: "wall-clock",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "`{tok}` outside util::bench / coordinator::real — wall \
                         time and OS randomness break run-to-run reproducibility; \
                         inject a clock handle or use the seeded simul::Rng"
                    ),
                });
            }
        }
    }
}

/// `float-eq`: no bare `==`/`!=` against a float literal in solver /
/// mapping / cloudsim::billing — the 1e-9 epsilon convention applies.
fn check_float_eq(rel: &str, scan: &FileScan, out: &mut Vec<Violation>) {
    if !FLOAT_EQ_MODULES.contains(&top_module(rel)) && !FLOAT_EQ_FILES.contains(&rel) {
        return;
    }
    for (idx, code) in scan.code_lines.iter().enumerate() {
        let line = idx + 1;
        if scan.is_test_line(line) {
            continue;
        }
        if let Some(lit) = float_literal_compare(code) {
            out.push(Violation {
                rule: "float-eq",
                file: rel.to_string(),
                line,
                message: format!(
                    "bare `==`/`!=` against float literal `{lit}` — costs are \
                     compared with the 1e-9 epsilon convention: \
                     `(a - b).abs() < 1e-9` (or `> 1e-9` for inequality)"
                ),
            });
        }
    }
}

/// `spec-unwrap`: no `unwrap()` / `expect(` / panicking macros in
/// TOML-parse paths — user input flows there.
fn check_spec_unwrap(rel: &str, scan: &FileScan, out: &mut Vec<Violation>) {
    if !(rel.ends_with("/spec.rs") || SPEC_PARSE_FILES.contains(&rel)) {
        return;
    }
    const TOKENS: [&str; 6] =
        [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
    for (idx, code) in scan.code_lines.iter().enumerate() {
        let line = idx + 1;
        if scan.is_test_line(line) {
            continue;
        }
        for tok in TOKENS {
            if find_token(code, tok).is_some() {
                out.push(Violation {
                    rule: "spec-unwrap",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "`{tok}` in a TOML-parse path — user-written spec input \
                         flows here; return an anyhow error naming the offending \
                         key instead of panicking"
                    ),
                });
            }
        }
    }
}

/// `unknown-key`: every spec-table parser file must call the shared
/// `tomlmini::reject_unknown_keys` helper somewhere in production code.
fn check_unknown_key(rel: &str, scan: &FileScan, out: &mut Vec<Violation>) {
    if !UNKNOWN_KEY_FILES.contains(&rel) {
        return;
    }
    let calls_helper = scan.code_lines.iter().enumerate().any(|(idx, code)| {
        !scan.is_test_line(idx + 1) && find_token(code, "reject_unknown_keys").is_some()
    });
    if !calls_helper {
        out.push(Violation {
            rule: "unknown-key",
            file: rel.to_string(),
            line: 1,
            message: "spec-table parser never calls the shared \
                      `tomlmini::reject_unknown_keys` helper — every parsed table \
                      must reject unknown keys by name"
                .to_string(),
        });
    }
}

/// First path component with a `.rs` suffix stripped: `cloudsim/mod.rs` →
/// `cloudsim`, `main.rs` → `main`.
fn top_module(rel: &str) -> &str {
    let first = rel.split('/').next().unwrap_or(rel);
    first.strip_suffix(".rs").unwrap_or(first)
}

fn is_ident_char(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Substring search with identifier boundaries on both ends (so `HashMap`
/// does not match `MyHashMapLike`). Token edges that are not identifier
/// chars (`.`, `(`, `:`) make the corresponding boundary check a no-op.
fn find_token(code: &str, tok: &str) -> Option<usize> {
    let cb = code.as_bytes();
    let tb = tok.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok).map(|p| p + start) {
        let ok_before = pos == 0
            || !is_ident_char(cb[pos - 1])
            || !is_ident_char(tb[0]);
        let end = pos + tb.len();
        let ok_after = end >= cb.len()
            || !is_ident_char(cb[end])
            || !is_ident_char(tb[tb.len() - 1]);
        if ok_before && ok_after {
            return Some(pos);
        }
        start = pos + 1;
    }
    None
}

/// Scan one masked line for `==` / `!=` with a float literal on either
/// side; returns the literal. Heuristic: a float literal starts with an
/// ASCII digit and contains a `.` (or carries an explicit `f32`/`f64`
/// suffix) — identifier operands are never flagged, so epsilon-style
/// comparisons and integer comparisons pass untouched.
fn float_literal_compare(code: &str) -> Option<String> {
    let b = code.as_bytes();
    let n = b.len();
    let mut k = 0;
    while k + 1 < n {
        let (is_eq, is_ne) = (b[k] == b'=' && b[k + 1] == b'=', b[k] == b'!' && b[k + 1] == b'=');
        if !is_eq && !is_ne {
            k += 1;
            continue;
        }
        // Exclude `<=`, `>=`, `=>`, `===`-like runs and compound operators.
        if is_eq {
            let bad_before =
                k > 0 && matches!(b[k - 1], b'=' | b'!' | b'<' | b'>' | b'+' | b'-' | b'*' | b'/' | b'%' | b'&' | b'|' | b'^');
            let bad_after = k + 2 < n && b[k + 2] == b'=';
            if bad_before || bad_after {
                k += 2;
                continue;
            }
        }
        let left = operand_before(b, k);
        let right = operand_after(b, k + 2);
        for word in [left, right].into_iter().flatten() {
            if is_float_literal(&word) {
                return Some(word);
            }
        }
        k += 2;
    }
    None
}

fn operand_before(b: &[u8], op_start: usize) -> Option<String> {
    let mut j = op_start;
    while j > 0 && b[j - 1] == b' ' {
        j -= 1;
    }
    let end = j;
    while j > 0 && (is_ident_char(b[j - 1]) || b[j - 1] == b'.') {
        j -= 1;
    }
    (j < end).then(|| String::from_utf8_lossy(&b[j..end]).into_owned())
}

fn operand_after(b: &[u8], mut j: usize) -> Option<String> {
    let n = b.len();
    while j < n && b[j] == b' ' {
        j += 1;
    }
    if j < n && b[j] == b'-' {
        j += 1; // unary minus on a literal
    }
    let start = j;
    while j < n && (is_ident_char(b[j]) || b[j] == b'.') {
        j += 1;
    }
    (j > start).then(|| String::from_utf8_lossy(&b[start..j]).into_owned())
}

fn is_float_literal(word: &str) -> bool {
    let Some(first) = word.bytes().next() else {
        return false;
    };
    first.is_ascii_digit()
        && (word.contains('.') || word.ends_with("f32") || word.ends_with("f64"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan;

    fn hits(rel: &str, src: &str) -> Vec<String> {
        check_all(rel, &scan(src)).into_iter().map(|v| v.rule.to_string()).collect()
    }

    #[test]
    fn token_boundaries() {
        assert!(find_token("let m = HashMap::new();", "HashMap").is_some());
        assert!(find_token("let m = MyHashMapLike::new();", "HashMap").is_none());
        assert!(find_token("x.unwrap_or(0)", ".unwrap()").is_none());
        assert!(find_token("x.unwrap()", ".unwrap()").is_some());
    }

    #[test]
    fn float_compare_detection() {
        assert!(float_literal_compare("if x == 1.0 {").is_some());
        assert!(float_literal_compare("if 0.5 != y {").is_some());
        assert!(float_literal_compare("if x == -2.5 {").is_some());
        assert!(float_literal_compare("if x == 1f64 {").is_some());
        assert!(float_literal_compare("if n == 10 {").is_none());
        assert!(float_literal_compare("if a == b {").is_none());
        assert!(float_literal_compare("if x <= 1.0 {").is_none());
        assert!(float_literal_compare("if x >= 1.0 {").is_none());
        assert!(float_literal_compare("let f = |x| x == other;").is_none());
        assert!(float_literal_compare("(a - b).abs() < 1e-9").is_none());
    }

    #[test]
    fn module_scoping() {
        assert_eq!(top_module("cloudsim/mod.rs"), "cloudsim");
        assert_eq!(top_module("main.rs"), "main");
        assert!(hits("cloudsim/fake.rs", "fn f() { let m = HashMap::new(); }\n")
            .contains(&"hash-iter".to_string()));
        assert!(hits("data/fake.rs", "fn f() { let m = HashMap::new(); }\n").is_empty());
    }
}
