//! Pre-Scheduling module (§4.1).
//!
//! Runs a *dummy application* across the environment to obtain two slowdown
//! metrics used by the Initial Mapping and Dynamic Scheduler:
//!
//! 1. `sl_inst_jkl` — execution slowdown of every VM type vs a baseline VM
//!    (Table 3; baseline vm121 on CloudLab);
//! 2. `sl_comm_jklm` — communication slowdown of every region pair vs a
//!    baseline pair (Table 4; baseline APT–APT).
//!
//! It also measures the *job baselines* of the actual FL application: the
//! per-client train/test execution time on the baseline VM (`train_bl_i`,
//! `test_bl_i`) and the message-exchange time on the baseline pair
//! (`train_comm_bl`, `test_comm_bl`).
//!
//! The dummy app executes two rounds per VM (the first one pays framework /
//! accelerator warm-up, so slowdowns use round 2) — exactly the measurement
//! protocol behind Table 3. Results are cached on disk keyed by an
//! environment fingerprint so re-runs are no-ops until regions or VM types
//! change (§4.1: "it is not necessary to re-execute the dummy application in
//! every framework execution"); campaigns additionally share reports
//! in-memory through `crate::framework::EnvCache`, keyed by the same
//! [`fingerprint`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::cloud::tables::{DUMMY_TEST_GB, DUMMY_TRAIN_GB};
use crate::cloud::{Catalog, RegionId, VmTypeId};
use crate::cloudsim::MultiCloud;

/// One dummy-app measurement on one VM (two rounds of train+test).
#[derive(Debug, Clone, Copy)]
pub struct DummyRun {
    pub train_r1: f64,
    pub train_r2: f64,
    pub test_r1: f64,
    pub test_r2: f64,
}

/// One dummy message-exchange measurement between a region pair.
#[derive(Debug, Clone, Copy)]
pub struct CommRun {
    pub train_secs: f64,
    pub test_secs: f64,
}

/// The Pre-Scheduling output consumed by Initial Mapping / Dynamic Scheduler.
#[derive(Debug, Clone)]
pub struct SlowdownReport {
    /// Raw dummy measurements per VM type (Table 3's time columns).
    pub dummy_runs: BTreeMap<VmTypeId, DummyRun>,
    /// Raw exchange measurements per region pair (Table 4's time columns).
    pub comm_runs: BTreeMap<(RegionId, RegionId), CommRun>,
    /// `sl_inst` per VM type.
    pub exec_slowdown: BTreeMap<VmTypeId, f64>,
    /// `sl_comm` per (unordered, canonicalized) region pair.
    pub comm_slowdown: BTreeMap<(RegionId, RegionId), f64>,
    pub baseline_vm: VmTypeId,
    pub baseline_pair: (RegionId, RegionId),
    /// Fingerprint of the environment this report was measured on.
    pub fingerprint: String,
}

impl SlowdownReport {
    pub fn sl_inst(&self, vm: VmTypeId) -> f64 {
        self.exec_slowdown[&vm]
    }

    pub fn sl_comm(&self, a: RegionId, b: RegionId) -> f64 {
        self.comm_slowdown[&canon(a, b)]
    }
}

fn canon(a: RegionId, b: RegionId) -> (RegionId, RegionId) {
    if a <= b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Environment fingerprint: regions + VM ids + prices. A report is reusable
/// while this stays unchanged.
pub fn fingerprint(cat: &Catalog) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for r in &cat.regions {
        let _ = write!(s, "{}|", r.name);
    }
    for v in &cat.vm_types {
        let _ = write!(s, "{}:{}:{}:{};", v.id, v.vcpus, v.gpus, v.on_demand_hourly);
    }
    // FNV-1a, enough for a cache key.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// The Pre-Scheduling module.
pub struct PreScheduler<'a> {
    cloud: &'a MultiCloud,
}

impl<'a> PreScheduler<'a> {
    pub fn new(cloud: &'a MultiCloud) -> Self {
        Self { cloud }
    }

    /// Run the dummy application on every VM type and between every region
    /// pair, producing the slowdown report. `baseline_vm` / `baseline_pair`
    /// anchor the ratios (the paper uses vm121 and APT–APT on CloudLab).
    pub fn measure(&self, baseline_vm: VmTypeId, baseline_pair: (RegionId, RegionId)) -> SlowdownReport {
        let cat = &self.cloud.catalog;
        let gt = self.cloud.ground_truth();

        // --- execution: two dummy rounds per VM type ---
        let mut dummy_runs = BTreeMap::new();
        for vm in cat.vm_ids() {
            let d = gt.dummy_times(&cat.vm(vm).id);
            dummy_runs.insert(
                vm,
                DummyRun {
                    train_r1: d.train_r1,
                    train_r2: d.train_r2,
                    test_r1: d.test_r1,
                    test_r2: d.test_r2,
                },
            );
        }
        let base_steady = {
            let d = &dummy_runs[&baseline_vm];
            d.train_r2 + d.test_r2
        };
        let exec_slowdown = dummy_runs
            .iter()
            .map(|(&vm, d)| (vm, (d.train_r2 + d.test_r2) / base_steady))
            .collect();

        // --- communication: exchange the dummy volumes on every pair ---
        let mut comm_runs = BTreeMap::new();
        for a in cat.region_ids() {
            for b in cat.region_ids() {
                let key = canon(a, b);
                comm_runs.entry(key).or_insert_with(|| CommRun {
                    train_secs: self.cloud.network.transfer_secs(a, b, DUMMY_TRAIN_GB),
                    test_secs: self.cloud.network.transfer_secs(a, b, DUMMY_TEST_GB),
                });
            }
        }
        let base_total = {
            let c = &comm_runs[&canon(baseline_pair.0, baseline_pair.1)];
            c.train_secs + c.test_secs
        };
        let comm_slowdown = comm_runs
            .iter()
            .map(|(&k, c)| (k, (c.train_secs + c.test_secs) / base_total))
            .collect();

        SlowdownReport {
            dummy_runs,
            comm_runs,
            exec_slowdown,
            comm_slowdown,
            baseline_vm,
            baseline_pair,
            fingerprint: fingerprint(cat),
        }
    }

    /// Measure with the paper's default baselines: the first VM whose
    /// slowdown the paper normalizes to 1.0 (vm121 / first catalog VM) and
    /// the first region pair.
    pub fn measure_defaults(&self) -> SlowdownReport {
        let cat = &self.cloud.catalog;
        let gt = self.cloud.ground_truth();
        let baseline_vm = cat
            .vm_by_id(&gt.baseline_vm)
            .expect("ground-truth baseline VM not in catalog");
        let r = cat
            .region_by_name(&gt.baseline_pair.0)
            .expect("ground-truth baseline region not in catalog");
        let r2 = cat
            .region_by_name(&gt.baseline_pair.1)
            .expect("ground-truth baseline region not in catalog");
        self.measure(baseline_vm, (r, r2))
    }
}

/// Cache a report to disk / load it back, so the framework skips
/// re-measurement when the environment fingerprint matches.
pub mod cache {
    use super::*;

    pub fn save(report: &SlowdownReport, cat: &Catalog, path: &Path) -> anyhow::Result<()> {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "fingerprint = \"{}\"", report.fingerprint);
        let _ = writeln!(out, "baseline_vm = \"{}\"", cat.vm(report.baseline_vm).id);
        let _ = writeln!(
            out,
            "baseline_pair = [\"{}\", \"{}\"]",
            cat.region(report.baseline_pair.0).name,
            cat.region(report.baseline_pair.1).name
        );
        // BTreeMap iterates in ascending key order, so the cache file is
        // byte-identical to what the former sort-by-key emitted.
        for (vm, d) in &report.dummy_runs {
            let _ = writeln!(out, "\n[[exec]]");
            let _ = writeln!(out, "vm = \"{}\"", cat.vm(*vm).id);
            let _ = writeln!(
                out,
                "times = [{}, {}, {}, {}]",
                d.train_r1, d.train_r2, d.test_r1, d.test_r2
            );
        }
        for ((a, b), c) in &report.comm_runs {
            let _ = writeln!(out, "\n[[comm]]");
            let _ = writeln!(
                out,
                "pair = [\"{}\", \"{}\"]",
                cat.region(*a).name,
                cat.region(*b).name
            );
            let _ = writeln!(out, "times = [{}, {}]", c.train_secs, c.test_secs);
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Load a cached report; returns None when missing or stale (fingerprint
    /// mismatch), in which case the caller re-measures.
    pub fn load(cat: &Catalog, path: &Path) -> anyhow::Result<Option<SlowdownReport>> {
        if !path.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(path)?;
        let root = crate::util::tomlmini::parse(&text)?;
        let fp = root
            .get("fingerprint")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        if fp != fingerprint(cat) {
            return Ok(None); // environment changed → stale
        }
        let baseline_vm = cat
            .vm_by_id(root["baseline_vm"].as_str().unwrap_or_default())
            .ok_or_else(|| anyhow::anyhow!("cached baseline vm missing from catalog"))?;
        let pair = root["baseline_pair"]
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("bad baseline_pair"))?;
        let baseline_pair = (
            cat.region_by_name(pair[0].as_str().unwrap_or_default())
                .ok_or_else(|| anyhow::anyhow!("bad baseline region"))?,
            cat.region_by_name(pair[1].as_str().unwrap_or_default())
                .ok_or_else(|| anyhow::anyhow!("bad baseline region"))?,
        );
        let mut dummy_runs = BTreeMap::new();
        if let Some(execs) = root.get("exec").and_then(|v| v.as_table_array()) {
            for e in execs {
                let vm = cat
                    .vm_by_id(e["vm"].as_str().unwrap_or_default())
                    .ok_or_else(|| anyhow::anyhow!("cached vm missing"))?;
                let t = e["times"].as_array().ok_or_else(|| anyhow::anyhow!("bad times"))?;
                dummy_runs.insert(
                    vm,
                    DummyRun {
                        train_r1: t[0].as_float().unwrap_or(0.0),
                        train_r2: t[1].as_float().unwrap_or(0.0),
                        test_r1: t[2].as_float().unwrap_or(0.0),
                        test_r2: t[3].as_float().unwrap_or(0.0),
                    },
                );
            }
        }
        let mut comm_runs = BTreeMap::new();
        if let Some(comms) = root.get("comm").and_then(|v| v.as_table_array()) {
            for c in comms {
                let pair = c["pair"].as_array().ok_or_else(|| anyhow::anyhow!("bad pair"))?;
                let a = cat
                    .region_by_name(pair[0].as_str().unwrap_or_default())
                    .ok_or_else(|| anyhow::anyhow!("bad region"))?;
                let b = cat
                    .region_by_name(pair[1].as_str().unwrap_or_default())
                    .ok_or_else(|| anyhow::anyhow!("bad region"))?;
                let t = c["times"].as_array().ok_or_else(|| anyhow::anyhow!("bad times"))?;
                comm_runs.insert(
                    canon(a, b),
                    CommRun {
                        train_secs: t[0].as_float().unwrap_or(0.0),
                        test_secs: t[1].as_float().unwrap_or(0.0),
                    },
                );
            }
        }
        let base_steady = {
            let d = &dummy_runs[&baseline_vm];
            d.train_r2 + d.test_r2
        };
        let exec_slowdown = dummy_runs
            .iter()
            .map(|(&vm, d)| (vm, (d.train_r2 + d.test_r2) / base_steady))
            .collect();
        let base_total = {
            let c = &comm_runs[&canon(baseline_pair.0, baseline_pair.1)];
            c.train_secs + c.test_secs
        };
        let comm_slowdown = comm_runs
            .iter()
            .map(|(&k, c)| (k, (c.train_secs + c.test_secs) / base_total))
            .collect();
        Ok(Some(SlowdownReport {
            dummy_runs,
            comm_runs,
            exec_slowdown,
            comm_slowdown,
            baseline_vm,
            baseline_pair,
            fingerprint: fp,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;
    use crate::cloudsim::RevocationModel;

    fn cloudlab_sim() -> MultiCloud {
        MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::none(),
            7,
        )
    }

    #[test]
    fn measured_exec_slowdowns_match_table3() {
        let mc = cloudlab_sim();
        let report = PreScheduler::new(&mc).measure_defaults();
        let cat = &mc.catalog;
        let vm126 = cat.vm_by_id("vm126").unwrap();
        let vm212 = cat.vm_by_id("vm212").unwrap();
        let vm121 = cat.vm_by_id("vm121").unwrap();
        assert!((report.sl_inst(vm121) - 1.0).abs() < 1e-12);
        assert!((report.sl_inst(vm126) - 0.045).abs() < 0.001);
        assert!((report.sl_inst(vm212) - 2.328).abs() < 0.001);
    }

    #[test]
    fn measured_comm_slowdowns_match_table4() {
        let mc = cloudlab_sim();
        let report = PreScheduler::new(&mc).measure_defaults();
        let cat = &mc.catalog;
        let apt = cat.region_by_name("APT").unwrap();
        let mass = cat.region_by_name("Massachusetts").unwrap();
        let wis = cat.region_by_name("Wisconsin").unwrap();
        let utah = cat.region_by_name("Utah").unwrap();
        assert!((report.sl_comm(apt, apt) - 1.0).abs() < 0.03);
        assert!((report.sl_comm(mass, wis) - 24.731).abs() < 0.5);
        assert!((report.sl_comm(utah, utah) - 0.372).abs() < 0.03);
        // symmetric lookup
        assert_eq!(report.sl_comm(mass, wis), report.sl_comm(wis, mass));
    }

    #[test]
    fn report_covers_every_vm_and_pair() {
        let mc = cloudlab_sim();
        let report = PreScheduler::new(&mc).measure_defaults();
        assert_eq!(report.exec_slowdown.len(), mc.catalog.vm_types.len());
        let n = mc.catalog.regions.len();
        assert_eq!(report.comm_slowdown.len(), n * (n + 1) / 2);
    }

    #[test]
    fn cache_round_trip_and_staleness() {
        let mc = cloudlab_sim();
        let report = PreScheduler::new(&mc).measure_defaults();
        let dir = std::env::temp_dir().join(format!("mfls-presched-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("slowdowns.toml");
        cache::save(&report, &mc.catalog, &path).unwrap();
        let loaded = cache::load(&mc.catalog, &path).unwrap().expect("fresh cache");
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        assert!((loaded.sl_inst(vm126) - report.sl_inst(vm126)).abs() < 1e-12);
        // A different environment invalidates the cache.
        let other = tables::aws_gcp();
        assert!(cache::load(&other, &path).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_changes_with_prices() {
        let mut cat = tables::cloudlab();
        let f1 = fingerprint(&cat);
        cat.vm_types[0].on_demand_hourly *= 2.0;
        assert_ne!(f1, fingerprint(&cat));
    }
}
