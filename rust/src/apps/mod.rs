//! The paper's three Cross-Silo FL applications (§5.1) as descriptors.
//!
//! * **TIL** — Tumor-Infiltrating Lymphocyte classification: 4 clients with
//!   948 training / 522 test samples each, VGG16-class CNN, 504 MB model
//!   messages, 10 rounds with 5 local epochs. The per-client baseline round
//!   time (2765.4 s on vm121) and message baseline (8.66 s on APT–APT) are
//!   the paper's §5.4 measurements.
//! * **Shakespeare** (LEAF, adapted to Cross-Silo): 8 clients with
//!   16488–26282 training samples, char-LSTM (embedding 8, 2×256 LSTM),
//!   small messages, 20 rounds × 20 epochs.
//! * **FEMNIST** (LEAF, adapted): 5 clients with 796–1050 training samples,
//!   a robust CNN (2 conv + wide FC stack), 100 rounds × 100 epochs.
//!
//! Baseline execution times for the two LEAF apps are calibrated so the
//! simulated on-demand executions land on the paper's reported totals
//! (Shakespeare 1:53:54 / FEMNIST 1:56:37, §5.6.2); see EXPERIMENTS.md.

use crate::mapping::problem::{JobProfile, MessageSizes};

/// Static description of one FL application.
#[derive(Debug, Clone)]
pub struct AppSpec {
    pub name: &'static str,
    /// Per-client training-set sizes (drives execution-time heterogeneity).
    pub train_samples: Vec<u32>,
    pub test_samples: Vec<u32>,
    /// Per-round, per-client baseline (train+test) seconds on the baseline
    /// VM, for the *average-size* client; scaled linearly by samples.
    pub exec_bl_secs: f64,
    /// Fraction of `exec_bl_secs` spent in training (vs test).
    pub train_frac: f64,
    /// Round-trip message baseline on the baseline region pair, seconds.
    pub train_comm_bl: f64,
    pub test_comm_bl: f64,
    /// Server aggregation baseline seconds per round.
    pub agg_bl: f64,
    pub msg: MessageSizes,
    pub n_rounds: u32,
    pub local_epochs: u32,
    /// Checkpoint size (server model), GB — TIL's 504 MB is the paper's.
    pub checkpoint_gb: f64,
    /// L2 artifact prefix (`artifacts/<prefix>_train.hlo.txt` etc.) for
    /// real-compute runs; simulation-only experiments don't need it.
    pub artifact_prefix: &'static str,
}

impl AppSpec {
    pub fn n_clients(&self) -> usize {
        self.train_samples.len()
    }

    /// Expand into the Pre-Scheduling job profile (per-client baselines).
    pub fn profile(&self) -> JobProfile {
        let avg: f64 = self.train_samples.iter().map(|&s| s as f64).sum::<f64>()
            / self.train_samples.len() as f64;
        let mut client_train_bl = Vec::new();
        let mut client_test_bl = Vec::new();
        for (&tr, &te) in self.train_samples.iter().zip(&self.test_samples) {
            // Execution time scales with local dataset size; test time with
            // the test split.
            let scale_train = tr as f64 / avg;
            let avg_test: f64 = self.test_samples.iter().map(|&s| s as f64).sum::<f64>()
                / self.test_samples.len() as f64;
            let scale_test = te as f64 / avg_test;
            client_train_bl.push(self.exec_bl_secs * self.train_frac * scale_train);
            client_test_bl.push(self.exec_bl_secs * (1.0 - self.train_frac) * scale_test);
        }
        JobProfile {
            name: self.name.to_string(),
            client_train_bl,
            client_test_bl,
            train_comm_bl: self.train_comm_bl,
            test_comm_bl: self.test_comm_bl,
            agg_bl: self.agg_bl,
            msg: self.msg,
            n_rounds: self.n_rounds,
        }
    }
}

/// The TIL use-case application on the CloudLab environment (§5.1, §5.4).
pub fn til() -> AppSpec {
    AppSpec {
        name: "til",
        train_samples: vec![948; 4],
        test_samples: vec![522; 4],
        // §5.4: baseline (train+test) per round on vm121 = 2765.4 s.
        exec_bl_secs: 2765.4,
        // Split as in Table 3's baseline row (112.83 train / 2.22 test).
        train_frac: 112.83 / (112.83 + 2.22),
        // §5.4: communication baseline 8.66 s, split as Table 4's APT–APT row.
        train_comm_bl: 5.61,
        test_comm_bl: 3.05,
        agg_bl: 2.0,
        msg: MessageSizes {
            // VGG16-class model ≈ 504 MB per weight message (§5.5).
            s_train_gb: 0.504,
            s_aggreg_gb: 0.504,
            c_train_gb: 0.504,
            c_test_gb: 0.001, // metrics only
        },
        n_rounds: 10,
        local_epochs: 5,
        checkpoint_gb: 0.504,
        artifact_prefix: "til",
    }
}

/// TIL on the AWS/GCP proof-of-concept environment (§5.7): 2 clients (one
/// silo per cloud), baselines re-anchored to the g4dn.2xlarge baseline VM.
pub fn til_aws_gcp() -> AppSpec {
    AppSpec {
        name: "til-aws-gcp",
        train_samples: vec![948; 2],
        test_samples: vec![522; 2],
        // Calibrated: 10 rounds ≈ 2:00:18 on-demand incl. AWS boot (§5.7).
        exec_bl_secs: 700.0,
        train_frac: 0.96,
        train_comm_bl: 3.3,
        test_comm_bl: 1.7,
        agg_bl: 1.0,
        msg: MessageSizes {
            s_train_gb: 0.504,
            s_aggreg_gb: 0.504,
            c_train_gb: 0.504,
            c_test_gb: 0.001,
        },
        n_rounds: 10,
        local_epochs: 5,
        checkpoint_gb: 0.504,
        artifact_prefix: "til",
    }
}

/// LEAF Shakespeare adapted to Cross-Silo (§5.1): 8 clients, big datasets,
/// small LSTM model.
pub fn shakespeare() -> AppSpec {
    AppSpec {
        name: "shakespeare",
        // Paper: training sets range 16488–26282; evenly spread 8 clients.
        train_samples: vec![16488, 17887, 19286, 20685, 22084, 23483, 24882, 26282],
        test_samples: vec![1833, 1988, 2144, 2299, 2455, 2610, 2766, 2921],
        // Calibrated: 20 rounds ≈ 1:53:54 end-to-end on-demand (§5.6.2).
        exec_bl_secs: 400.0,
        train_frac: 0.95,
        train_comm_bl: 0.15,
        test_comm_bl: 0.08,
        agg_bl: 0.5,
        msg: MessageSizes {
            // Embedding-8 + 2×256 LSTM ≈ 3.3 MB per weight message.
            s_train_gb: 0.0033,
            s_aggreg_gb: 0.0033,
            c_train_gb: 0.0033,
            c_test_gb: 0.0001,
        },
        n_rounds: 20,
        local_epochs: 20,
        checkpoint_gb: 0.0033,
        artifact_prefix: "shakespeare",
    }
}

/// LEAF FEMNIST adapted to Cross-Silo (§5.1): 5 clients, small datasets,
/// robust CNN.
pub fn femnist() -> AppSpec {
    AppSpec {
        name: "femnist",
        train_samples: vec![796, 859, 922, 986, 1050],
        test_samples: vec![90, 97, 104, 111, 118],
        // Calibrated: 100 rounds ≈ 1:56:37 end-to-end on-demand (§5.6.2).
        exec_bl_secs: 1300.0,
        train_frac: 0.93,
        train_comm_bl: 1.2,
        test_comm_bl: 0.6,
        agg_bl: 0.8,
        msg: MessageSizes {
            // Conv + wide-FC stack ≈ 180 MB per weight message.
            s_train_gb: 0.18,
            s_aggreg_gb: 0.18,
            c_train_gb: 0.18,
            c_test_gb: 0.0001,
        },
        n_rounds: 100,
        local_epochs: 100,
        checkpoint_gb: 0.18,
        artifact_prefix: "femnist",
    }
}

/// All application descriptors by name (CLI lookup).
pub fn by_name(name: &str) -> Option<AppSpec> {
    match name {
        "til" => Some(til()),
        "til-aws-gcp" => Some(til_aws_gcp()),
        "shakespeare" => Some(shakespeare()),
        "femnist" => Some(femnist()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn til_matches_paper_parameters() {
        let app = til();
        assert_eq!(app.n_clients(), 4);
        assert_eq!(app.train_samples[0], 948);
        assert_eq!(app.test_samples[0], 522);
        let profile = app.profile();
        // Homogeneous clients → every baseline equals 2765.4 split.
        for i in 0..4 {
            let total = profile.client_train_bl[i] + profile.client_test_bl[i];
            assert!((total - 2765.4).abs() < 1e-6, "client {i}: {total}");
        }
        assert!((profile.train_comm_bl + profile.test_comm_bl - 8.66).abs() < 1e-9);
    }

    #[test]
    fn shakespeare_has_heterogeneous_clients() {
        let profile = shakespeare().profile();
        assert_eq!(profile.n_clients(), 8);
        // Largest client trains ~1.6x longer than smallest (26282/16488).
        let ratio = profile.client_train_bl[7] / profile.client_train_bl[0];
        assert!((ratio - 26282.0 / 16488.0).abs() < 1e-6);
    }

    #[test]
    fn femnist_sample_ranges_match_paper() {
        let app = femnist();
        assert_eq!(app.n_clients(), 5);
        assert_eq!(*app.train_samples.first().unwrap(), 796);
        assert_eq!(*app.train_samples.last().unwrap(), 1050);
        assert_eq!(*app.test_samples.first().unwrap(), 90);
        assert_eq!(*app.test_samples.last().unwrap(), 118);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("til").is_some());
        assert!(by_name("shakespeare").is_some());
        assert!(by_name("femnist").is_some());
        assert!(by_name("til-aws-gcp").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn message_round_volume() {
        let m = til().msg;
        // ~1.5 GB exchanged per client per round (3× 504 MB weights).
        assert!((m.round_total_gb() - 1.513).abs() < 0.01);
    }
}
