//! Server aggregation strategies. The paper's applications all use FedAvg
//! (weighted average by sample count); the trait keeps the server generic
//! (Flower-style pluggable strategy).

/// One client's round contribution.
#[derive(Debug, Clone)]
pub struct ClientUpdate {
    pub client: usize,
    pub weights: Vec<f32>,
    pub n_samples: u32,
}

/// Aggregation strategy (Flower's `Strategy.aggregate_fit` analogue).
pub trait Strategy: Send + Sync {
    fn name(&self) -> &'static str;

    /// Combine client updates into the new global weights.
    fn aggregate(&self, updates: &[ClientUpdate]) -> Vec<f32>;
}

/// FedAvg (McMahan et al. 2017): sample-count-weighted average.
#[derive(Debug, Clone, Default)]
pub struct FedAvg;

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Vec<f32> {
        assert!(!updates.is_empty(), "FedAvg over zero clients");
        let dim = updates[0].weights.len();
        let total: f64 = updates.iter().map(|u| u.n_samples as f64).sum();
        assert!(total > 0.0, "FedAvg with zero total samples");
        // Hot path (EXPERIMENTS.md §Perf): initialize from the first client,
        // then axpy the rest in f32. Cross-Silo FL has ≤ ~10 clients, so f32
        // accumulation loses < 3 ulp vs the f64 reference while letting the
        // compiler vectorize a single fused multiply-add pass per client.
        let w0 = (updates[0].n_samples as f64 / total) as f32;
        let mut out: Vec<f32> = updates[0].weights.iter().map(|&x| w0 * x).collect();
        for u in &updates[1..] {
            assert_eq!(u.weights.len(), dim, "client {} weight dim mismatch", u.client);
            let w = (u.n_samples as f64 / total) as f32;
            for (o, &x) in out.iter_mut().zip(&u.weights) {
                *o += w * x;
            }
        }
        out
    }
}

/// Unweighted mean (ablation baseline; ignores dataset-size heterogeneity).
#[derive(Debug, Clone, Default)]
pub struct UniformAvg;

impl Strategy for UniformAvg {
    fn name(&self) -> &'static str {
        "uniform-avg"
    }

    fn aggregate(&self, updates: &[ClientUpdate]) -> Vec<f32> {
        assert!(!updates.is_empty());
        let dim = updates[0].weights.len();
        let k = updates.len() as f64;
        let mut out = vec![0.0f64; dim];
        for u in updates {
            for (o, &x) in out.iter_mut().zip(&u.weights) {
                *o += x as f64 / k;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }
}

/// Aggregate evaluation metrics (weighted mean loss, pooled accuracy).
pub fn aggregate_metrics(results: &[(f64, u32, u32)]) -> (f64, f64) {
    // (loss, correct, n_samples) per client.
    let total: f64 = results.iter().map(|&(_, _, n)| n as f64).sum();
    if total == 0.0 {
        return (f64::NAN, f64::NAN);
    }
    let loss = results.iter().map(|&(l, _, n)| l * n as f64).sum::<f64>() / total;
    let acc = results.iter().map(|&(_, c, _)| c as f64).sum::<f64>() / total;
    (loss, acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fedavg_weighted_by_samples() {
        let updates = vec![
            ClientUpdate { client: 0, weights: vec![0.0, 0.0], n_samples: 30 },
            ClientUpdate { client: 1, weights: vec![10.0, 20.0], n_samples: 10 },
        ];
        let w = FedAvg.aggregate(&updates);
        assert!((w[0] - 2.5).abs() < 1e-6);
        assert!((w[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn fedavg_single_client_is_identity() {
        let updates = vec![ClientUpdate { client: 0, weights: vec![1.5, -2.0], n_samples: 7 }];
        assert_eq!(FedAvg.aggregate(&updates), vec![1.5, -2.0]);
    }

    #[test]
    fn uniform_ignores_sample_counts() {
        let updates = vec![
            ClientUpdate { client: 0, weights: vec![0.0], n_samples: 1000 },
            ClientUpdate { client: 1, weights: vec![10.0], n_samples: 1 },
        ];
        let w = UniformAvg.aggregate(&updates);
        assert!((w[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn dim_mismatch_panics() {
        let updates = vec![
            ClientUpdate { client: 0, weights: vec![0.0, 1.0], n_samples: 1 },
            ClientUpdate { client: 1, weights: vec![0.0], n_samples: 1 },
        ];
        FedAvg.aggregate(&updates);
    }

    #[test]
    fn metric_aggregation() {
        // 100 samples at loss 1.0 / 50 correct; 100 at loss 3.0 / 100 correct.
        let (loss, acc) = aggregate_metrics(&[(1.0, 50, 100), (3.0, 100, 100)]);
        assert!((loss - 2.0).abs() < 1e-9);
        assert!((acc - 0.75).abs() < 1e-9);
    }

    #[test]
    fn fedavg_preserves_constant_weights() {
        // All clients agree → aggregate is the same vector, regardless of n.
        let updates: Vec<ClientUpdate> = (0..5)
            .map(|c| ClientUpdate { client: c, weights: vec![0.5; 16], n_samples: (c as u32 + 1) * 10 })
            .collect();
        let w = FedAvg.aggregate(&updates);
        for v in w {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }
}
