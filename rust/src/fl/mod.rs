//! A Flower-like Cross-Silo FL runtime (§3, §4).
//!
//! The server and each client run as OS threads connected by channels; the
//! server drives communication rounds (train phase → aggregate → eval
//! phase), checkpoints every X rounds through the Fault Tolerance module,
//! and tolerates client failures by re-issuing the round to the restarted
//! task — the in-process analogue of Multi-FedLS relaunching the task on a
//! fresh VM. As in the paper (§4.3), the server always waits for *all*
//! clients before proceeding (Cross-Silo FL has few clients; skipping one
//! every round harms the model).

pub mod message;
pub mod strategy;
pub mod trainer;

pub use message::{ClientMsg, ServerMsg};
pub use strategy::{ClientUpdate, FedAvg, Strategy, UniformAvg};
pub use trainer::{QuadraticTrainer, Trainer};

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::ft::{Checkpoint, CheckpointStore};

/// Per-round results recorded by the server.
#[derive(Debug, Clone)]
pub struct RoundMetrics {
    pub round: u32,
    /// Sample-weighted mean evaluation loss across clients.
    pub loss: f64,
    /// Pooled accuracy across clients.
    pub accuracy: f64,
    /// Client failures handled during this round.
    pub failures: u32,
    /// Total bytes moved (both directions) this round.
    pub bytes: u64,
    /// Wall-clock seconds for the round.
    pub wall_secs: f64,
}

/// Clock handle filling [`RoundMetrics::wall_secs`]: seconds since an
/// epoch of the caller's choosing, sampled at round boundaries.
pub type ClockFn = Box<dyn Fn() -> f64 + Send>;

/// FL job configuration.
pub struct FlConfig {
    pub rounds: u32,
    /// Server checkpoint cadence (None disables).
    pub server_ckpt_every: Option<u32>,
    /// Clients persist received weights each round when a store is given.
    pub checkpoint_store: Option<CheckpointStore>,
    /// Resume the global model from a checkpoint (server restart path).
    pub resume_from: Option<Checkpoint>,
    /// Injected clock for per-round wall timings. The library itself never
    /// reads wall time (the `wall-clock` lint bans it here): the default is
    /// a constant zero clock, so simulated and test runs report
    /// `wall_secs = 0`; `coordinator::real` injects an `Instant`-based
    /// elapsed-seconds clock for real-compute runs.
    pub clock: ClockFn,
}

impl Default for FlConfig {
    fn default() -> Self {
        Self {
            rounds: 10,
            server_ckpt_every: None,
            checkpoint_store: None,
            resume_from: None,
            clock: Box::new(|| 0.0),
        }
    }
}

/// Outcome of a federated run.
#[derive(Debug)]
pub struct FlOutcome {
    pub history: Vec<RoundMetrics>,
    pub final_weights: Vec<f32>,
    pub total_failures: u32,
    pub first_round: u32,
}

/// Client task: answer the server's phase messages until shutdown.
fn client_loop(
    id: usize,
    mut trainer: Box<dyn Trainer>,
    rx: Receiver<ServerMsg>,
    tx: Sender<ClientMsg>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            ServerMsg::Train { round, weights } => {
                match trainer.train_round(&weights, round) {
                    Ok(new_weights) => {
                        let _ = tx.send(ClientMsg::TrainDone {
                            round,
                            client: id,
                            weights: new_weights,
                            n_samples: trainer.n_train_samples(),
                        });
                    }
                    Err(e) => {
                        let _ = tx.send(ClientMsg::Failed {
                            round,
                            client: id,
                            reason: e.to_string(),
                        });
                    }
                }
            }
            ServerMsg::Eval { round, weights } => match trainer.evaluate(&weights) {
                Ok((loss, correct)) => {
                    let _ = tx.send(ClientMsg::EvalDone {
                        round,
                        client: id,
                        loss,
                        correct,
                        n_samples: trainer.n_test_samples(),
                    });
                }
                Err(e) => {
                    let _ = tx.send(ClientMsg::Failed { round, client: id, reason: e.to_string() });
                }
            },
            ServerMsg::Shutdown => break,
        }
    }
}

/// Run a federated job in-process: one thread per client, server inline.
///
/// This is the runtime behind the real-compute examples; the hours-long
/// failure-simulation experiments use the DES-based driver in
/// [`crate::coordinator`] instead (same module structure, virtual time).
pub fn run_federated(
    trainers: Vec<Box<dyn Trainer>>,
    strategy: &dyn Strategy,
    initial_weights: Vec<f32>,
    mut config: FlConfig,
) -> anyhow::Result<FlOutcome> {
    let n = trainers.len();
    anyhow::ensure!(n > 0, "no clients");
    let (tx_server, rx_server) = channel::<ClientMsg>();
    let mut client_txs = Vec::with_capacity(n);
    let mut joins = Vec::with_capacity(n);
    for (id, trainer) in trainers.into_iter().enumerate() {
        let (tx, rx) = channel::<ServerMsg>();
        let tx_s = tx_server.clone();
        joins.push(std::thread::spawn(move || client_loop(id, trainer, rx, tx_s)));
        client_txs.push(tx);
    }
    drop(tx_server);

    // Server restart path (§4.3): resume from the freshest checkpoint.
    let (mut weights, first_round) = match config.resume_from.take() {
        Some(ckpt) => (Arc::new(ckpt.weights), ckpt.round + 1),
        None => (Arc::new(initial_weights), 1),
    };

    let mut history = Vec::new();
    let mut total_failures = 0u32;
    // A task that keeps failing after restarts is a configuration error
    // (e.g. a shard smaller than a batch), not a transient revocation —
    // give up instead of ping-ponging forever.
    const MAX_RETRIES_PER_PHASE: u32 = 5;

    for round in first_round..first_round + config.rounds {
        let t0 = (config.clock)();
        let mut bytes = 0u64;
        let mut failures = 0u32;

        // --- training phase ---
        for tx in &client_txs {
            let msg = ServerMsg::Train { round, weights: weights.clone() };
            bytes += msg.wire_bytes() as u64;
            tx.send(msg).map_err(|_| anyhow::anyhow!("client channel closed"))?;
        }
        let mut updates: Vec<Option<ClientUpdate>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        while received < n {
            let msg = rx_server.recv()?;
            bytes += msg.wire_bytes() as u64;
            match msg {
                ClientMsg::TrainDone { round: r, client, weights: w, n_samples } if r == round => {
                    if updates[client].is_none() {
                        received += 1;
                    }
                    updates[client] = Some(ClientUpdate { client, weights: w, n_samples });
                }
                ClientMsg::Failed { round: r, client, reason } if r == round => {
                    // Fault Tolerance: the task is restarted (new VM in the
                    // cloud case) and the round re-issued to it. The server
                    // keeps waiting for all clients (§4.3).
                    failures += 1;
                    anyhow::ensure!(
                        failures <= MAX_RETRIES_PER_PHASE * n as u32,
                        "client {client} keeps failing in round {round}: {reason}"
                    );
                    let msg = ServerMsg::Train { round, weights: weights.clone() };
                    bytes += msg.wire_bytes() as u64;
                    client_txs[client]
                        .send(msg)
                        .map_err(|_| anyhow::anyhow!("client {client} channel closed"))?;
                }
                _ => {} // stale message from a previous round
            }
        }
        let updates: Vec<ClientUpdate> = updates.into_iter().map(|u| u.unwrap()).collect();
        weights = Arc::new(strategy.aggregate(&updates));

        // --- server checkpoint every X rounds ---
        if let (Some(every), Some(store)) = (config.server_ckpt_every, config.checkpoint_store.as_mut())
        {
            if round % every == 0 {
                store.save("server", &Checkpoint { round, weights: (*weights).clone() })?;
            }
        }

        // --- evaluation phase ---
        for tx in &client_txs {
            let msg = ServerMsg::Eval { round, weights: weights.clone() };
            bytes += msg.wire_bytes() as u64;
            tx.send(msg).map_err(|_| anyhow::anyhow!("client channel closed"))?;
        }
        // Clients checkpoint the received aggregated weights locally (§4.3).
        if let Some(store) = config.checkpoint_store.as_mut() {
            for client in 0..n {
                store.save(
                    &format!("client-{client}"),
                    &Checkpoint { round, weights: (*weights).clone() },
                )?;
            }
        }
        let mut results: Vec<Option<(f64, u32, u32)>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        while received < n {
            let msg = rx_server.recv()?;
            bytes += msg.wire_bytes() as u64;
            match msg {
                ClientMsg::EvalDone { round: r, client, loss, correct, n_samples } if r == round => {
                    if results[client].is_none() {
                        received += 1;
                    }
                    results[client] = Some((loss, correct, n_samples));
                }
                ClientMsg::Failed { round: r, client, reason } if r == round => {
                    failures += 1;
                    anyhow::ensure!(
                        failures <= MAX_RETRIES_PER_PHASE * n as u32,
                        "client {client} keeps failing in eval of round {round}: {reason}"
                    );
                    let msg = ServerMsg::Eval { round, weights: weights.clone() };
                    bytes += msg.wire_bytes() as u64;
                    client_txs[client]
                        .send(msg)
                        .map_err(|_| anyhow::anyhow!("client {client} channel closed"))?;
                }
                _ => {}
            }
        }
        let results: Vec<(f64, u32, u32)> = results.into_iter().map(|r| r.unwrap()).collect();
        let (loss, accuracy) = strategy::aggregate_metrics(&results);

        total_failures += failures;
        history.push(RoundMetrics {
            round,
            loss,
            accuracy,
            failures,
            bytes,
            wall_secs: (config.clock)() - t0,
        });
    }

    for tx in &client_txs {
        let _ = tx.send(ServerMsg::Shutdown);
    }
    for j in joins {
        let _ = j.join();
    }
    if let Some(store) = config.checkpoint_store.as_mut() {
        store.flush();
    }
    Ok(FlOutcome {
        history,
        final_weights: Arc::try_unwrap(weights).unwrap_or_else(|a| (*a).clone()),
        total_failures,
        first_round,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_clients(targets: &[Vec<f32>]) -> Vec<Box<dyn Trainer>> {
        targets
            .iter()
            .map(|t| Box::new(QuadraticTrainer::new(t.clone(), 100)) as Box<dyn Trainer>)
            .collect()
    }

    #[test]
    fn fedavg_converges_to_weighted_target_mean() {
        // Two equal-sized silos with targets (0,0) and (2,2): FedAvg fixed
        // point is (1,1).
        let trainers = quad_clients(&[vec![0.0, 0.0], vec![2.0, 2.0]]);
        let out = run_federated(
            trainers,
            &FedAvg,
            vec![5.0, -5.0],
            FlConfig { rounds: 30, ..Default::default() },
        )
        .unwrap();
        let w = out.final_weights;
        assert!((w[0] - 1.0).abs() < 1e-2 && (w[1] - 1.0).abs() < 1e-2, "{w:?}");
        // Loss decreases over training.
        assert!(out.history.last().unwrap().loss < out.history[0].loss);
    }

    #[test]
    fn unequal_silos_shift_the_fixed_point() {
        // 300 samples at target 0, 100 at target 4 → fixed point 1.0.
        let mut t0 = QuadraticTrainer::new(vec![0.0], 300);
        t0.lr = 0.9;
        t0.steps = 50; // near-exact local minimization each round
        let mut t1 = QuadraticTrainer::new(vec![4.0], 100);
        t1.lr = 0.9;
        t1.steps = 50;
        let out = run_federated(
            vec![Box::new(t0), Box::new(t1)],
            &FedAvg,
            vec![0.0],
            FlConfig { rounds: 25, ..Default::default() },
        )
        .unwrap();
        assert!((out.final_weights[0] - 1.0).abs() < 0.05, "{:?}", out.final_weights);
    }

    #[test]
    fn client_failure_is_retried_and_round_completes() {
        let mut failing = QuadraticTrainer::new(vec![1.0], 100);
        failing.fail_at_round = Some(3);
        let trainers: Vec<Box<dyn Trainer>> = vec![
            Box::new(failing),
            Box::new(QuadraticTrainer::new(vec![1.0], 100)),
        ];
        let out = run_federated(
            trainers,
            &FedAvg,
            vec![0.0],
            FlConfig { rounds: 6, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.total_failures, 1);
        assert_eq!(out.history.len(), 6);
        assert_eq!(out.history[2].failures, 1, "failure was at round 3");
        // Still converged.
        assert!((out.final_weights[0] - 1.0).abs() < 1e-2);
    }

    #[test]
    fn checkpoint_and_resume_reproduces_uninterrupted_run() {
        let dir = std::env::temp_dir().join(format!("mfls-fl-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        // Uninterrupted 10-round reference.
        let reference = run_federated(
            quad_clients(&[vec![0.0, 2.0], vec![2.0, 0.0]]),
            &FedAvg,
            vec![8.0, 8.0],
            FlConfig { rounds: 10, ..Default::default() },
        )
        .unwrap();

        // Interrupted: 6 rounds with checkpoints every 2, then the server
        // "dies" and a new one resumes from the freshest checkpoint (round 6)
        // for the remaining 4 rounds.
        let store = CheckpointStore::new(dir.join("ckpt"), Some(dir.join("stable"))).unwrap();
        let first = run_federated(
            quad_clients(&[vec![0.0, 2.0], vec![2.0, 0.0]]),
            &FedAvg,
            vec![8.0, 8.0],
            FlConfig {
                rounds: 6,
                server_ckpt_every: Some(2),
                checkpoint_store: Some(store),
                ..Default::default()
            },
        )
        .unwrap();
        drop(first);
        let store = CheckpointStore::new(dir.join("ckpt"), Some(dir.join("stable"))).unwrap();
        let latest = store.latest_stable("server").expect("server checkpoint replicated");
        assert_eq!(latest, 6);
        let ckpt = store.load("server", latest).unwrap();
        let resumed = run_federated(
            quad_clients(&[vec![0.0, 2.0], vec![2.0, 0.0]]),
            &FedAvg,
            vec![8.0, 8.0], // ignored on resume
            FlConfig { rounds: 4, resume_from: Some(ckpt), ..Default::default() },
        )
        .unwrap();
        assert_eq!(resumed.first_round, 7);
        // Deterministic trainers → identical final weights.
        for (a, b) in resumed.final_weights.iter().zip(&reference.final_weights) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn client_checkpoints_written_every_round() {
        let dir = std::env::temp_dir().join(format!("mfls-fl-cckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = CheckpointStore::new(dir.join("ckpt"), None).unwrap();
        let _ = run_federated(
            quad_clients(&[vec![1.0], vec![0.0]]),
            &FedAvg,
            vec![0.0],
            FlConfig {
                rounds: 3,
                checkpoint_store: Some(store),
                ..Default::default()
            },
        )
        .unwrap();
        let store = CheckpointStore::new(dir.join("ckpt"), None).unwrap();
        assert_eq!(store.latest_local("client-0"), Some(3));
        assert_eq!(store.latest_local("client-1"), Some(3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn history_records_bytes_and_rounds() {
        let out = run_federated(
            quad_clients(&[vec![0.0; 100], vec![1.0; 100]]),
            &FedAvg,
            vec![0.0; 100],
            FlConfig { rounds: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.history.len(), 2);
        for r in &out.history {
            // ≥ 4 weight messages of 400 bytes per round.
            assert!(r.bytes > 1600, "bytes={}", r.bytes);
            assert!(r.wall_secs >= 0.0);
        }
        assert_eq!(out.history[0].round, 1);
        assert_eq!(out.history[1].round, 2);
    }
}
