//! The client-side compute interface.
//!
//! A [`Trainer`] owns a client's local silo and its compute (in production:
//! the PJRT executor over the AOT-compiled JAX/Pallas train step, see
//! [`crate::runtime`]). The FL runtime only sees this trait, so tests and
//! simulations can plug in cheap models.

/// Client-local training/evaluation over a private silo.
pub trait Trainer: Send {
    /// Number of local training samples (FedAvg weight).
    fn n_train_samples(&self) -> u32;
    fn n_test_samples(&self) -> u32;

    /// One round of local training (the configured number of local epochs),
    /// starting from `weights`; returns the updated weights.
    fn train_round(&mut self, weights: &[f32], round: u32) -> anyhow::Result<Vec<f32>>;

    /// Evaluate `weights` on the local test split → (mean loss, #correct).
    fn evaluate(&mut self, weights: &[f32]) -> anyhow::Result<(f64, u32)>;
}

/// A closed-form FL problem for tests: client `i` holds a private quadratic
/// `f_i(w) = ½‖w − target_i‖²`; a training round takes `steps` gradient
/// steps `w ← w − lr (w − target_i)`. FedAvg over these clients converges to
/// the (sample-weighted) mean of the targets — verifiable exactly, which
/// makes it a sharp integration oracle for the whole runtime.
pub struct QuadraticTrainer {
    pub target: Vec<f32>,
    pub n_train: u32,
    pub n_test: u32,
    pub lr: f32,
    pub steps: u32,
    /// If set, fail (simulated revocation) when asked to train this round.
    pub fail_at_round: Option<u32>,
}

impl QuadraticTrainer {
    pub fn new(target: Vec<f32>, n_train: u32) -> Self {
        Self { target, n_train, n_test: n_train / 4, lr: 0.5, steps: 4, fail_at_round: None }
    }
}

impl Trainer for QuadraticTrainer {
    fn n_train_samples(&self) -> u32 {
        self.n_train
    }

    fn n_test_samples(&self) -> u32 {
        self.n_test
    }

    fn train_round(&mut self, weights: &[f32], round: u32) -> anyhow::Result<Vec<f32>> {
        if self.fail_at_round == Some(round) {
            self.fail_at_round = None; // fail once, then recover
            anyhow::bail!("simulated revocation at round {round}");
        }
        let mut w = weights.to_vec();
        for _ in 0..self.steps {
            for (wi, ti) in w.iter_mut().zip(&self.target) {
                *wi -= self.lr * (*wi - ti);
            }
        }
        Ok(w)
    }

    fn evaluate(&mut self, weights: &[f32]) -> anyhow::Result<(f64, u32)> {
        let loss: f64 = weights
            .iter()
            .zip(&self.target)
            .map(|(&w, &t)| 0.5 * ((w - t) as f64).powi(2))
            .sum::<f64>()
            / weights.len().max(1) as f64;
        // "Correct" when close to the local optimum — a crude accuracy.
        let correct = if loss < 0.05 { self.n_test } else { 0 };
        Ok((loss, correct))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadratic_trainer_descends() {
        let mut t = QuadraticTrainer::new(vec![1.0, -1.0], 100);
        let w0 = vec![0.0, 0.0];
        let w1 = t.train_round(&w0, 1).unwrap();
        let (l0, _) = t.evaluate(&w0).unwrap();
        let (l1, _) = t.evaluate(&w1).unwrap();
        assert!(l1 < l0);
    }

    #[test]
    fn quadratic_trainer_converges_to_target() {
        let mut t = QuadraticTrainer::new(vec![2.0, 3.0], 10);
        let mut w = vec![0.0, 0.0];
        for round in 0..20 {
            w = t.train_round(&w, round).unwrap();
        }
        assert!((w[0] - 2.0).abs() < 1e-3 && (w[1] - 3.0).abs() < 1e-3);
    }

    #[test]
    fn injected_failure_fires_once() {
        let mut t = QuadraticTrainer::new(vec![0.0], 10);
        t.fail_at_round = Some(3);
        assert!(t.train_round(&[0.0], 2).is_ok());
        assert!(t.train_round(&[0.0], 3).is_err());
        assert!(t.train_round(&[0.0], 3).is_ok(), "fails only once");
    }
}
