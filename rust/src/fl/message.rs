//! Wire messages of the FL round protocol (§3).
//!
//! One communication round = a training phase (`s_msg_train` →
//! `c_msg_train`) followed by an evaluation phase (`s_msg_aggreg` →
//! `c_msg_test`). Weights travel as flattened `f32` vectors (the same layout
//! the AOT-compiled train-step artifacts use).

use std::sync::Arc;

/// Server → client.
#[derive(Debug, Clone)]
pub enum ServerMsg {
    /// `s_msg_train`: start local training from these global weights.
    Train { round: u32, weights: Arc<Vec<f32>> },
    /// `s_msg_aggreg`: evaluate these aggregated weights locally.
    Eval { round: u32, weights: Arc<Vec<f32>> },
    /// Training finished; terminate cleanly.
    Shutdown,
}

/// Client → server.
#[derive(Debug, Clone)]
pub enum ClientMsg {
    /// `c_msg_train`: locally updated weights + sample count for FedAvg.
    TrainDone { round: u32, client: usize, weights: Vec<f32>, n_samples: u32 },
    /// `c_msg_test`: local evaluation metrics.
    EvalDone { round: u32, client: usize, loss: f64, correct: u32, n_samples: u32 },
    /// The client task died (revocation / runtime error); the Fault
    /// Tolerance module reacts by restarting it elsewhere.
    Failed { round: u32, client: usize, reason: String },
}

impl ServerMsg {
    /// Approximate on-wire size in bytes (used for cost accounting).
    pub fn wire_bytes(&self) -> usize {
        match self {
            ServerMsg::Train { weights, .. } | ServerMsg::Eval { weights, .. } => {
                8 + 4 * weights.len()
            }
            ServerMsg::Shutdown => 8,
        }
    }
}

impl ClientMsg {
    pub fn wire_bytes(&self) -> usize {
        match self {
            ClientMsg::TrainDone { weights, .. } => 16 + 4 * weights.len(),
            ClientMsg::EvalDone { .. } => 32,
            ClientMsg::Failed { reason, .. } => 16 + reason.len(),
        }
    }

    pub fn round(&self) -> u32 {
        match self {
            ClientMsg::TrainDone { round, .. }
            | ClientMsg::EvalDone { round, .. }
            | ClientMsg::Failed { round, .. } => *round,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_sizes_scale_with_weights() {
        let w = Arc::new(vec![0.0f32; 1000]);
        let m = ServerMsg::Train { round: 1, weights: w.clone() };
        assert_eq!(m.wire_bytes(), 8 + 4000);
        let c = ClientMsg::TrainDone { round: 1, client: 0, weights: vec![0.0; 1000], n_samples: 10 };
        assert_eq!(c.wire_bytes(), 16 + 4000);
        assert!(ClientMsg::EvalDone { round: 1, client: 0, loss: 0.0, correct: 1, n_samples: 2 }.wire_bytes() < 64);
    }

    #[test]
    fn round_extraction() {
        assert_eq!(
            ClientMsg::Failed { round: 9, client: 1, reason: "revoked".into() }.round(),
            9
        );
    }
}
