//! Declarative outlook configuration: the `[outlook]` job-spec table and
//! the `[[outlook]]` named definitions of sweep/workload specs.
//!
//! ```toml
//! [outlook]              # job spec: one table (presence turns it on)
//! horizon = 14400.0      # forecast window, seconds (default: the job's
//!                        # planning horizon, n_rounds × baseline round)
//! bid_risk = 0.1         # max acceptable eviction probability for
//!                        # [`MarketOutlook::advise_bid`], in [0, 1]
//! defer = true           # let the mapper delay provisioning past a spike
//! ```
//!
//! Sweep and workload specs define *named* outlooks as `[[outlook]]` tables
//! (same keys plus `name`) and select them per grid point via the
//! `outlooks` axis; `"off"` is the reserved built-in name for the disabled
//! default. Unknown keys are rejected by name, matching the rest of the
//! spec validation.
//!
//! [`MarketOutlook`]: super::MarketOutlook

use std::collections::BTreeMap;

use crate::util::tomlmini::{self, Value};

type Tbl = BTreeMap<String, Value>;

/// Market-outlook configuration carried by
/// [`crate::coordinator::SimConfig`]. The default (`enabled = false`) keeps
/// every consumer on the flat expected-factor path, bit-identical to the
/// outlook-less planner (`tests/outlook_parity.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct OutlookSpec {
    /// Whether the planning stack consults a [`super::MarketOutlook`] at
    /// all. Set by the presence of an `[outlook]` table.
    pub enabled: bool,
    /// Forecast window in seconds; `None` = the job's planning horizon.
    pub horizon_secs: Option<f64>,
    /// Eviction-probability ceiling for the bid advisor, in [0, 1].
    pub bid_risk: f64,
    /// Allow the Initial Mapping to defer provisioning past an upcoming
    /// price spike when the deadline slack allows.
    pub defer: bool,
}

impl Default for OutlookSpec {
    fn default() -> Self {
        OutlookSpec { enabled: false, horizon_secs: None, bid_risk: 0.1, defer: false }
    }
}

impl OutlookSpec {
    /// Parse an `[outlook]` table. Presence of the table enables the
    /// outlook; rejects unknown keys and out-of-range parameters by name.
    pub fn from_table(tbl: &Tbl) -> anyhow::Result<OutlookSpec> {
        let horizon_secs = match tbl.get("horizon") {
            None => None,
            Some(v) => {
                let h = v
                    .as_float()
                    .ok_or_else(|| anyhow::anyhow!("[outlook] horizon must be a number"))?;
                anyhow::ensure!(
                    h.is_finite() && h > 0.0,
                    "[outlook] horizon must be positive, got {h}"
                );
                Some(h)
            }
        };
        let bid_risk = match tbl.get("bid_risk") {
            None => OutlookSpec::default().bid_risk,
            Some(v) => {
                let r = v
                    .as_float()
                    .ok_or_else(|| anyhow::anyhow!("[outlook] bid_risk must be a number"))?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&r),
                    "[outlook] bid_risk must be in [0, 1], got {r}"
                );
                r
            }
        };
        let defer = match tbl.get("defer") {
            None => false,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| anyhow::anyhow!("[outlook] defer must be a boolean"))?,
        };
        tomlmini::reject_unknown_keys(tbl, &["horizon", "bid_risk", "defer"], "[outlook]")?;
        Ok(OutlookSpec { enabled: true, horizon_secs, bid_risk, defer })
    }
}

/// Parse the `[[outlook]]` definitions of a sweep/workload spec into a
/// name → spec map. Names must be unique and must not shadow the built-in
/// `"off"` default.
pub fn named_outlooks(root: &Tbl) -> anyhow::Result<BTreeMap<String, OutlookSpec>> {
    let mut out = BTreeMap::new();
    let Some(tables) = root.get("outlook") else { return Ok(out) };
    let tables = tables.as_table_array().ok_or_else(|| {
        anyhow::anyhow!("[[outlook]] must be an array of tables (use [[outlook]], not [outlook])")
    })?;
    for (i, tbl) in tables.iter().enumerate() {
        let name = tbl
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("[[outlook]] #{i} needs a `name`"))?
            .to_string();
        anyhow::ensure!(
            name != "off",
            "[[outlook]] name \"off\" is reserved for the built-in disabled default"
        );
        let mut body = tbl.clone();
        body.remove("name");
        let spec = OutlookSpec::from_table(&body)
            .map_err(|e| anyhow::anyhow!("[[outlook]] \"{name}\": {e}"))?;
        anyhow::ensure!(out.insert(name.clone(), spec).is_none(), "duplicate outlook {name}");
    }
    Ok(out)
}

/// Resolve an outlook reference from an `outlooks` grid axis or a per-job
/// `outlook = "name"` key: a defined name, or the built-in `"off"`.
pub fn resolve_outlook(
    name: &str,
    defs: &BTreeMap<String, OutlookSpec>,
) -> anyhow::Result<OutlookSpec> {
    if let Some(spec) = defs.get(name) {
        return Ok(spec.clone());
    }
    if name == "off" {
        return Ok(OutlookSpec::default());
    }
    anyhow::bail!("unknown outlook {name} (define it as a [[outlook]] table; built-in: off)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> anyhow::Result<OutlookSpec> {
        OutlookSpec::from_table(&tomlmini::parse(text).unwrap())
    }

    #[test]
    fn default_is_disabled_and_table_presence_enables() {
        let dflt = OutlookSpec::default();
        assert!(!dflt.enabled && !dflt.defer && dflt.horizon_secs.is_none());
        let spec = parse("").unwrap();
        assert!(spec.enabled, "an empty [outlook] table still turns the outlook on");
        assert_eq!(spec.horizon_secs, None);
        assert_eq!(spec.bid_risk, dflt.bid_risk);
    }

    #[test]
    fn parses_all_keys() {
        let spec = parse("horizon = 7200.0\nbid_risk = 0.25\ndefer = true\n").unwrap();
        assert_eq!(spec.horizon_secs, Some(7200.0));
        assert_eq!(spec.bid_risk, 0.25);
        assert!(spec.defer);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_ranges() {
        let err = parse("horizion = 10.0\n").unwrap_err().to_string();
        assert!(err.contains("unknown key `horizion`"), "{err}");
        assert!(parse("horizon = 0.0\n").is_err());
        assert!(parse("horizon = -5.0\n").is_err());
        assert!(parse("bid_risk = 1.5\n").is_err());
        assert!(parse("bid_risk = -0.1\n").is_err());
        assert!(parse("defer = 1.0\n").is_err(), "defer must be a boolean");
    }

    #[test]
    fn named_outlooks_resolve_and_reserve_off() {
        let root = tomlmini::parse(
            "[[outlook]]\nname = \"aware\"\nhorizon = 3600.0\ndefer = true\n",
        )
        .unwrap();
        let defs = named_outlooks(&root).unwrap();
        assert_eq!(defs.len(), 1);
        assert!(resolve_outlook("aware", &defs).unwrap().defer);
        assert!(!resolve_outlook("off", &defs).unwrap().enabled);
        assert!(resolve_outlook("nope", &defs).is_err());

        let reserved = tomlmini::parse("[[outlook]]\nname = \"off\"\n").unwrap();
        assert!(named_outlooks(&reserved).is_err());
        let unnamed = tomlmini::parse("[[outlook]]\ndefer = true\n").unwrap();
        assert!(named_outlooks(&unnamed).is_err());
        let dup = tomlmini::parse(
            "[[outlook]]\nname = \"a\"\n\n[[outlook]]\nname = \"a\"\n",
        )
        .unwrap();
        assert!(named_outlooks(&dup).is_err());
    }
}
