//! Market forecasting: turn a job's [`MarketSpec`] into a queryable
//! [`MarketOutlook`].
//!
//! The PR 4 spot-market subsystem gave every planner a single flat
//! `spot_price_factor` — the price series time-averaged over the whole
//! planning horizon — even when the configured [`PriceSeries`] has known
//! steps and the revocation process a time-varying hazard. The outlook
//! closes that gap with three families of queries, all closed-form and
//! deterministic (no sampling, no RNG):
//!
//! * **Windowed expected price** — [`MarketOutlook::expected_price_factor`]
//!   integrates the price series exactly over any `[t, t+h)` window, so a
//!   consumer can price a candidate over its *actual* remaining horizon
//!   instead of the job-wide average.
//! * **Survival / expected revocations** —
//!   [`MarketOutlook::integrated_hazard`] evaluates `Λ(a, b) = ∫ λ` for
//!   every revocation kind (exponential, Weibull and seasonal closed forms,
//!   trace counting), giving [`MarketOutlook::survival`]
//!   `S = exp(-Λ)` and [`MarketOutlook::expected_revocations`] without
//!   touching the simulation's RNG streams.
//! * **Bid advice / deferral** — [`MarketOutlook::advise_bid`] finds the
//!   cheapest bid factor whose eviction probability over an estimated
//!   makespan stays under the configured `bid_risk`, and
//!   [`MarketOutlook::best_start_offset`] finds the provisioning delay
//!   (among upcoming price-step instants) that minimizes the expected price
//!   over the job's duration — the `defer` decision surfaced in
//!   [`crate::mapping::MappingSolution`] and honored by `framework::exec`.
//!
//! Consumers (all gated on [`OutlookSpec::enabled`], so the outlook-off
//! default stays bit-identical to the flat expected-factor path —
//! `tests/outlook_parity.rs`):
//!
//! 1. [`crate::mapping::MappingProblem`] carries `Option<&MarketOutlook>`;
//!    [`crate::mapping::MappingProblem::defer_secs`] turns deadline slack
//!    into a delayed-start decision.
//! 2. [`crate::dynsched`] receives the outlook through
//!    [`crate::market::MarketView`] and prices each replacement candidate
//!    over the job's remaining-rounds window.
//! 3. The workload engine's admission retry loop asks
//!    [`MarketOutlook::next_price_event_after`] instead of its ad-hoc
//!    next-price-step probe.
//!
//! Like the [`crate::market`] revocation processes, the closed forms here
//! are pinned against the sampling implementations by tests: the seasonal
//! hazard is the same expression `SeasonalProcess` inverts, and the Weibull
//! hazard matches the inverse-CDF sampler's distribution.

pub mod spec;

pub use spec::{named_outlooks, resolve_outlook, OutlookSpec};

use crate::market::{MarketSpec, PriceSeries, PriceSpec, RevocationSpec};

/// A queryable forecast of one job's spot market: exact windowed price
/// integrals, closed-form revocation hazards, and bid/deferral advice.
/// Built once per job from its (possibly admission-shifted) [`MarketSpec`];
/// owns its data, so consumers can hold plain references.
#[derive(Debug, Clone)]
pub struct MarketOutlook {
    market: MarketSpec,
    /// The job's `revocation_mean_secs` (`k_r`) — consumed only by the
    /// exponential default; other processes carry their own parameters.
    k_r: Option<f64>,
    spec: OutlookSpec,
    /// Fallback forecast window when the spec pins no `horizon`.
    default_horizon_secs: f64,
    price: PriceSeries,
}

impl MarketOutlook {
    pub fn new(
        market: &MarketSpec,
        k_r: Option<f64>,
        spec: OutlookSpec,
        default_horizon_secs: f64,
    ) -> MarketOutlook {
        let price = market.price_series();
        MarketOutlook { market: market.clone(), k_r, spec, default_horizon_secs, price }
    }

    /// The configuration this outlook was built under.
    pub fn spec(&self) -> &OutlookSpec {
        &self.spec
    }

    /// The forecast window: the spec's `horizon`, or the job's planning
    /// horizon when unset.
    pub fn horizon_secs(&self) -> f64 {
        self.spec.horizon_secs.unwrap_or(self.default_horizon_secs)
    }

    /// Whether deferral advice may move a job's start (`defer = true`).
    pub fn defers(&self) -> bool {
        self.spec.defer
    }

    /// Spot-price multiplier in effect at instant `t`.
    pub fn price_factor_at(&self, t: f64) -> f64 {
        self.price.factor_at(t)
    }

    /// Expected (time-averaged) price factor over `[t, t+h)`, integrating
    /// the series exactly across its steps. The constant series is exactly
    /// 1.0 — the same bits the flat expected-factor path uses — and a
    /// degenerate window falls back to the instantaneous factor.
    pub fn expected_price_factor(&self, t: f64, h: f64) -> f64 {
        match &self.price {
            PriceSeries::Constant => 1.0,
            series => {
                if h.is_finite() && h > 0.0 {
                    series.weighted_secs(t, t + h) / h
                } else {
                    series.factor_at(t)
                }
            }
        }
    }

    /// Integrated revocation hazard `Λ(a, b) = ∫_a^b λ(t) dt` for a spot VM
    /// provisioned at instant `a`, in closed form:
    ///
    /// * exponential — `(b-a)/k_r` (0 when revocations are off);
    /// * Weibull — `((b-a)/λ)^k` (the hazard is *age*-driven: the VM is age
    ///   0 at `a`);
    /// * seasonal — the same closed form [`SeasonalProcess`] inverts,
    ///   `((b-a) + A/ω·(cos ω(a+φ) − cos ω(b+φ)))/mean`, on the job-local
    ///   clock (phase already folded in);
    /// * trace — the number of recorded instants in `(a, b]` (a VM
    ///   provisioned exactly at an instant survives it).
    ///
    /// [`SeasonalProcess`]: crate::market::SeasonalProcess
    pub fn integrated_hazard(&self, a: f64, b: f64) -> f64 {
        if b <= a {
            return 0.0;
        }
        match &self.market.revocation {
            RevocationSpec::Exponential => match self.k_r {
                Some(k) => (b - a) / k,
                None => 0.0,
            },
            RevocationSpec::Weibull { scale_secs, shape } => ((b - a) / scale_secs).powf(*shape),
            RevocationSpec::Seasonal { mean_secs, period_secs, amplitude, phase_secs } => {
                let w = std::f64::consts::TAU / period_secs;
                let (pa, pb) = (a + phase_secs, b + phase_secs);
                let sine_term = amplitude / w * ((w * pa).cos() - (w * pb).cos());
                ((pb - pa) + sine_term) / mean_secs
            }
            RevocationSpec::Trace { times } => {
                times.iter().filter(|&&at| at > a && at <= b).count() as f64
            }
        }
    }

    /// Probability that a spot VM provisioned at `a` is still alive at `b`:
    /// `exp(-Λ(a, b))` for the stochastic processes; 0/1 for the
    /// deterministic trace replay.
    pub fn survival(&self, a: f64, b: f64) -> f64 {
        match &self.market.revocation {
            RevocationSpec::Trace { .. } => {
                if self.integrated_hazard(a, b) > 0.0 {
                    0.0
                } else {
                    1.0
                }
            }
            _ => (-self.integrated_hazard(a, b)).exp(),
        }
    }

    /// Expected number of revocation events hitting a task that keeps a
    /// spot VM provisioned (and replaced in place) over `[a, b)` — the
    /// integrated hazard itself, by the time-rescaling property.
    pub fn expected_revocations(&self, a: f64, b: f64) -> f64 {
        self.integrated_hazard(a, b)
    }

    /// Cheapest bid factor for a spot VM provisioned at `at` that keeps its
    /// eviction probability over the next `duration_secs` within the
    /// configured `bid_risk`. Price-driven eviction is deterministic given
    /// the series (a step with `factor > bid` inside the window evicts with
    /// certainty), so the advised bid is the maximum factor reached during
    /// the window; `None` when the revocation process alone already exceeds
    /// the risk ceiling — no bid level can help.
    pub fn advise_bid(&self, at: f64, duration_secs: f64) -> Option<f64> {
        let end = at + duration_secs.max(0.0);
        if 1.0 - self.survival(at, end) > self.spec.bid_risk + 1e-9 {
            return None;
        }
        let mut bid = self.price_factor_at(at);
        if let PriceSpec::Steps(points) = &self.market.price {
            for &(step_at, factor) in points {
                if step_at > at && step_at < end {
                    bid = bid.max(factor);
                }
            }
        }
        Some(bid)
    }

    /// The provisioning delay (from the job-local t = 0) minimizing the
    /// expected price factor over a run of `duration_secs`, considering
    /// starting now or at any upcoming price-step instant within
    /// `max_delay_secs`. Returns 0.0 unless some deferral is *strictly*
    /// cheaper (beyond the repo-wide 1e-9 epsilon); ties keep the earliest
    /// start. Constant-price markets always return 0.0, which keeps
    /// outlook-on runs on such markets bit-identical to outlook-off
    /// (`tests/outlook_parity.rs`).
    pub fn best_start_offset(&self, duration_secs: f64, max_delay_secs: f64) -> f64 {
        if !(max_delay_secs > 0.0) || !(duration_secs > 0.0) || !duration_secs.is_finite() {
            return 0.0;
        }
        let PriceSpec::Steps(points) = &self.market.price else { return 0.0 };
        let mut best_at = 0.0;
        let mut best_cost = self.expected_price_factor(0.0, duration_secs);
        for &(at, _) in points {
            if at <= 0.0 {
                continue;
            }
            if at > max_delay_secs {
                break;
            }
            let cost = self.expected_price_factor(at, duration_secs);
            if cost < best_cost - 1e-9 {
                best_at = at;
                best_cost = cost;
            }
        }
        best_at
    }

    /// The next instant strictly after `t` at which the price changes —
    /// when a budget-capped job's admission feasibility can next change
    /// without a capacity release (the workload engine's retry instants).
    pub fn next_price_event_after(&self, t: f64) -> Option<f64> {
        self.market.next_price_step_after(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::market::SeasonalProcess;
    use crate::simul::{Rng, SimTime};

    fn steps_market() -> MarketSpec {
        MarketSpec {
            price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8), (10_800.0, 0.6)]),
            ..MarketSpec::default()
        }
    }

    fn outlook(market: MarketSpec, k_r: Option<f64>) -> MarketOutlook {
        MarketOutlook::new(&market, k_r, OutlookSpec::default(), 86_400.0)
    }

    #[test]
    fn expected_price_integrates_windows_exactly() {
        let o = outlook(steps_market(), None);
        // [0, 7200): 3600·1.0 + 3600·1.8 over 7200 s.
        assert!((o.expected_price_factor(0.0, 7200.0) - 1.4).abs() < 1e-12);
        // A window entirely inside one step is that step's factor.
        assert!((o.expected_price_factor(4000.0, 1000.0) - 1.8).abs() < 1e-12);
        assert!((o.expected_price_factor(20_000.0, 5000.0) - 0.6).abs() < 1e-12);
        // Degenerate windows fall back to the instantaneous factor.
        assert!((o.expected_price_factor(5000.0, 0.0) - 1.8).abs() < 1e-12);
        assert!((o.expected_price_factor(5000.0, f64::INFINITY) - 1.8).abs() < 1e-12);
        // The constant series is exactly 1.0 (bit-level parity anchor).
        let c = outlook(MarketSpec::default(), Some(7200.0));
        assert_eq!(c.expected_price_factor(123.4, 5678.9).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn exponential_hazard_matches_k_r() {
        let o = outlook(MarketSpec::default(), Some(7200.0));
        assert!((o.integrated_hazard(0.0, 7200.0) - 1.0).abs() < 1e-12);
        assert!((o.survival(0.0, 7200.0) - (-1.0f64).exp()).abs() < 1e-12);
        assert_eq!(o.expected_revocations(500.0, 500.0), 0.0);
        // Revocations off: certain survival.
        let off = outlook(MarketSpec::default(), None);
        assert_eq!(off.integrated_hazard(0.0, 1e6), 0.0);
        assert_eq!(off.survival(0.0, 1e6), 1.0);
    }

    #[test]
    fn weibull_hazard_matches_the_sampler_distribution() {
        // P(life > x) = exp(-(x/λ)^k): compare the closed form against the
        // empirical survival of the inverse-CDF sampler.
        let market = MarketSpec {
            revocation: RevocationSpec::Weibull { scale_secs: 5000.0, shape: 0.7 },
            ..MarketSpec::default()
        };
        let o = outlook(market, None);
        let proc_ = crate::market::WeibullProcess { scale_secs: 5000.0, shape: 0.7 };
        let mut rng = Rng::seeded(13);
        let n = 40_000;
        let x = 4000.0;
        let alive = (0..n)
            .filter(|_| {
                proc_.sample(SimTime::ZERO, &mut rng).unwrap().secs() > x
            })
            .count() as f64
            / n as f64;
        let want = o.survival(0.0, x);
        assert!((alive - want).abs() < 0.01, "empirical={alive} closed-form={want}");
    }

    #[test]
    fn seasonal_hazard_is_the_process_closed_form() {
        // Pin the outlook's seasonal Λ to the expression SeasonalProcess
        // inverts: a sample at hazard-inversion precision must satisfy
        // Λ(t0, sample) = E for the same RNG stream.
        let market = MarketSpec {
            revocation: RevocationSpec::Seasonal {
                mean_secs: 3600.0,
                period_secs: 7200.0,
                amplitude: 0.8,
                phase_secs: 250.0,
            },
            ..MarketSpec::default()
        };
        let o = outlook(market, None);
        let proc_ = SeasonalProcess {
            mean_secs: 3600.0,
            period_secs: 7200.0,
            amplitude: 0.8,
            phase_secs: 250.0,
        };
        let mut a = Rng::seeded(17);
        let mut b = Rng::seeded(17);
        for _ in 0..50 {
            let now = 500.0;
            let got = proc_.sample(SimTime::from_secs(now), &mut a).unwrap();
            let e = -b.next_f64_open().ln();
            // The outlook works on the job-local clock: its phase handling
            // must line up with the process's `now + phase` anchoring.
            let lambda = o.integrated_hazard(now, got.secs());
            assert!((lambda - e).abs() < 1e-6, "Λ={lambda} vs E={e}");
        }
    }

    #[test]
    fn trace_hazard_counts_instants_and_survival_is_deterministic() {
        let market = MarketSpec {
            revocation: RevocationSpec::Trace { times: vec![100.0, 500.0, 900.0] },
            ..MarketSpec::default()
        };
        let o = outlook(market, None);
        assert_eq!(o.expected_revocations(0.0, 1000.0), 3.0);
        assert_eq!(o.expected_revocations(100.0, 500.0), 1.0, "(a, b] window");
        assert_eq!(o.survival(0.0, 50.0), 1.0);
        assert_eq!(o.survival(0.0, 100.0), 0.0);
        assert_eq!(o.survival(900.0, 2000.0), 1.0, "trace exhausted");
    }

    #[test]
    fn bid_advice_covers_the_window_or_declines() {
        let spec = OutlookSpec { bid_risk: 0.5, ..OutlookSpec::default() };
        let o = MarketOutlook::new(&steps_market(), Some(1e9), spec, 86_400.0);
        // Window [0, 5000) spans the 1.8 spike: the cheapest safe bid rides
        // just at the spike.
        assert_eq!(o.advise_bid(0.0, 5000.0), Some(1.8));
        // A window inside the first step never sees the spike.
        assert_eq!(o.advise_bid(0.0, 3600.0), Some(1.0));
        // Provisioned during the spike, headed into the cheap regime.
        assert_eq!(o.advise_bid(4000.0, 10_000.0), Some(1.8));
        // A hazard above the risk ceiling cannot be bid away.
        let risky = MarketOutlook::new(
            &steps_market(),
            Some(100.0),
            OutlookSpec { bid_risk: 0.01, ..OutlookSpec::default() },
            86_400.0,
        );
        assert_eq!(risky.advise_bid(0.0, 5000.0), None);
    }

    #[test]
    fn deferral_waits_out_a_spike_only_when_allowed_by_slack() {
        let o = outlook(steps_market(), None);
        // A 4000 s run started now straddles the 1.8 spike; started at the
        // 10 800 s step it rides the 0.6 regime throughout.
        let off = o.best_start_offset(4000.0, 20_000.0);
        assert_eq!(off, 10_800.0);
        // Not enough slack to reach the cheap regime: starting now (1.0
        // first) still beats starting at the spike step.
        assert_eq!(o.best_start_offset(4000.0, 5000.0), 0.0);
        // Degenerate inputs and constant markets never defer.
        assert_eq!(o.best_start_offset(0.0, 20_000.0), 0.0);
        assert_eq!(o.best_start_offset(4000.0, 0.0), 0.0);
        let c = outlook(MarketSpec::default(), Some(7200.0));
        assert_eq!(c.best_start_offset(4000.0, f64::INFINITY), 0.0);
    }

    #[test]
    fn horizon_defaults_to_the_planning_horizon() {
        let o = MarketOutlook::new(
            &MarketSpec::default(),
            None,
            OutlookSpec { enabled: true, ..OutlookSpec::default() },
            12_345.0,
        );
        assert_eq!(o.horizon_secs(), 12_345.0);
        let pinned = MarketOutlook::new(
            &MarketSpec::default(),
            None,
            OutlookSpec { enabled: true, horizon_secs: Some(60.0), ..OutlookSpec::default() },
            12_345.0,
        );
        assert_eq!(pinned.horizon_secs(), 60.0);
        assert_eq!(pinned.next_price_event_after(0.0), None);
        let s = outlook(steps_market(), None);
        assert_eq!(s.next_price_event_after(0.0), Some(3600.0));
        assert_eq!(s.next_price_event_after(10_800.0), None);
    }
}
