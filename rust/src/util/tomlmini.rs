//! A minimal TOML-subset parser and writer for the config system.
//!
//! The environment is offline (no `toml`/`serde` crates), so we implement the
//! subset the framework's config files need:
//!
//! * top-level `key = value` pairs
//! * tables: `[section]` and dotted keys within sections
//! * arrays of tables: `[[section]]`
//! * values: strings (basic, `"..."`), integers, floats, booleans, and
//!   homogeneous inline arrays `[1, 2, 3]`
//! * `#` comments and blank lines
//!
//! Not supported (and not needed by `configs/`): multi-line strings, dates,
//! nested inline tables, array-of-array.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
    /// A table (section) of key → value.
    Table(BTreeMap<String, Value>),
    /// An array of tables (`[[name]]`).
    TableArray(Vec<BTreeMap<String, Value>>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric coercion: ints count as floats (TOML writes `1` for `1.0`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_table_array(&self) -> Option<&[BTreeMap<String, Value>]> {
        match self {
            Value::TableArray(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Parse a document into its root table.
pub fn parse(text: &str) -> Result<BTreeMap<String, Value>, ParseError> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    // Path of the currently open section; empty = root. The bool is "array
    // of tables" (append mode).
    let mut current: Option<(String, bool)> = None;

    for (idx, raw) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let name = name.trim().to_string();
            if name.is_empty() {
                return Err(err(lineno, "empty table-array name"));
            }
            match root
                .entry(name.clone())
                .or_insert_with(|| Value::TableArray(Vec::new()))
            {
                Value::TableArray(v) => v.push(BTreeMap::new()),
                _ => return Err(err(lineno, format!("{name} is not an array of tables"))),
            }
            current = Some((name, true));
        } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let name = name.trim().to_string();
            if name.is_empty() {
                return Err(err(lineno, "empty table name"));
            }
            match root.entry(name.clone()).or_insert_with(|| Value::Table(BTreeMap::new())) {
                Value::Table(_) => {}
                _ => return Err(err(lineno, format!("{name} is not a table"))),
            }
            current = Some((name, false));
        } else {
            let (key, val_text) = line
                .split_once('=')
                .ok_or_else(|| err(lineno, format!("expected key = value, got {line:?}")))?;
            let key = key.trim().to_string();
            let value = parse_value(val_text.trim(), lineno)?;
            let target = match &current {
                None => &mut root,
                Some((name, false)) => match root.get_mut(name) {
                    Some(Value::Table(t)) => t,
                    _ => unreachable!("section registered above"),
                },
                Some((name, true)) => match root.get_mut(name) {
                    Some(Value::TableArray(v)) => v.last_mut().expect("entry pushed above"),
                    _ => unreachable!(),
                },
            };
            if target.insert(key.clone(), value).is_some() {
                return Err(err(lineno, format!("duplicate key {key}")));
            }
        }
    }
    Ok(root)
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value, ParseError> {
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        // Minimal escapes.
        let s = inner.replace("\\\"", "\"").replace("\\\\", "\\").replace("\\n", "\n");
        return Ok(Value::Str(s));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), lineno)?);
        }
        return Ok(Value::Array(items));
    }
    let clean = text.replace('_', "");
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, format!("cannot parse value {text:?}")))
}

/// Split on commas that are not inside strings or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

/// Serialize a root table back to TOML text (sections after scalars).
pub fn write(root: &BTreeMap<String, Value>) -> String {
    let mut out = String::new();
    for (k, v) in root {
        match v {
            Value::Table(_) | Value::TableArray(_) => {}
            _ => {
                out.push_str(&format!("{k} = {}\n", write_value(v)));
            }
        }
    }
    for (k, v) in root {
        match v {
            Value::Table(t) => {
                out.push_str(&format!("\n[{k}]\n"));
                for (kk, vv) in t {
                    out.push_str(&format!("{kk} = {}\n", write_value(vv)));
                }
            }
            Value::TableArray(ts) => {
                for t in ts {
                    out.push_str(&format!("\n[[{k}]]\n"));
                    for (kk, vv) in t {
                        out.push_str(&format!("{kk} = {}\n", write_value(vv)));
                    }
                }
            }
            _ => {}
        }
    }
    out
}

fn write_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::Array(items) => {
            let inner: Vec<String> = items.iter().map(write_value).collect();
            format!("[{}]", inner.join(", "))
        }
        Value::Table(_) | Value::TableArray(_) => unreachable!("nested tables not supported inline"),
    }
}

/// Reject keys of `tbl` that are not in `allowed`, naming the offending
/// key and the accepting context (e.g. `[market]`, `job spec`) plus the
/// full accepted set — so a typo'd config key fails loudly instead of
/// being silently ignored.
///
/// This is the shared validation helper every spec-table parser must call;
/// the `unknown-key` lint rule enforces its presence in each parser file.
pub fn reject_unknown_keys(
    tbl: &BTreeMap<String, Value>,
    allowed: &[&str],
    ctx: &str,
) -> anyhow::Result<()> {
    for key in tbl.keys() {
        anyhow::ensure!(
            allowed.contains(&key.as_str()),
            "unknown key `{key}` in {ctx} (accepted keys: {})",
            allowed.join(", ")
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = r#"
name = "cloudlab"  # a comment
alpha = 0.5
rounds = 10
spot = true

[server]
vm = "vm121"
"#;
        let root = parse(doc).unwrap();
        assert_eq!(root["name"].as_str(), Some("cloudlab"));
        assert_eq!(root["alpha"].as_float(), Some(0.5));
        assert_eq!(root["rounds"].as_int(), Some(10));
        assert_eq!(root["spot"].as_bool(), Some(true));
        assert_eq!(root["server"].as_table().unwrap()["vm"].as_str(), Some("vm121"));
    }

    #[test]
    fn parses_array_of_tables() {
        let doc = r#"
[[vm]]
id = "vm121"
price = 1.670

[[vm]]
id = "vm126"
price = 4.693
"#;
        let root = parse(doc).unwrap();
        let vms = root["vm"].as_table_array().unwrap();
        assert_eq!(vms.len(), 2);
        assert_eq!(vms[1]["id"].as_str(), Some("vm126"));
        assert_eq!(vms[1]["price"].as_float(), Some(4.693));
    }

    #[test]
    fn parses_inline_arrays() {
        let root = parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\n").unwrap();
        let xs = root["xs"].as_array().unwrap();
        assert_eq!(xs.iter().map(|v| v.as_int().unwrap()).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(root["ys"].as_array().unwrap()[1].as_str(), Some("b"));
    }

    #[test]
    fn int_coerces_to_float() {
        let root = parse("x = 3\n").unwrap();
        assert_eq!(root["x"].as_float(), Some(3.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let root = parse("s = \"a#b\"\n").unwrap();
        assert_eq!(root["s"].as_str(), Some("a#b"));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn missing_equals_rejected() {
        let e = parse("garbage line\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn round_trip() {
        let doc = r#"
alpha = 0.5
name = "x"

[server]
vm = "vm121"

[[client]]
id = 0

[[client]]
id = 1
"#;
        let root = parse(doc).unwrap();
        let text = write(&root);
        let back = parse(&text).unwrap();
        assert_eq!(root, back);
    }

    #[test]
    fn negative_and_underscore_numbers() {
        let root = parse("a = -4\nb = 1_000\nc = -0.5\n").unwrap();
        assert_eq!(root["a"].as_int(), Some(-4));
        assert_eq!(root["b"].as_int(), Some(1000));
        assert_eq!(root["c"].as_float(), Some(-0.5));
    }

    #[test]
    fn reject_unknown_keys_names_key_context_and_accepted_set() {
        let root = parse("app = \"til\"\noops = 1\n").unwrap();
        let err = reject_unknown_keys(&root, &["app", "rounds"], "job spec").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown key `oops`"), "{msg}");
        assert!(msg.contains("job spec"), "{msg}");
        assert!(msg.contains("app, rounds"), "{msg}");
        assert!(reject_unknown_keys(&root, &["app", "oops"], "job spec").is_ok());
    }
}
