//! Self-contained utility substrates: mini-TOML config parsing, JSON output,
//! a benchmarking harness, and property-testing helpers. (The build
//! environment is offline, so these replace `toml`, `serde_json`,
//! `criterion`, and `proptest`.)

pub mod bench;
pub mod json;
pub mod testkit;
pub mod tomlmini;

pub use json::Json;
