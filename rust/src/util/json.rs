//! A small JSON value builder/writer (no serde offline), used for trace and
//! experiment output (`--json` CLI flags, EXPERIMENTS.md raw data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics on non-objects (builder misuse).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        self.insert(key, value);
        self
    }

    /// In-place insert into an object; panics on non-objects (builder
    /// misuse). The by-reference sibling of [`Json::set`].
    pub fn insert(&mut self, key: &str, value: impl Into<Json>) {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::insert on non-object"),
        }
    }

    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Parse a JSON document (the reader side of this writer — accepts
    /// standard JSON; numbers become `Num(f64)`).
    pub fn parse(text: &str) -> anyhow::Result<Json> {
        let b = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        anyhow::ensure!(pos == b.len(), "trailing characters at byte {pos}");
        Ok(v)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
        let close_pad = if pretty { "  ".repeat(indent) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                out.push_str(nl);
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad);
                    item.write(out, indent + 1, pretty);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&close_pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                out.push_str(nl);
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&close_pad);
                out.push('}');
            }
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> anyhow::Result<()> {
    skip_ws(b, pos);
    anyhow::ensure!(
        *pos < b.len() && b[*pos] == c,
        "expected `{}` at byte {pos}",
        c as char
    );
    *pos += 1;
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    anyhow::ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    _ => anyhow::bail!("object key must be a string at byte {pos}"),
                };
                expect(b, pos, b':')?;
                m.insert(key, parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => anyhow::bail!("expected `,` or `}}` at byte {pos}"),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut xs = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            loop {
                xs.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(xs));
                    }
                    _ => anyhow::bail!("expected `,` or `]` at byte {pos}"),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            loop {
                anyhow::ensure!(*pos < b.len(), "unterminated string");
                match b[*pos] {
                    b'"' => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    b'\\' => {
                        *pos += 1;
                        anyhow::ensure!(*pos < b.len(), "unterminated escape");
                        match b[*pos] {
                            b'"' => s.push('"'),
                            b'\\' => s.push('\\'),
                            b'/' => s.push('/'),
                            b'n' => s.push('\n'),
                            b't' => s.push('\t'),
                            b'r' => s.push('\r'),
                            b'b' => s.push('\u{8}'),
                            b'f' => s.push('\u{c}'),
                            b'u' => {
                                anyhow::ensure!(*pos + 4 < b.len(), "truncated \\u escape");
                                let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                    .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
                                let cp = u32::from_str_radix(hex, 16)
                                    .map_err(|_| anyhow::anyhow!("bad \\u escape `{hex}`"))?;
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| anyhow::anyhow!("bad codepoint {cp}"))?,
                                );
                                *pos += 4;
                            }
                            c => anyhow::bail!("bad escape `\\{}`", c as char),
                        }
                        *pos += 1;
                    }
                    _ => {
                        // Consume one UTF-8 scalar (the input is a &str, so
                        // slicing at char boundaries is safe).
                        let start = *pos;
                        *pos += 1;
                        while *pos < b.len() && (b[*pos] & 0xC0) == 0x80 {
                            *pos += 1;
                        }
                        s.push_str(std::str::from_utf8(&b[start..*pos]).expect("utf8 input"));
                    }
                }
            }
        }
        b't' if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        b'f' if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        b'n' if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let word = std::str::from_utf8(&b[start..*pos]).expect("ascii number");
            word.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| anyhow::anyhow!("invalid JSON value `{word}` at byte {start}"))
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj()
            .set("name", "til")
            .set("rounds", 10i64)
            .set("cost", 15.44)
            .set("spot", true);
        assert_eq!(
            j.to_string_compact(),
            r#"{"cost":15.44,"name":"til","rounds":10,"spot":true}"#
        );
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::obj().set("xs", vec![1i64, 2, 3]).set("inner", Json::obj().set("a", 1i64));
        assert_eq!(j.to_string_compact(), r#"{"inner":{"a":1},"xs":[1,2,3]}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_compact(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(10.0).to_string_compact(), "10");
        assert_eq!(Json::Num(10.5).to_string_compact(), "10.5");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let j = Json::obj().set("a", vec![1i64, 2]).set("b", "x");
        let p = j.to_string_pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"a\": ["));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
        assert_eq!(Json::obj().to_string_compact(), "{}");
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let j = Json::obj()
            .set("name", "til \"x\"\n")
            .set("rounds", 10i64)
            .set("cost", 15.44)
            .set("spot", true)
            .set("none", Json::Null)
            .set("xs", vec![1i64, 2, 3])
            .set("inner", Json::obj().set("a", -2.5));
        for text in [j.to_string_compact(), j.to_string_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, j, "round trip failed for {text}");
        }
    }

    #[test]
    fn parse_accessors_and_errors() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}], "n": 1e3}"#).unwrap();
        assert_eq!(j.get("n").and_then(Json::as_f64), Some(1000.0));
        let arr = j.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[1].get("b").and_then(Json::as_str), Some("c"));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse("nope").is_err());
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
    }
}
