//! A small JSON value builder/writer (no serde offline), used for trace and
//! experiment output (`--json` CLI flags, EXPERIMENTS.md raw data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics on non-objects (builder misuse).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = if pretty { "  ".repeat(indent + 1) } else { String::new() };
        let close_pad = if pretty { "  ".repeat(indent) } else { String::new() };
        let nl = if pretty { "\n" } else { "" };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                out.push_str(nl);
                for (i, item) in items.iter().enumerate() {
                    out.push_str(&pad);
                    item.write(out, indent + 1, pretty);
                    if i + 1 < items.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&close_pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                out.push_str(nl);
                for (i, (k, v)) in map.iter().enumerate() {
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < map.len() {
                        out.push(',');
                    }
                    out.push_str(nl);
                }
                out.push_str(&close_pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compact_object() {
        let j = Json::obj()
            .set("name", "til")
            .set("rounds", 10i64)
            .set("cost", 15.44)
            .set("spot", true);
        assert_eq!(
            j.to_string_compact(),
            r#"{"cost":15.44,"name":"til","rounds":10,"spot":true}"#
        );
    }

    #[test]
    fn arrays_and_nesting() {
        let j = Json::obj().set("xs", vec![1i64, 2, 3]).set("inner", Json::obj().set("a", 1i64));
        assert_eq!(j.to_string_compact(), r#"{"inner":{"a":1},"xs":[1,2,3]}"#);
    }

    #[test]
    fn string_escaping() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string_compact(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(10.0).to_string_compact(), "10");
        assert_eq!(Json::Num(10.5).to_string_compact(), "10.5");
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn pretty_is_reparseable_shape() {
        let j = Json::obj().set("a", vec![1i64, 2]).set("b", "x");
        let p = j.to_string_pretty();
        assert!(p.contains("\n"));
        assert!(p.contains("\"a\": ["));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
        assert_eq!(Json::obj().to_string_compact(), "{}");
    }
}
