//! A small benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` binaries (`cargo bench` runs them with
//! `harness = false`). Provides warm-up, calibrated iteration counts, and
//! robust statistics, plus table-rendering helpers shared with the CLI's
//! `experiment` subcommand.

use std::time::{Duration, Instant};

/// Statistics over a set of per-iteration timings.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Append one machine-readable JSON line per finished bench to the file
/// named by the `MULTI_FEDLS_BENCH_JSON` env var (no-op when unset), so a
/// `cargo bench` run leaves a perf-trajectory artifact CI can archive.
/// Failures are swallowed: a perf log must never fail the bench run.
fn write_json_line(name: &str, stats: &Stats) {
    let Ok(path) = std::env::var("MULTI_FEDLS_BENCH_JSON") else {
        return;
    };
    if path.is_empty() {
        return;
    }
    let mut j = crate::util::Json::obj();
    j.insert("name", name);
    j.insert("iters", stats.iters as i64);
    j.insert("mean_ns", stats.mean.as_nanos() as i64);
    j.insert("median_ns", stats.median.as_nanos() as i64);
    j.insert("min_ns", stats.min.as_nanos() as i64);
    j.insert("max_ns", stats.max.as_nanos() as i64);
    j.insert("stddev_ns", stats.stddev.as_nanos() as i64);
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = writeln!(f, "{}", j.to_string_compact());
    }
}

/// Time `f` repeatedly: a warm-up pass, then enough iterations to cover
/// ~`budget` of wall time (at least `min_iters`). Returns statistics.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, min_iters: usize, mut f: F) -> Stats {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed();
    let target = budget.as_secs_f64();
    let per = first.as_secs_f64().max(1e-9);
    let iters = ((target / per) as usize).clamp(min_iters, 100_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    let stats = Stats::from_samples(samples);
    println!(
        "bench {name:<40} iters={:<6} mean={:<12} median={:<12} min={:<12} max={:<12} stddev={}",
        stats.iters,
        fmt_duration(stats.mean),
        fmt_duration(stats.median),
        fmt_duration(stats.min),
        fmt_duration(stats.max),
        fmt_duration(stats.stddev),
    );
    write_json_line(name, &stats);
    stats
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render an aligned ASCII table (used to print the paper's tables).
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let samples = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_samples(samples);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.median, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
    }

    #[test]
    fn bench_runs_at_least_min_iters() {
        let mut count = 0usize;
        let stats = bench("noop", Duration::from_millis(1), 5, || {
            count += 1;
        });
        assert!(stats.iters >= 5);
        // warm-up + measured iterations
        assert_eq!(count, stats.iters + 1);
    }

    #[test]
    fn bench_json_writer_appends_parseable_lines() {
        let path =
            std::env::temp_dir().join(format!("multi-fedls-bench-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        std::env::set_var("MULTI_FEDLS_BENCH_JSON", &path);
        bench("json-probe", Duration::from_millis(1), 3, || {});
        std::env::remove_var("MULTI_FEDLS_BENCH_JSON");
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        // Another test's bench may interleave (the env var is process-wide);
        // only our probe line matters.
        let line = text.lines().find(|l| l.contains("\"json-probe\"")).expect("probe line");
        let j = crate::util::Json::parse(line).unwrap();
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("json-probe"));
        assert!(j.get("iters").and_then(|v| v.as_f64()).unwrap() >= 3.0);
        for key in ["mean_ns", "median_ns", "min_ns", "max_ns", "stddev_ns"] {
            assert!(j.get(key).and_then(|v| v.as_f64()).is_some(), "{key} missing");
        }
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["VM", "Slowdown"]);
        t.row(&["vm126".into(), "0.045".into()]);
        t.row(&["vm212".into(), "2.328".into()]);
        let s = t.render();
        assert!(s.contains("| vm126 | 0.045    |"));
        assert!(s.contains("== Demo =="));
        // All lines of the body share the same width.
        let widths: std::collections::HashSet<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert_eq!(widths.len(), 1, "{s}");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.000 µs");
        assert_eq!(fmt_duration(Duration::from_nanos(120)), "120.0 ns");
    }
}
