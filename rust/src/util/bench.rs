//! A small benchmarking harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` binaries (`cargo bench` runs them with
//! `harness = false`). Provides warm-up, calibrated iteration counts, and
//! robust statistics, plus table-rendering helpers shared with the CLI's
//! `experiment` subcommand.

use std::time::{Duration, Instant};

/// Statistics over a set of per-iteration timings.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    pub stddev: Duration,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<Duration>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        let mean = total / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_secs_f64() - mean_s;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        Stats {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            stddev: Duration::from_secs_f64(var.sqrt()),
        }
    }
}

fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` repeatedly: a warm-up pass, then enough iterations to cover
/// ~`budget` of wall time (at least `min_iters`). Returns statistics.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, min_iters: usize, mut f: F) -> Stats {
    // Warm-up + calibration.
    let t0 = Instant::now();
    f();
    let first = t0.elapsed();
    let target = budget.as_secs_f64();
    let per = first.as_secs_f64().max(1e-9);
    let iters = ((target / per) as usize).clamp(min_iters, 100_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    let stats = Stats::from_samples(samples);
    println!(
        "bench {name:<40} iters={:<6} mean={:<12} median={:<12} min={:<12} max={:<12} stddev={}",
        stats.iters,
        fmt_duration(stats.mean),
        fmt_duration(stats.median),
        fmt_duration(stats.min),
        fmt_duration(stats.max),
        fmt_duration(stats.stddev),
    );
    stats
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Render an aligned ASCII table (used to print the paper's tables).
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let samples = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let s = Stats::from_samples(samples);
        assert_eq!(s.mean, Duration::from_millis(20));
        assert_eq!(s.median, Duration::from_millis(20));
        assert_eq!(s.min, Duration::from_millis(10));
        assert_eq!(s.max, Duration::from_millis(30));
    }

    #[test]
    fn bench_runs_at_least_min_iters() {
        let mut count = 0usize;
        let stats = bench("noop", Duration::from_millis(1), 5, || {
            count += 1;
        });
        assert!(stats.iters >= 5);
        // warm-up + measured iterations
        assert_eq!(count, stats.iters + 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["VM", "Slowdown"]);
        t.row(&["vm126".into(), "0.045".into()]);
        t.row(&["vm212".into(), "2.328".into()]);
        let s = t.render();
        assert!(s.contains("| vm126 | 0.045    |"));
        assert!(s.contains("== Demo =="));
        // All lines of the body share the same width.
        let widths: std::collections::HashSet<usize> =
            s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert_eq!(widths.len(), 1, "{s}");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.000 µs");
        assert_eq!(fmt_duration(Duration::from_nanos(120)), "120.0 ns");
    }
}
