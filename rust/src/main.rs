//! Multi-FedLS command-line interface (the leader entrypoint).
//!
//! ```text
//! multi-fedls catalog [cloudlab|aws-gcp]       print the environment catalog
//! multi-fedls preschedule [--env E] [--cache F] run Pre-Scheduling, print slowdowns
//! multi-fedls map --app A [--alpha X] [...]    run the Initial Mapping solver
//! multi-fedls simulate --spec FILE [--json]    simulate a job spec (TOML)
//!                   [--trace-out F] [--flame-out F]
//! multi-fedls sweep --spec FILE [--jobs N]     run a campaign grid in parallel
//!                   [--results DIR] [--resume] [--no-persist]
//! multi-fedls workload --spec FILE [--jobs N]  run a multi-job workload campaign
//!                   [--results DIR] [--resume] [--no-persist]
//!                   [--trace-out F] [--flame-out F]
//! multi-fedls report <dir|trace.jsonl>         summarize a telemetry trace
//! multi-fedls report --diff A B                compare two traces/campaigns
//! multi-fedls explain <trace.jsonl> [...]      why each scheduling decision
//! multi-fedls run --app A [--rounds N] [...]   real-compute FL run (needs artifacts)
//! multi-fedls experiment <name> [--json]       regenerate a paper table/figure
//! multi-fedls lint [--json] [--src DIR]        determinism & invariant lint pass
//! ```

use std::collections::HashMap;

use multi_fedls::cloud::{tables, Market};
use multi_fedls::cloudsim::{MultiCloud, RevocationModel};
use multi_fedls::coordinator::real::{run as real_run, RealRunConfig};
use multi_fedls::coordinator::JobSpec;
use multi_fedls::mapping::problem::MappingProblem;
use multi_fedls::presched::PreScheduler;
use multi_fedls::simul::SimTime;
use multi_fedls::trace;

/// Minimal argv parser: positional args + `--key value` / `--flag` options.
struct Args {
    positional: Vec<String>,
    options: HashMap<String, String>,
}

/// A token only counts as an option if it is not a (possibly negative or
/// exponent-form) number, so `--alpha -0.5` parses as a value, not a flag.
fn is_option_token(tok: &str) -> bool {
    tok.starts_with('-') && tok != "-" && tok.parse::<f64>().is_err()
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut options = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if i + 1 < argv.len() && !is_option_token(&argv[i + 1]) {
                    options.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    options.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, options }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.get(key) == Some("true")
    }
}

const USAGE: &str = "\
Multi-FedLS — Cross-Silo Federated Learning on multi-cloud environments

USAGE:
  multi-fedls catalog [cloudlab|aws-gcp]
  multi-fedls preschedule [--env cloudlab|aws-gcp] [--cache FILE]
  multi-fedls map --app <til|shakespeare|femnist|til-aws-gcp> [--alpha A]
                  [--market on-demand|spot] [--budget B] [--deadline T]
                  [--mapper exact|milp|cheapest|fastest|random|single-cloud]
  multi-fedls simulate --spec configs/<job>.toml [--json]
                    [--trace-out FILE] [--flame-out FILE]
  multi-fedls sweep --spec configs/<grid>.toml [--jobs N] [--json|--csv]
                    [--results DIR] [--resume] [--no-persist]
  multi-fedls workload --spec configs/workload-<name>.toml [--jobs N] [--json|--csv]
                    [--results DIR] [--resume] [--no-persist]
                    [--trace-out FILE] [--flame-out FILE]
  multi-fedls report <results-dir | trace.jsonl>
  multi-fedls report --diff <A> <B>
  multi-fedls explain <trace.jsonl | results-dir> [--job JOB] [--decision N] [--vm TYPE]
  multi-fedls run --app <name> [--rounds N] [--epochs E] [--scale S]
                  [--artifacts DIR] [--ckpt-every X] [--ckpt-dir DIR]
  multi-fedls experiment <table3|table4|validation|fig2|table5..8|poc|mapping|alpha-sweep|multijob|dynsched-ablation|mapper-ablation|preempt-ablation|market-sensitivity|outlook-ablation|all> [--json]
  multi-fedls lint [--json] [--src DIR]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let result = match cmd.as_str() {
        "catalog" => cmd_catalog(&args),
        "preschedule" => cmd_preschedule(&args),
        "map" => cmd_map(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "workload" => cmd_workload(&args),
        "report" => cmd_report(&args),
        "explain" => cmd_explain(&args),
        "run" => cmd_run(&args),
        "experiment" => cmd_experiment(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown command {other}\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn env_by_name(name: &str) -> anyhow::Result<MultiCloud> {
    match name {
        "cloudlab" => Ok(MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::none(),
            1,
        )),
        "aws-gcp" => Ok(MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            1,
        )),
        other => anyhow::bail!("unknown environment {other} (cloudlab | aws-gcp)"),
    }
}

/// `multi-fedls lint` — run the determinism & invariant pass over the
/// crate's `src/` (auto-discovered from the cwd, or `--src DIR`).
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let src_root = match args.get("src") {
        Some(dir) => std::path::PathBuf::from(dir),
        None => ["src", "rust/src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.join("lib.rs").is_file())
            .ok_or_else(|| {
                anyhow::anyhow!("cannot find the crate's src/; run from the repo or rust/ root, or pass --src DIR")
            })?,
    };
    let report = multi_fedls::lint::lint_tree(&src_root)?;
    if args.flag("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        for v in &report.violations {
            println!("{v}");
        }
        println!(
            "{} file(s) scanned, {} rule(s), {} violation(s)",
            report.files_scanned,
            multi_fedls::lint::RULES.len(),
            report.violations.len()
        );
    }
    anyhow::ensure!(report.is_clean(), "{} lint violation(s)", report.violations.len());
    Ok(())
}

fn cmd_catalog(args: &Args) -> anyhow::Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("cloudlab");
    trace::catalog_table(which).print();
    Ok(())
}

fn cmd_preschedule(args: &Args) -> anyhow::Result<()> {
    let env = args.get("env").unwrap_or("cloudlab");
    let mc = env_by_name(env)?;
    // Cache: skip measurement when the fingerprint matches (§4.1).
    if let Some(cache) = args.get("cache") {
        let path = std::path::Path::new(cache);
        if let Some(report) = multi_fedls::presched::cache::load(&mc.catalog, path)? {
            println!("pre-scheduling cache hit ({}), skipping dummy runs", report.fingerprint);
            return Ok(());
        }
        let report = PreScheduler::new(&mc).measure_defaults();
        multi_fedls::presched::cache::save(&report, &mc.catalog, path)?;
        println!("pre-scheduling measured and cached to {cache}");
    }
    if env == "cloudlab" {
        let (t3, _) = trace::table3();
        let (t4, _) = trace::table4();
        t3.print();
        t4.print();
    } else {
        println!("slowdowns measured for {env} ({} VM types)", mc.catalog.vm_types.len());
    }
    Ok(())
}

fn cmd_map(args: &Args) -> anyhow::Result<()> {
    let app_name = args.get("app").ok_or_else(|| anyhow::anyhow!("--app required"))?;
    let app = multi_fedls::apps::by_name(app_name)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app_name}"))?;
    let (catalog, gt) = multi_fedls::coordinator::sim::environment_for(&app);
    let mc = MultiCloud::new(catalog, gt, RevocationModel::none(), 1);
    let sl = PreScheduler::new(&mc).measure_defaults();
    let job = app.profile();
    let alpha: f64 = args.get("alpha").map(|s| s.parse()).transpose()?.unwrap_or(0.5);
    let market = match args.get("market").unwrap_or("on-demand") {
        "spot" => Market::Spot,
        _ => Market::OnDemand,
    };
    let p = MappingProblem {
        catalog: &mc.catalog,
        slowdowns: &sl,
        job: &job,
        alpha,
        market,
        spot_price_factor: 1.0,
        budget_round: args.get("budget").map(|s| s.parse()).transpose()?.unwrap_or(f64::INFINITY),
        deadline_round: args
            .get("deadline")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(f64::INFINITY),
        outlook: None,
    };
    let mapper_kind = match args.get("mapper") {
        Some(k) => multi_fedls::mapping::MapperKind::from_key(k)
            .ok_or_else(|| anyhow::anyhow!("unknown mapper {k}"))?,
        None => multi_fedls::mapping::MapperKind::Exact,
    };
    let mapper = multi_fedls::framework::modules::mapper_for(mapper_kind);
    match mapper.map(&p) {
        Some(sol) => {
            println!(
                "Initial Mapping for {app_name} (alpha={alpha}, {market}, {} mapper):",
                mapper.name()
            );
            println!("  server : {}", mc.catalog.vm(sol.mapping.server).id);
            for (i, &c) in sol.mapping.clients.iter().enumerate() {
                println!("  client{i}: {}", mc.catalog.vm(c).id);
            }
            println!(
                "  per-round makespan {:.1}s, cost ${:.4}, objective {:.5}",
                sol.eval.makespan, sol.eval.total_cost, sol.eval.objective
            );
            println!(
                "  whole job ({} rounds): {} / ${:.2}",
                job.n_rounds,
                SimTime::from_secs(sol.eval.makespan * job.n_rounds as f64).hms(),
                sol.eval.total_cost * job.n_rounds as f64
            );
        }
        None => println!("no feasible mapping under the given budget/deadline"),
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let spec_path = args.get("spec").ok_or_else(|| anyhow::anyhow!("--spec required"))?;
    let spec = JobSpec::from_file(std::path::Path::new(spec_path))?;
    // Telemetry sinks: one extra instrumented run of the base config (the
    // aggregate trials below stay untouched — telemetry never perturbs
    // numerics, this just avoids re-plumbing per-trial outcomes).
    if args.get("trace-out").is_some() || args.get("flame-out").is_some() {
        let mut cfg = spec.config.clone();
        cfg.telemetry.enabled = true;
        let out = multi_fedls::coordinator::simulate(&cfg)?;
        if let Some(path) = args.get("trace-out") {
            let trace: Vec<multi_fedls::telemetry::TraceEvent> = out
                .events
                .iter()
                .map(|e| multi_fedls::telemetry::TraceEvent {
                    at: e.at.secs(),
                    job: Some(cfg.app.name.to_string()),
                    tenant: None,
                    kind: e.kind.clone(),
                })
                .collect();
            let mut text = multi_fedls::telemetry::trace_jsonl(0, 0, &trace);
            // Decision provenance + billed VM lifetimes ride in the same
            // stream (`explain` reads all three line kinds).
            if let Some(tel) = out.telemetry.as_ref() {
                for d in &tel.decisions {
                    let mut d = d.clone();
                    if d.job.is_none() {
                        d.job = Some(cfg.app.name.to_string());
                    }
                    let mut j = d.to_json();
                    j.insert("point", 0i64);
                    j.insert("trial", 0i64);
                    text.push_str(&j.to_string_compact());
                    text.push('\n');
                }
                for v in &tel.vms {
                    let span = multi_fedls::telemetry::VmSpanRecord {
                        job: Some(cfg.app.name.to_string()),
                        tenant: None,
                        vm: v.vm.clone(),
                        instance: v.instance,
                        provider: v.provider.clone(),
                        region: v.region.clone(),
                        spot: v.spot,
                        start: v.start,
                        end: v.end,
                        billed_cost: v.billed_cost,
                    };
                    let mut j = span.to_json();
                    j.insert("point", 0i64);
                    j.insert("trial", 0i64);
                    text.push_str(&j.to_string_compact());
                    text.push('\n');
                }
            }
            std::fs::write(path, &text)
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            eprintln!("trace written to {path} ({} lines)", text.lines().count());
        }
        if let Some(path) = args.get("flame-out") {
            let tel = out.telemetry.as_ref().expect("telemetry enabled");
            let folded = multi_fedls::telemetry::flamegraph_folded(tel);
            std::fs::write(path, &folded)
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            eprintln!("collapsed stacks written to {path} ({} spans)", folded.lines().count());
        }
    }
    let stats = multi_fedls::coordinator::run_trials(&spec.config, spec.trials, spec.config.seed)?;
    if args.flag("json") {
        let j = multi_fedls::util::Json::obj()
            .set("app", spec.config.app.name)
            .set("trials", spec.trials)
            .set("avg_revocations", stats.revocations.mean)
            .set("avg_fl_exec_secs", stats.exec_secs.mean)
            .set("avg_total_secs", stats.total_secs.mean)
            .set("avg_cost", stats.cost.mean)
            .set("cost_stddev", stats.cost.stddev)
            .set("cost_ci95", stats.cost.ci95);
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "{} × {} trials: avg revocations {:.2}, FL exec {}, total {}, cost ${:.2} ±{:.2}",
            spec.config.app.name,
            spec.trials,
            stats.revocations.mean,
            stats.fl_hms(),
            stats.exec_hms(),
            stats.cost.mean,
            stats.cost.ci95
        );
    }
    Ok(())
}

/// `multi-fedls sweep --spec FILE [--jobs N] [--json|--csv] [--results DIR]
/// [--resume] [--no-persist]`: expand a declarative campaign grid and run
/// it across the worker pool. Output is byte-identical for any `--jobs`
/// value. By default results are persisted under `--results` (default
/// `results/`) keyed by the spec fingerprint; `--resume` skips grid points
/// already recorded there.
fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    let spec_path = args.get("spec").ok_or_else(|| anyhow::anyhow!("--spec required"))?;
    let spec = multi_fedls::sweep::SweepSpec::from_file(std::path::Path::new(spec_path))?;
    let jobs = match args.get("jobs") {
        Some(j) => j.parse::<usize>().map_err(|e| anyhow::anyhow!("--jobs {j}: {e}"))?,
        None => spec.jobs.unwrap_or(0), // 0 = one worker per core
    };
    let points = spec.expand()?;
    let n_trials: usize = points.iter().map(|p| p.seeds.len()).sum();
    eprintln!(
        "sweep {}: {} points × {} trials = {} runs on {} workers",
        spec.name,
        points.len(),
        spec.trials,
        n_trials,
        multi_fedls::sweep::effective_jobs(jobs, n_trials)
    );
    let resume = args.flag("resume");
    anyhow::ensure!(
        !(resume && args.flag("no-persist")),
        "--resume reads and writes the results directory; drop --no-persist"
    );
    let persist = resume || !args.flag("no-persist");
    let stats = if persist {
        let results_dir = std::path::Path::new(args.get("results").unwrap_or("results"));
        let (stats, dir) = multi_fedls::sweep::persist::run_campaign_persistent(
            &spec,
            &points,
            jobs,
            results_dir,
            resume,
        )?;
        eprintln!("campaign recorded in {}", dir.display());
        stats
    } else {
        multi_fedls::sweep::run_campaign(&points, jobs)?
    };
    if args.flag("json") {
        let j = multi_fedls::sweep::spec::render_json(&spec, &points, &stats);
        println!("{}", j.to_string_pretty());
    } else if args.flag("csv") {
        print!("{}", multi_fedls::sweep::spec::render_csv(&points, &stats));
    } else {
        multi_fedls::sweep::spec::render_table(&spec, &points, &stats).print();
    }
    Ok(())
}

/// `multi-fedls workload --spec FILE [--jobs N] [--json|--csv]
/// [--results DIR] [--resume] [--no-persist] [--trace-out FILE]
/// [--flame-out FILE]`: expand a multi-job workload campaign (arrival
/// processes × admission policies × budget/deadline axes) and run each
/// point's trials across the worker pool. Output — including the
/// `--trace-out` telemetry JSONL and `--flame-out` collapsed stacks — is
/// byte-identical for any `--jobs` value. Either sink force-enables
/// `[telemetry]` on every job and runs in-memory (no results directory).
fn cmd_workload(args: &Args) -> anyhow::Result<()> {
    let spec_path = args.get("spec").ok_or_else(|| anyhow::anyhow!("--spec required"))?;
    let spec = multi_fedls::workload::WorkloadSpec::from_file(std::path::Path::new(spec_path))?;
    let jobs = match args.get("jobs") {
        Some(j) => j.parse::<usize>().map_err(|e| anyhow::anyhow!("--jobs {j}: {e}"))?,
        None => spec.workers.unwrap_or(0), // 0 = one worker per core
    };
    let mut points = spec.expand()?;
    let trace_out = args.get("trace-out");
    let flame_out = args.get("flame-out");
    if trace_out.is_some() || flame_out.is_some() {
        // Force telemetry on uniformly so the trace covers every job (and
        // the fingerprint-relevant configs stay consistent across runs).
        for p in &mut points {
            for w in &mut p.trials {
                for j in &mut w.jobs {
                    j.cfg.telemetry.enabled = true;
                }
            }
        }
    }
    eprintln!(
        "workload {}: {} jobs × {} points × {} trials on {} workers",
        spec.name,
        spec.jobs.len(),
        points.len(),
        spec.trials,
        // The pool flattens every point's trials together, so parallelism
        // spans points (matching run_points / the persistent runner).
        multi_fedls::sweep::effective_jobs(jobs, points.len() * spec.trials.max(1))
    );
    let resume = args.flag("resume");
    anyhow::ensure!(
        !(resume && args.flag("no-persist")),
        "--resume reads and writes the results directory; drop --no-persist"
    );
    anyhow::ensure!(
        !(resume && (trace_out.is_some() || flame_out.is_some())),
        "--trace-out/--flame-out run in-memory; drop --resume"
    );
    let persist = trace_out.is_none()
        && flame_out.is_none()
        && (resume || !args.flag("no-persist"));
    let aggs = if trace_out.is_some() || flame_out.is_some() {
        let (aggs, traces, flames) =
            multi_fedls::workload::spec::run_points_traced_full(&points, jobs)?;
        if let Some(path) = trace_out {
            let text: String = traces.concat();
            std::fs::write(path, &text).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            eprintln!("telemetry trace written to {path} ({} lines)", text.lines().count());
        }
        if let Some(path) = flame_out {
            let text: String = flames.concat();
            std::fs::write(path, &text).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            eprintln!("collapsed stacks written to {path} ({} frames)", text.lines().count());
        }
        aggs
    } else if persist {
        let results_dir = std::path::Path::new(args.get("results").unwrap_or("results"));
        let (aggs, dir) = multi_fedls::sweep::persist::run_workload_campaign_persistent(
            &spec,
            &points,
            jobs,
            results_dir,
            resume,
        )?;
        eprintln!("campaign recorded in {}", dir.display());
        aggs
    } else {
        multi_fedls::workload::spec::run_points(&points, jobs)?
    };
    if args.flag("json") {
        let j = multi_fedls::workload::spec::render_json(&spec, &points, &aggs);
        println!("{}", j.to_string_pretty());
    } else if args.flag("csv") {
        print!("{}", multi_fedls::workload::spec::render_csv(&points, &aggs));
    } else {
        multi_fedls::workload::spec::render_table(&spec, &points, &aggs).print();
    }
    Ok(())
}

/// Discover the trace files a report/explain target names: every `.jsonl`
/// under a results directory (the `trace-NNNN.jsonl` files a persisted
/// workload campaign writes), or the one file given. Errors when the
/// directory holds no traces (metadata-only campaign dirs included).
fn trace_files(path: &std::path::Path) -> anyhow::Result<Vec<std::path::PathBuf>> {
    let files: Vec<std::path::PathBuf> = if path.is_dir() {
        let mut fs: Vec<std::path::PathBuf> = std::fs::read_dir(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.ends_with(".jsonl"))
            })
            .collect();
        fs.sort();
        fs
    } else {
        vec![path.to_path_buf()]
    };
    anyhow::ensure!(
        !files.is_empty(),
        "no .jsonl trace files under {} (run a workload with --trace-out, or point at a \
         persisted campaign directory)",
        path.display()
    );
    Ok(files)
}

/// One `job-complete` trace line's scalar fields (report/diff rows).
struct JobDone {
    job: String,
    tenant: String,
    point: i64,
    trial: i64,
    rounds: i64,
    revocations: i64,
    preemptions: i64,
    wait_secs: f64,
    fl_secs: f64,
    cost: f64,
}

impl JobDone {
    /// Stable identity for cross-trace matching (`--diff`).
    fn key(&self) -> String {
        format!("{}@{}/{}", self.job, self.point, self.trial)
    }
}

/// Everything `report` aggregates from one trace target: per-kind line
/// counts plus every completed job's scalars, in trace order.
struct TraceSummary {
    n_files: usize,
    n_lines: usize,
    by_kind: std::collections::BTreeMap<String, u64>,
    jobs: Vec<JobDone>,
}

fn load_trace_summary(path: &std::path::Path) -> anyhow::Result<TraceSummary> {
    use multi_fedls::util::Json;
    let files = trace_files(path)?;
    let mut n_lines = 0usize;
    let mut by_kind: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
    let mut jobs = Vec::new();
    for f in &files {
        let text = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", f.display()))?;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{}: bad trace line: {e}", f.display()))?;
            n_lines += 1;
            let kind = j.get("kind").and_then(|k| k.as_str()).unwrap_or("?").to_string();
            *by_kind.entry(kind.clone()).or_insert(0) += 1;
            if kind == "job-complete" {
                let s = |key: &str| {
                    j.get(key).and_then(|v| v.as_str()).unwrap_or("").to_string()
                };
                let n = |key: &str| j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                jobs.push(JobDone {
                    job: s("job"),
                    tenant: s("tenant"),
                    point: n("point") as i64,
                    trial: n("trial") as i64,
                    rounds: n("rounds") as i64,
                    revocations: n("revocations") as i64,
                    preemptions: n("preemptions") as i64,
                    wait_secs: n("wait_secs"),
                    fl_secs: n("fl_secs"),
                    cost: n("cost"),
                });
            }
        }
    }
    anyhow::ensure!(
        n_lines > 0,
        "{}: trace file(s) are empty — expected telemetry JSONL lines; re-run the \
         campaign with [telemetry] enabled, or use --trace-out",
        path.display()
    );
    Ok(TraceSummary { n_files: files.len(), n_lines, by_kind, jobs })
}

/// `multi-fedls report <results-dir | trace.jsonl>`: summarize a telemetry
/// trace. Renders a per-completed-job table plus event-kind counts.
/// `--diff A B` compares two traces/campaign directories instead.
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    use multi_fedls::util::bench::Table;
    if let Some(a) = args.get("diff") {
        let b = args.positional.first().ok_or_else(|| {
            anyhow::anyhow!("report --diff needs two traces or campaign dirs: --diff A B\n{USAGE}")
        })?;
        return report_diff(std::path::Path::new(a), std::path::Path::new(b));
    }
    let target = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("report needs a results directory or a .jsonl trace file\n{USAGE}")
    })?;
    let sum = load_trace_summary(std::path::Path::new(target))?;
    let mut jobs_table = Table::new(
        "Telemetry report — completed jobs",
        &["Job", "Tenant", "Pt/Trial", "Rounds", "Revoc", "Preempt", "Wait", "FL time", "Cost ($)"],
    );
    let mut total_cost = 0.0f64;
    for d in &sum.jobs {
        total_cost += d.cost;
        jobs_table.row(&[
            d.job.clone(),
            d.tenant.clone(),
            format!("{}/{}", d.point, d.trial),
            d.rounds.to_string(),
            d.revocations.to_string(),
            d.preemptions.to_string(),
            SimTime::from_secs(d.wait_secs).hms(),
            SimTime::from_secs(d.fl_secs).hms(),
            format!("{:.2}", d.cost),
        ]);
    }
    if !sum.jobs.is_empty() {
        jobs_table.print();
        println!();
    }
    let mut kinds = Table::new(
        format!("Event kinds ({} events, {} file(s))", sum.n_lines, sum.n_files),
        &["Kind", "Count"],
    );
    for (k, c) in &sum.by_kind {
        kinds.row(&[k.clone(), c.to_string()]);
    }
    kinds.print();
    println!("{} completed job(s), total cost ${total_cost:.2}", sum.jobs.len());
    Ok(())
}

/// `multi-fedls report --diff A B`: regression-triage comparison of two
/// traces or campaign directories — event-kind count deltas, per-job
/// FL-time/wait/cost deltas for jobs present in both, and jobs that are
/// new in B or disappeared from it.
fn report_diff(a: &std::path::Path, b: &std::path::Path) -> anyhow::Result<()> {
    use multi_fedls::util::bench::Table;
    let sa = load_trace_summary(a)?;
    let sb = load_trace_summary(b)?;
    let mut kind_names: std::collections::BTreeSet<String> = sa.by_kind.keys().cloned().collect();
    kind_names.extend(sb.by_kind.keys().cloned());
    let mut kinds = Table::new(
        format!("Event-kind deltas — A={} B={}", a.display(), b.display()),
        &["Kind", "A", "B", "B-A"],
    );
    let mut changed = 0usize;
    for k in &kind_names {
        let ca = *sa.by_kind.get(k).unwrap_or(&0) as i64;
        let cb = *sb.by_kind.get(k).unwrap_or(&0) as i64;
        if ca != cb {
            changed += 1;
        }
        kinds.row(&[k.clone(), ca.to_string(), cb.to_string(), format!("{:+}", cb - ca)]);
    }
    kinds.print();
    println!();
    let map_a: std::collections::BTreeMap<String, &JobDone> =
        sa.jobs.iter().map(|d| (d.key(), d)).collect();
    let map_b: std::collections::BTreeMap<String, &JobDone> =
        sb.jobs.iter().map(|d| (d.key(), d)).collect();
    let mut jobs = Table::new(
        "Per-job deltas (B - A)",
        &["Job@Pt/Trial", "FL secs", "Wait secs", "Cost ($)", "Revoc", "Preempt"],
    );
    let mut common = 0usize;
    for (k, da) in &map_a {
        if let Some(db) = map_b.get(k) {
            common += 1;
            jobs.row(&[
                k.clone(),
                format!("{:+.1}", db.fl_secs - da.fl_secs),
                format!("{:+.1}", db.wait_secs - da.wait_secs),
                format!("{:+.4}", db.cost - da.cost),
                format!("{:+}", db.revocations - da.revocations),
                format!("{:+}", db.preemptions - da.preemptions),
            ]);
        }
    }
    if common > 0 {
        jobs.print();
    }
    let gone: Vec<String> = map_a.keys().filter(|k| !map_b.contains_key(*k)).cloned().collect();
    let newly: Vec<String> = map_b.keys().filter(|k| !map_a.contains_key(*k)).cloned().collect();
    if !gone.is_empty() {
        println!("disappeared in B: {}", gone.join(", "));
    }
    if !newly.is_empty() {
        println!("new in B: {}", newly.join(", "));
    }
    println!(
        "{changed} kind(s) changed, {common} common job(s), {} new, {} disappeared",
        newly.len(),
        gone.len()
    );
    Ok(())
}

/// `multi-fedls explain <trace.jsonl> [--job J] [--decision N] [--vm TYPE]`:
/// answer *why* the scheduler decided what it did, from the decision
/// provenance a `--trace-out` trace carries. The default lists every
/// decision one line each; `--decision N` expands one record with its
/// ranked candidate table and the events it caused; `--job J` scopes any
/// query to one job; `--vm TYPE` shows every decision that chose or
/// considered a VM type plus its total billed downstream cost.
fn cmd_explain(args: &Args) -> anyhow::Result<()> {
    use multi_fedls::telemetry::{DecisionRecord, VmSpanRecord};
    use multi_fedls::util::Json;
    let target = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("explain needs a .jsonl trace file or a results directory\n{USAGE}")
    })?;
    let path = std::path::Path::new(target);
    let files = trace_files(path)?;
    // (point, trial) envelope keys ride alongside every parsed line so
    // decision IDs — unique only within one trial — resolve correctly.
    let pt_of = |j: &Json| -> (i64, i64) {
        (
            j.get("point").and_then(|v| v.as_f64()).unwrap_or(0.0) as i64,
            j.get("trial").and_then(|v| v.as_f64()).unwrap_or(0.0) as i64,
        )
    };
    let mut decisions: Vec<(i64, i64, DecisionRecord)> = Vec::new();
    let mut spans: Vec<(i64, i64, VmSpanRecord)> = Vec::new();
    let mut events: Vec<Json> = Vec::new();
    let mut n_lines = 0usize;
    for f in &files {
        let text = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", f.display()))?;
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{}: bad trace line: {e}", f.display()))?;
            n_lines += 1;
            match j.get("kind").and_then(|k| k.as_str()) {
                Some("decision") => {
                    let (pt, tr) = pt_of(&j);
                    if let Some(d) = DecisionRecord::from_json(&j) {
                        decisions.push((pt, tr, d));
                    }
                }
                Some("vm-span") => {
                    let (pt, tr) = pt_of(&j);
                    if let Some(v) = VmSpanRecord::from_json(&j) {
                        spans.push((pt, tr, v));
                    }
                }
                _ => events.push(j),
            }
        }
    }
    anyhow::ensure!(
        !decisions.is_empty(),
        "{}: no decision provenance in {n_lines} trace line(s) — record it by re-running \
         with [telemetry] enabled and `decisions = true` (the default)",
        path.display()
    );
    let job_filter = args.get("job");
    let keep = |d: &DecisionRecord| job_filter.map_or(true, |j| d.job.as_deref() == Some(j));
    // Prefix rows with the (point, trial) envelope only when it varies.
    let multi = decisions.iter().any(|&(pt, tr, _)| (pt, tr) != (0, 0));
    let tag = |pt: i64, tr: i64| if multi { format!("[{pt}/{tr}] ") } else { String::new() };

    if let Some(n) = args.get("decision") {
        let id: u64 = n.parse().map_err(|e| anyhow::anyhow!("--decision {n}: {e}"))?;
        let hits: Vec<&(i64, i64, DecisionRecord)> =
            decisions.iter().filter(|(_, _, d)| d.id == id && keep(d)).collect();
        anyhow::ensure!(
            !hits.is_empty(),
            "no decision #{id} in the trace (run `explain` without --decision to list IDs)"
        );
        for (pt, tr, d) in hits {
            print!("{}{}", tag(*pt, *tr), d.render_full());
            let caused = events.iter().filter(|e| {
                e.get("decision").and_then(|v| v.as_f64()) == Some(id as f64)
                    && pt_of(e) == (*pt, *tr)
            });
            for e in caused {
                println!("  -> {}", e.to_string_compact());
            }
        }
        return Ok(());
    }

    if let Some(vm) = args.get("vm") {
        // Candidate labels read "provider/region vmid"; substring match
        // accepts either the bare type or the full label.
        let mut shown = 0usize;
        for (pt, tr, d) in &decisions {
            if !keep(d) {
                continue;
            }
            let chose = d.chosen.as_deref().is_some_and(|c| c.contains(vm));
            let considered = d.candidates.iter().any(|c| c.label.contains(vm));
            if !chose && !considered {
                continue;
            }
            shown += 1;
            let role = if chose { "" } else { "  (considered, not chosen)" };
            println!("{}{}{role}", tag(*pt, *tr), d.render());
        }
        let billed: Vec<&(i64, i64, VmSpanRecord)> = spans
            .iter()
            .filter(|(_, _, v)| {
                v.vm.contains(vm) && job_filter.map_or(true, |j| v.job.as_deref() == Some(j))
            })
            .collect();
        let total: f64 = billed.iter().map(|(_, _, v)| v.billed_cost).sum();
        println!(
            "{shown} decision(s) involved {vm}; {} VM lifetime(s) billed ${total:.4} total",
            billed.len()
        );
        return Ok(());
    }

    let mut shown = 0usize;
    for (pt, tr, d) in &decisions {
        if !keep(d) {
            continue;
        }
        shown += 1;
        println!("{}{}", tag(*pt, *tr), d.render());
    }
    if let Some(j) = job_filter {
        anyhow::ensure!(shown > 0, "no decisions for job {j}; drop --job to list all");
    }
    println!(
        "{shown} decision(s), {} vm span(s), {} event(s) in {} file(s)",
        spans.len(),
        events.len(),
        files.len()
    );
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let app_name = args.get("app").ok_or_else(|| anyhow::anyhow!("--app required"))?;
    let app = multi_fedls::apps::by_name(app_name)
        .ok_or_else(|| anyhow::anyhow!("unknown app {app_name}"))?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let mut cfg = RealRunConfig::quick(app);
    if let Some(r) = args.get("rounds") {
        cfg.rounds = r.parse()?;
    }
    if let Some(e) = args.get("epochs") {
        cfg.local_epochs = e.parse()?;
    }
    if let Some(s) = args.get("scale") {
        cfg.data_scale = s.parse()?;
    }
    if let Some(x) = args.get("ckpt-every") {
        cfg.server_ckpt_every = Some(x.parse()?);
    }
    if let Some(d) = args.get("ckpt-dir") {
        cfg.checkpoint_dir = Some(d.into());
    }
    let out = real_run(std::path::Path::new(artifacts), &cfg)?;
    let mut t = multi_fedls::util::bench::Table::new(
        format!("Real FL run — {} ({} rounds)", app_name, out.history.len()),
        &["Round", "Loss", "Accuracy", "Failures", "Secs"],
    );
    for r in &out.history {
        t.row(&[
            r.round.to_string(),
            format!("{:.4}", r.loss),
            format!("{:.4}", r.accuracy),
            r.failures.to_string(),
            format!("{:.2}", r.wall_secs),
        ]);
    }
    t.print();
    println!("total failures handled: {}", out.total_failures);
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let name = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("experiment name required\n{USAGE}"))?;
    let json = args.flag("json");
    let render = |t: multi_fedls::util::bench::Table, j: multi_fedls::util::Json| {
        if json {
            println!("{}", j.to_string_pretty());
        } else {
            t.print();
        }
    };
    match name.as_str() {
        "table3" => {
            let (t, j) = trace::table3();
            render(t, j);
        }
        "table4" => {
            let (t, j) = trace::table4();
            render(t, j);
        }
        "validation" => {
            let (t, j) = trace::validation_5_4();
            render(t, j);
        }
        "fig2" => {
            let (t, j) = trace::fig2();
            render(t, j);
        }
        "table5" => {
            let (t, j) = trace::table5();
            render(t, j);
        }
        "table6" => {
            let (t, j) = trace::table6();
            render(t, j);
        }
        "table7" => {
            let (t, j) = trace::table7();
            render(t, j);
        }
        "table8" => {
            let (t, j) = trace::table8();
            render(t, j);
        }
        "poc" => {
            let (t, j) = trace::poc_aws_gcp();
            render(t, j);
        }
        "mapping" => {
            let (t, j) = trace::mapping_comparison();
            render(t, j);
        }
        "alpha-sweep" => {
            let (t, j) = trace::alpha_sweep();
            render(t, j);
        }
        "multijob" => {
            let (t, j) = trace::multijob();
            render(t, j);
        }
        "dynsched-ablation" => {
            let (t, j) = trace::dynsched_ablation();
            render(t, j);
        }
        "mapper-ablation" => {
            let (t, j) = trace::mapper_ablation();
            render(t, j);
        }
        "preempt-ablation" => {
            let (t, j) = trace::preempt_ablation();
            render(t, j);
        }
        "market-sensitivity" => {
            let (t, j) = trace::market_sensitivity();
            render(t, j);
        }
        "outlook-ablation" => {
            let (t, j) = trace::outlook_ablation();
            render(t, j);
        }
        "all" => {
            for f in [
                trace::table3 as fn() -> (multi_fedls::util::bench::Table, multi_fedls::util::Json),
                trace::table4,
                trace::validation_5_4,
                trace::fig2,
                trace::table5,
                trace::table6,
                trace::table7,
                trace::table8,
                trace::poc_aws_gcp,
                trace::mapping_comparison,
                trace::alpha_sweep,
                trace::multijob,
                trace::dynsched_ablation,
                trace::mapper_ablation,
                trace::preempt_ablation,
                trace::market_sensitivity,
                trace::outlook_ablation,
            ] {
                let (t, _) = f();
                t.print();
                println!();
            }
        }
        other => anyhow::bail!("unknown experiment {other}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        let argv: Vec<String> = tokens.iter().map(|s| s.to_string()).collect();
        Args::parse(&argv)
    }

    #[test]
    fn negative_numeric_values_are_not_swallowed_as_flags() {
        let a = parse(&["--alpha", "-0.5", "--budget", "-3", "--deadline", "-1e4"]);
        assert_eq!(a.get("alpha"), Some("-0.5"));
        assert_eq!(a.get("budget"), Some("-3"));
        assert_eq!(a.get("deadline"), Some("-1e4"));
    }

    #[test]
    fn key_equals_value_syntax() {
        let a = parse(&["--alpha=-0.5", "--spec=configs/x.toml"]);
        assert_eq!(a.get("alpha"), Some("-0.5"));
        assert_eq!(a.get("spec"), Some("configs/x.toml"));
    }

    #[test]
    fn bare_flags_and_positionals() {
        let a = parse(&["simulate", "--json", "--spec", "f.toml", "extra"]);
        assert_eq!(a.positional, vec!["simulate", "extra"]);
        assert!(a.flag("json"));
        assert_eq!(a.get("spec"), Some("f.toml"));
    }

    #[test]
    fn flag_followed_by_another_option_stays_boolean() {
        let a = parse(&["--json", "--jobs", "8"]);
        assert!(a.flag("json"));
        assert_eq!(a.get("jobs"), Some("8"));
    }

    #[test]
    fn option_token_classification() {
        assert!(is_option_token("--jobs"));
        assert!(is_option_token("-x"));
        assert!(!is_option_token("-0.5"));
        assert!(!is_option_token("-3"));
        assert!(!is_option_token("-1e-4"));
        assert!(!is_option_token("value"));
        assert!(!is_option_token("-"));
    }
}
