//! Declarative sweep specifications: a TOML grid expanded into campaign
//! points, with per-trial RNG streams derived via [`Rng::split_seed`] so the
//! expansion is a pure function of the spec — worker count and completion
//! order cannot change any seed.
//!
//! Spec format (parsed with `util::tomlmini`):
//!
//! ```toml
//! name = "til-failures"        # optional; used in the JSON header
//! trials = 3                   # executions per grid point (default 1)
//! seed = 50                    # root seed for the split streams (default 42)
//! rounds = 80                  # optional n_rounds override for every point
//! max_revocations_per_task = 1 # optional §5.6.1 cap (scalar; or a grid axis)
//! checkpoints = true           # optional checkpoints_enabled override
//! jobs = 8                     # optional default worker count (CLI --jobs wins)
//!
//! [grid]                       # every key is an axis; the grid is the product
//! apps = ["til"]
//! scenarios = ["all-spot", "on-demand-server"]
//! revocation_mean_secs = [7200.0, 14400.0]   # 0 = no failures
//! policies = ["different-vm", "same-vm"]
//! alphas = [0.5]
//! mappers = ["exact"]          # optional: Initial Mapping module per point
//! server_ckpt_every = [10, 40] # optional: server cadence X; 0 = server ckpt off
//! client_checkpoint = [true]   # optional: per-round client checkpoint on/off
//! max_revocations_per_task = [1, 2]  # optional axis form of the scalar cap
//! budget_round = [1.0, 2.0]    # optional: B_round $ cap per round (Constraint 8)
//! deadline_round = [600.0]     # optional: T_round seconds per round (Constraint 9)
//! markets = ["exponential", "volatile"]  # optional: spot-market model per point
//! outlooks = ["off", "aware"]  # optional: market-outlook config per point
//!
//! [[market]]                   # named market definitions for the axis
//! name = "volatile"            # ("exponential" = the built-in default market)
//! revocation = "trace"
//! revocation_times = [3600.0, 10800.0]
//! price = "steps"
//! price_times = [0.0, 7200.0]
//! price_factors = [1.0, 1.6]
//!
//! [[outlook]]                  # named outlook definitions for the axis
//! name = "aware"               # ("off" = the built-in disabled default)
//! horizon = 14400.0
//! defer = true
//! ```
//!
//! Checkpoint-axis semantics (Fig. 2 in one spec, `sweep-fig2.toml`):
//! `server_ckpt_every = 0` turns the periodic server save off; if the
//! point's client checkpoint is also off, checkpointing is disabled
//! entirely for that point (the Fig. 2 "no checkpoints" baseline).

use std::collections::BTreeMap;
use std::path::Path;

use super::PointSpec;
use crate::apps;
use crate::coordinator::{Scenario, SimConfig, TrialStats};
use crate::dynsched::DynSchedPolicy;
use crate::mapping::MapperKind;
use crate::market::{self, MarketSpec};
use crate::outlook::{self, OutlookSpec};
use crate::simul::Rng;
use crate::util::bench::Table;
use crate::util::tomlmini::{self, Value};
use crate::util::Json;

/// A parsed sweep specification (the campaign grid).
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub name: String,
    pub trials: usize,
    pub seed: u64,
    pub apps: Vec<String>,
    pub scenarios: Vec<Scenario>,
    /// Mean time between revocations `k_r`; `None` = no failures (spelled
    /// `0` in the TOML grid).
    pub revocation_mean_secs: Vec<Option<f64>>,
    pub policies: Vec<DynSchedPolicy>,
    pub alphas: Vec<f64>,
    /// Initial Mapping module per point (default: exact only).
    pub mappers: Vec<MapperKind>,
    /// Optional axis: server checkpoint cadence X (0 = server ckpt off;
    /// combined with a client-checkpoint-off point, checkpointing is
    /// disabled entirely). `None` = not swept.
    pub server_ckpt_every: Option<Vec<u32>>,
    /// Optional axis: per-round client checkpoint on/off. `None` = not swept.
    pub client_checkpoint: Option<Vec<bool>>,
    /// Optional axis form of the per-task revocation cap. `None` = not
    /// swept (the scalar `max_revocations_per_task` applies instead).
    pub max_revocations_axis: Option<Vec<u32>>,
    /// Optional axis: per-round budget `B_round` in $ handed to the Initial
    /// Mapping solver. `None` = not swept (unconstrained).
    pub budget_round: Option<Vec<f64>>,
    /// Optional axis: per-round deadline `T_round` in seconds.
    pub deadline_round: Option<Vec<f64>>,
    /// Optional axis: named spot-market models (`markets` keys resolved
    /// against the `[[market]]` definitions; "exponential" = the built-in
    /// default). `None` = not swept (every point runs the default market).
    pub markets: Option<Vec<(String, MarketSpec)>>,
    /// Optional axis: named market-outlook configurations (`outlooks` keys
    /// resolved against the `[[outlook]]` definitions; "off" = the built-in
    /// disabled default). `None` = not swept (every point runs outlook-off).
    pub outlooks: Option<Vec<(String, OutlookSpec)>>,
    pub rounds: Option<u32>,
    pub max_revocations_per_task: Option<u32>,
    pub checkpoints: Option<bool>,
    /// Default worker count; the CLI `--jobs` flag overrides it.
    pub jobs: Option<usize>,
}

fn policy_key(p: DynSchedPolicy) -> &'static str {
    if p.remove_revoked {
        "different-vm"
    } else {
        "same-vm"
    }
}

fn parse_policy(s: &str) -> anyhow::Result<DynSchedPolicy> {
    match s {
        "different-vm" => Ok(DynSchedPolicy::different_vm()),
        "same-vm" => Ok(DynSchedPolicy::same_vm_allowed()),
        other => anyhow::bail!("unknown policy {other} (different-vm | same-vm)"),
    }
}

type Tbl = BTreeMap<String, Value>;

/// Read an axis as a list, accepting a bare scalar as a one-element list.
fn axis<'a>(grid: &'a Tbl, key: &str) -> Option<Vec<&'a Value>> {
    match grid.get(key)? {
        Value::Array(items) => Some(items.iter().collect()),
        v => Some(vec![v]),
    }
}

fn str_axis(grid: &Tbl, key: &str) -> anyhow::Result<Option<Vec<String>>> {
    match axis(grid, key) {
        None => Ok(None),
        Some(items) => items
            .into_iter()
            .map(|v| {
                v.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow::anyhow!("grid.{key} entries must be strings"))
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some),
    }
}

fn num_axis(grid: &Tbl, key: &str) -> anyhow::Result<Option<Vec<f64>>> {
    match axis(grid, key) {
        None => Ok(None),
        Some(items) => items
            .into_iter()
            .map(|v| {
                v.as_float()
                    .ok_or_else(|| anyhow::anyhow!("grid.{key} entries must be numbers"))
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some),
    }
}

fn uint_axis(grid: &Tbl, key: &str) -> anyhow::Result<Option<Vec<u32>>> {
    match axis(grid, key) {
        None => Ok(None),
        Some(items) => items
            .into_iter()
            .map(|v| match v.as_int() {
                Some(x) if (0..=u32::MAX as i64).contains(&x) => Ok(x as u32),
                Some(x) => anyhow::bail!("grid.{key} entry {x} outside 0..=u32::MAX"),
                None => anyhow::bail!("grid.{key} entries must be integers"),
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some),
    }
}

/// Signed-integer axis (e.g. the workload grid's `priorities`, which may
/// legitimately be negative).
pub(crate) fn int_axis(
    grid: &BTreeMap<String, Value>,
    key: &str,
) -> anyhow::Result<Option<Vec<i64>>> {
    match axis(grid, key) {
        None => Ok(None),
        Some(items) => items
            .into_iter()
            .map(|v| {
                v.as_int()
                    .ok_or_else(|| anyhow::anyhow!("grid.{key} entries must be integers"))
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some),
    }
}

fn bool_axis(grid: &Tbl, key: &str) -> anyhow::Result<Option<Vec<bool>>> {
    match axis(grid, key) {
        None => Ok(None),
        Some(items) => items
            .into_iter()
            .map(|v| {
                v.as_bool()
                    .ok_or_else(|| anyhow::anyhow!("grid.{key} entries must be booleans"))
            })
            .collect::<anyhow::Result<Vec<_>>>()
            .map(Some),
    }
}

impl SweepSpec {
    pub fn from_toml(text: &str) -> anyhow::Result<SweepSpec> {
        Self::from_toml_with_base(text, None)
    }

    /// [`Self::from_toml`] with the spec file's directory for resolving
    /// relative `[[market]]` trace-file references.
    pub fn from_toml_with_base(text: &str, base: Option<&Path>) -> anyhow::Result<SweepSpec> {
        let root = tomlmini::parse(text)?;
        tomlmini::reject_unknown_keys(
            &root,
            &[
                "name", "trials", "seed", "rounds", "max_revocations_per_task", "checkpoints",
                "jobs", "grid", "market", "outlook",
            ],
            "sweep spec",
        )?;
        let grid = root
            .get("grid")
            .and_then(|v| v.as_table())
            .ok_or_else(|| anyhow::anyhow!("sweep spec missing [grid] section"))?;
        tomlmini::reject_unknown_keys(
            grid,
            &[
                "apps",
                "scenarios",
                "revocation_mean_secs",
                "policies",
                "alphas",
                "mappers",
                "server_ckpt_every",
                "client_checkpoint",
                "max_revocations_per_task",
                "budget_round",
                "deadline_round",
                "markets",
                "outlooks",
            ],
            "sweep [grid]",
        )?;

        let apps = str_axis(grid, "apps")?
            .ok_or_else(|| anyhow::anyhow!("grid.apps is required (e.g. [\"til\"])"))?;
        anyhow::ensure!(!apps.is_empty(), "grid.apps is empty");
        for a in &apps {
            anyhow::ensure!(apps::by_name(a).is_some(), "unknown app {a}");
        }

        let scenarios = match str_axis(grid, "scenarios")? {
            Some(keys) => keys
                .iter()
                .map(|k| {
                    Scenario::from_key(k)
                        .ok_or_else(|| anyhow::anyhow!("unknown scenario {k}"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![Scenario::AllOnDemand],
        };

        let revocation_mean_secs = match num_axis(grid, "revocation_mean_secs")? {
            Some(ks) => ks
                .into_iter()
                .map(|k| {
                    anyhow::ensure!(k >= 0.0, "revocation_mean_secs must be >= 0 (0 = none)");
                    Ok(if k == 0.0 { None } else { Some(k) })
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![None],
        };

        let policies = match str_axis(grid, "policies")? {
            Some(keys) => keys
                .iter()
                .map(|k| parse_policy(k))
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![DynSchedPolicy::same_vm_allowed()],
        };

        let alphas = match num_axis(grid, "alphas")? {
            Some(xs) => {
                for &a in &xs {
                    anyhow::ensure!((0.0..=1.0).contains(&a), "alpha {a} outside [0,1]");
                }
                xs
            }
            None => vec![0.5],
        };

        let mappers = match str_axis(grid, "mappers")? {
            Some(keys) => keys
                .iter()
                .map(|k| {
                    MapperKind::from_key(k).ok_or_else(|| anyhow::anyhow!("unknown mapper {k}"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => vec![MapperKind::Exact],
        };

        let server_ckpt_every = uint_axis(grid, "server_ckpt_every")?;
        let client_checkpoint = bool_axis(grid, "client_checkpoint")?;
        let max_revocations_axis = uint_axis(grid, "max_revocations_per_task")?;
        let positive_axis = |key: &str| -> anyhow::Result<Option<Vec<f64>>> {
            match num_axis(grid, key)? {
                None => Ok(None),
                Some(xs) => {
                    for &x in &xs {
                        anyhow::ensure!(x > 0.0, "grid.{key} entries must be positive, got {x}");
                    }
                    Ok(Some(xs))
                }
            }
        };
        let budget_round = positive_axis("budget_round")?;
        let deadline_round = positive_axis("deadline_round")?;

        // Spot-market axis: names resolved against the [[market]] tables
        // (plus the built-in "exponential" default market).
        let market_defs = market::spec::named_markets(&root, base)?;
        let markets = match str_axis(grid, "markets")? {
            None => None,
            Some(names) => Some(
                names
                    .into_iter()
                    .map(|n| market::spec::resolve_market(&n, &market_defs).map(|m| (n, m)))
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };

        // Market-outlook axis: names resolved against the [[outlook]] tables
        // (plus the built-in "off" disabled default).
        let outlook_defs = outlook::named_outlooks(&root)?;
        let outlooks = match str_axis(grid, "outlooks")? {
            None => None,
            Some(names) => Some(
                names
                    .into_iter()
                    .map(|n| outlook::resolve_outlook(&n, &outlook_defs).map(|o| (n, o)))
                    .collect::<anyhow::Result<Vec<_>>>()?,
            ),
        };

        // Negative integers must error, not wrap through the `as` casts.
        let get_nonneg = |key: &str| -> anyhow::Result<Option<i64>> {
            match root.get(key).and_then(|v| v.as_int()) {
                Some(x) if x < 0 => anyhow::bail!("{key} must be non-negative, got {x}"),
                other => Ok(other),
            }
        };
        let trials = get_nonneg("trials")?.unwrap_or(1);
        anyhow::ensure!(trials > 0, "trials must be positive");
        let max_revocations_per_task = get_nonneg("max_revocations_per_task")?.map(|m| m as u32);
        anyhow::ensure!(
            max_revocations_axis.is_none() || max_revocations_per_task.is_none(),
            "max_revocations_per_task given both as a scalar and as a grid axis"
        );
        Ok(SweepSpec {
            name: root
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("sweep")
                .to_string(),
            trials: trials as usize,
            seed: get_nonneg("seed")?.unwrap_or(42) as u64,
            apps,
            scenarios,
            revocation_mean_secs,
            policies,
            alphas,
            mappers,
            server_ckpt_every,
            client_checkpoint,
            max_revocations_axis,
            budget_round,
            deadline_round,
            markets,
            outlooks,
            rounds: get_nonneg("rounds")?.map(|r| r as u32),
            max_revocations_per_task,
            checkpoints: root.get("checkpoints").and_then(|v| v.as_bool()),
            jobs: get_nonneg("jobs")?.map(|j| j as usize),
        })
    }

    pub fn from_file(path: &Path) -> anyhow::Result<SweepSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml_with_base(&text, path.parent())
    }

    /// Number of grid points (trial count is `n_points() * trials`).
    pub fn n_points(&self) -> usize {
        self.apps.len()
            * self.scenarios.len()
            * self.revocation_mean_secs.len()
            * self.policies.len()
            * self.alphas.len()
            * self.mappers.len()
            * self.server_ckpt_every.as_ref().map_or(1, |v| v.len())
            * self.client_checkpoint.as_ref().map_or(1, |v| v.len())
            * self.max_revocations_axis.as_ref().map_or(1, |v| v.len())
            * self.budget_round.as_ref().map_or(1, |v| v.len())
            * self.deadline_round.as_ref().map_or(1, |v| v.len())
            * self.markets.as_ref().map_or(1, |v| v.len())
            * self.outlooks.as_ref().map_or(1, |v| v.len())
    }

    /// Expand the grid into campaign points. Each trial's seed is derived
    /// from the root seed via a pure `Rng::split_seed` on the trial's global
    /// index, so the same spec always yields the same seeds. Specs that do
    /// not use the optional axes expand to the exact same points (and
    /// seeds) as before those axes existed.
    pub fn expand(&self) -> anyhow::Result<Vec<PointSpec>> {
        let root = Rng::seeded(self.seed);
        // Optional axes: a single `None` entry when not swept.
        let ckpt_axis: Vec<Option<u32>> = match &self.server_ckpt_every {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let client_axis: Vec<Option<bool>> = match &self.client_checkpoint {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let maxrev_axis: Vec<Option<u32>> = match &self.max_revocations_axis {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let budget_axis: Vec<Option<f64>> = match &self.budget_round {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let deadline_axis: Vec<Option<f64>> = match &self.deadline_round {
            Some(v) => v.iter().copied().map(Some).collect(),
            None => vec![None],
        };
        let market_axis: Vec<Option<&(String, MarketSpec)>> = match &self.markets {
            Some(v) => v.iter().map(Some).collect(),
            None => vec![None],
        };
        let outlook_axis: Vec<Option<&(String, OutlookSpec)>> = match &self.outlooks {
            Some(v) => v.iter().map(Some).collect(),
            None => vec![None],
        };
        let mut points = Vec::with_capacity(self.n_points());
        let mut global_trial: u64 = 0;
        let trials = self.trials;
        let mut next_seeds = move || -> Vec<u64> {
            (0..trials)
                .map(|_| {
                    let s = root.split_seed(global_trial);
                    global_trial += 1;
                    s
                })
                .collect()
        };
        for app_name in &self.apps {
            let app = apps::by_name(app_name)
                .ok_or_else(|| anyhow::anyhow!("unknown app {app_name}"))?;
            for &scenario in &self.scenarios {
                for &k_r in &self.revocation_mean_secs {
                    for &policy in &self.policies {
                        for &alpha in &self.alphas {
                            for &mapper in &self.mappers {
                                for &ckpt_every in &ckpt_axis {
                                    for &client_ckpt in &client_axis {
                                        for &maxrev in &maxrev_axis {
                                            for &budget in &budget_axis {
                                                for &deadline in &deadline_axis {
                                                    for &mkt in &market_axis {
                                                        for &olk in &outlook_axis {
                                                            let seeds = next_seeds();
                                                            points.push(self.point(
                                                                app.clone(),
                                                                app_name,
                                                                scenario,
                                                                k_r,
                                                                policy,
                                                                alpha,
                                                                mapper,
                                                                ckpt_every,
                                                                client_ckpt,
                                                                maxrev,
                                                                budget,
                                                                deadline,
                                                                mkt,
                                                                olk,
                                                                seeds,
                                                            ));
                                                        }
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        anyhow::ensure!(!points.is_empty(), "sweep grid expanded to zero points");
        Ok(points)
    }

    /// Build one grid point: apply every axis value to the base config and
    /// record the axis tags for rendering.
    #[allow(clippy::too_many_arguments)]
    fn point(
        &self,
        app: apps::AppSpec,
        app_name: &str,
        scenario: Scenario,
        k_r: Option<f64>,
        policy: DynSchedPolicy,
        alpha: f64,
        mapper: MapperKind,
        ckpt_every: Option<u32>,
        client_ckpt: Option<bool>,
        maxrev: Option<u32>,
        budget: Option<f64>,
        deadline: Option<f64>,
        market: Option<&(String, MarketSpec)>,
        outlook: Option<&(String, OutlookSpec)>,
        seeds: Vec<u64>,
    ) -> PointSpec {
        let mut cfg = SimConfig::new(app, scenario, self.seed);
        cfg.alpha = alpha;
        cfg.revocation_mean_secs = k_r;
        cfg.dynsched_policy = policy;
        cfg.mapper = mapper;
        if let Some(r) = self.rounds {
            cfg.n_rounds = r;
        }
        if let Some(m) = maxrev.or(self.max_revocations_per_task) {
            cfg.max_revocations_per_task = Some(m);
        }
        if let Some(c) = self.checkpoints {
            cfg.checkpoints_enabled = c;
        }
        if let Some(b) = client_ckpt {
            cfg.ft.client_checkpoint = b;
        }
        if let Some(x) = ckpt_every {
            // 0 = server checkpointing off; with the client side also off
            // nothing is checkpointed at all (the Fig. 2 baseline). Shared
            // rule with the job-spec key via `set_server_ckpt_every`.
            cfg.set_server_ckpt_every(x);
        }
        if let Some(b) = budget {
            cfg.budget_round = b;
        }
        if let Some(d) = deadline {
            cfg.deadline_round = d;
        }
        if let Some((_, spec)) = market {
            cfg.market = spec.clone();
        }
        if let Some((_, spec)) = outlook {
            cfg.outlook = spec.clone();
        }
        let mut tags = vec![
            ("app".to_string(), app_name.to_string()),
            ("scenario".to_string(), scenario.key().to_string()),
            ("revocation_mean_secs".to_string(), format!("{}", k_r.unwrap_or(0.0))),
            ("policy".to_string(), policy_key(policy).to_string()),
            ("alpha".to_string(), format!("{alpha}")),
            ("mapper".to_string(), mapper.key().to_string()),
        ];
        if let Some(x) = ckpt_every {
            tags.push(("server_ckpt_every".to_string(), format!("{x}")));
        }
        if let Some(b) = client_ckpt {
            tags.push(("client_checkpoint".to_string(), format!("{b}")));
        }
        if let Some(m) = maxrev {
            tags.push(("max_revocations_per_task".to_string(), format!("{m}")));
        }
        if let Some(b) = budget {
            tags.push(("budget_round".to_string(), format!("{b}")));
        }
        if let Some(d) = deadline {
            tags.push(("deadline_round".to_string(), format!("{d}")));
        }
        if let Some((name, _)) = market {
            tags.push(("market".to_string(), name.clone()));
        }
        if let Some((name, _)) = outlook {
            tags.push(("outlook".to_string(), name.clone()));
        }
        PointSpec { tags, cfg, seeds }
    }
}

/// Render campaign results as JSON (one object per point, aggregates per
/// metric). Deliberately excludes the worker count so output is byte-stable
/// across `--jobs` values.
pub fn render_json(spec: &SweepSpec, points: &[PointSpec], stats: &[TrialStats]) -> Json {
    let rows: Vec<Json> = points
        .iter()
        .zip(stats)
        .map(|(p, s)| {
            let mut row = Json::obj();
            for (k, v) in &p.tags {
                row = row.set(k, v.clone());
            }
            row.set("trials", s.trials)
                .set("revocations", s.revocations.json())
                .set("fl_exec_secs", s.exec_secs.json())
                .set("total_secs", s.total_secs.json())
                .set("cost", s.cost.json())
        })
        .collect();
    Json::obj()
        .set("sweep", spec.name.clone())
        .set("seed", spec.seed)
        .set("trials_per_point", spec.trials)
        .set("points", Json::Arr(rows))
}

/// Render campaign results as CSV (flat columns, one row per point; axis
/// columns for un-swept optional axes are empty).
pub fn render_csv(points: &[PointSpec], stats: &[TrialStats]) -> String {
    let mut out = String::new();
    out.push_str(
        "app,scenario,revocation_mean_secs,policy,alpha,mapper,\
         server_ckpt_every,client_checkpoint,max_revocations_per_task,\
         budget_round,deadline_round,market,outlook,trials",
    );
    for metric in ["revocations", "fl_exec_secs", "total_secs", "cost"] {
        for stat in ["mean", "stddev", "min", "max", "ci95"] {
            out.push_str(&format!(",{metric}_{stat}"));
        }
    }
    out.push('\n');
    for (p, s) in points.iter().zip(stats) {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
            p.tag("app"),
            p.tag("scenario"),
            p.tag("revocation_mean_secs"),
            p.tag("policy"),
            p.tag("alpha"),
            p.tag("mapper"),
            p.tag("server_ckpt_every"),
            p.tag("client_checkpoint"),
            p.tag("max_revocations_per_task"),
            p.tag("budget_round"),
            p.tag("deadline_round"),
            p.tag("market"),
            p.tag("outlook"),
            s.trials
        ));
        for agg in [&s.revocations, &s.exec_secs, &s.total_secs, &s.cost] {
            out.push_str(&format!(
                ",{},{},{},{},{}",
                agg.mean, agg.stddev, agg.min, agg.max, agg.ci95
            ));
        }
        out.push('\n');
    }
    out
}

/// Render campaign results as a human table.
pub fn render_table(spec: &SweepSpec, points: &[PointSpec], stats: &[TrialStats]) -> Table {
    let mut t = Table::new(
        format!("Sweep — {} ({} points × {} trials)", spec.name, points.len(), spec.trials),
        &[
            "App",
            "Scenario",
            "k_r",
            "Policy",
            "alpha",
            "Mapper",
            "Avg revoc.",
            "FL exec",
            "Total",
            "Cost ($)",
            "Cost ±95% CI",
        ],
    );
    for (p, s) in points.iter().zip(stats) {
        t.row(&[
            p.tag("app").to_string(),
            p.tag("scenario").to_string(),
            p.tag("revocation_mean_secs").to_string(),
            p.tag("policy").to_string(),
            p.tag("alpha").to_string(),
            p.tag("mapper").to_string(),
            format!("{:.2}", s.revocations.mean),
            s.fl_hms(),
            s.exec_hms(),
            format!("{:.2}", s.cost.mean),
            format!("±{:.2}", s.cost.ci95),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
name = "unit"
trials = 3
seed = 9
rounds = 20
max_revocations_per_task = 1

[grid]
apps = ["til"]
scenarios = ["all-spot", "on-demand-server"]
revocation_mean_secs = [7200.0, 0]
policies = ["different-vm", "same-vm"]
alphas = 0.5
"#;

    #[test]
    fn parses_full_spec() {
        let spec = SweepSpec::from_toml(SPEC).unwrap();
        assert_eq!(spec.name, "unit");
        assert_eq!(spec.trials, 3);
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.rounds, Some(20));
        assert_eq!(spec.max_revocations_per_task, Some(1));
        assert_eq!(spec.apps, vec!["til"]);
        assert_eq!(spec.scenarios, vec![Scenario::AllSpot, Scenario::OnDemandServer]);
        assert_eq!(spec.revocation_mean_secs, vec![Some(7200.0), None]);
        assert_eq!(spec.policies.len(), 2);
        assert!(spec.policies[0].remove_revoked);
        assert_eq!(spec.alphas, vec![0.5]); // scalar accepted as 1-element axis
        assert_eq!(spec.n_points(), 8);
    }

    #[test]
    fn expansion_is_cartesian_and_sets_config() {
        let spec = SweepSpec::from_toml(SPEC).unwrap();
        let points = spec.expand().unwrap();
        assert_eq!(points.len(), 8);
        for p in &points {
            assert_eq!(p.seeds.len(), 3);
            assert_eq!(p.cfg.n_rounds, 20);
            assert_eq!(p.cfg.max_revocations_per_task, Some(1));
        }
        // Axis ordering: scenario is the outer loop over (k_r, policy).
        assert_eq!(points[0].tag("scenario"), "all-spot");
        assert_eq!(points[0].tag("policy"), "different-vm");
        assert_eq!(points[1].tag("policy"), "same-vm");
        assert_eq!(points[4].tag("scenario"), "on-demand-server");
        // k_r = 0 means no failures.
        assert!(points[2].cfg.revocation_mean_secs.is_none());
        assert_eq!(points[2].tag("revocation_mean_secs"), "0");
    }

    #[test]
    fn seeds_are_unique_and_reproducible() {
        let spec = SweepSpec::from_toml(SPEC).unwrap();
        let a = spec.expand().unwrap();
        let b = spec.expand().unwrap();
        let mut seen = std::collections::HashSet::new();
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.seeds, pb.seeds, "expansion must be deterministic");
            for &s in &pa.seeds {
                assert!(seen.insert(s), "duplicate trial seed {s}");
            }
        }
        assert_eq!(seen.len(), 24);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(SweepSpec::from_toml("trials = 3\n").is_err(), "missing [grid]");
        assert!(SweepSpec::from_toml("[grid]\nscenarios = [\"all-spot\"]\n").is_err(), "no apps");
        assert!(SweepSpec::from_toml("[grid]\napps = [\"nope\"]\n").is_err(), "unknown app");
        assert!(
            SweepSpec::from_toml("[grid]\napps = [\"til\"]\nscenarios = [\"weird\"]\n").is_err()
        );
        assert!(SweepSpec::from_toml("[grid]\napps = [\"til\"]\nalphas = [1.5]\n").is_err());
        assert!(
            SweepSpec::from_toml("[grid]\napps = [\"til\"]\nrevocation_mean_secs = [-1.0]\n")
                .is_err()
        );
        // Negative ints must error, not wrap through the u32/usize casts.
        assert!(SweepSpec::from_toml("rounds = -80\n[grid]\napps = [\"til\"]\n").is_err());
        assert!(
            SweepSpec::from_toml("max_revocations_per_task = -1\n[grid]\napps = [\"til\"]\n")
                .is_err()
        );
        assert!(SweepSpec::from_toml("jobs = -4\n[grid]\napps = [\"til\"]\n").is_err());
    }

    #[test]
    fn defaults_fill_missing_axes() {
        let spec = SweepSpec::from_toml("[grid]\napps = [\"femnist\"]\n").unwrap();
        assert_eq!(spec.trials, 1);
        assert_eq!(spec.seed, 42);
        assert_eq!(spec.scenarios, vec![Scenario::AllOnDemand]);
        assert_eq!(spec.revocation_mean_secs, vec![None]);
        assert_eq!(spec.alphas, vec![0.5]);
        assert_eq!(spec.mappers, vec![MapperKind::Exact]);
        assert!(spec.server_ckpt_every.is_none());
        assert!(spec.client_checkpoint.is_none());
        assert!(spec.max_revocations_axis.is_none());
        assert!(spec.budget_round.is_none());
        assert!(spec.deadline_round.is_none());
        assert_eq!(spec.n_points(), 1);
    }

    #[test]
    fn budget_deadline_axes_expand_and_tag() {
        let spec = SweepSpec::from_toml(
            "[grid]\napps = [\"til\"]\nbudget_round = [2.0, 4.0]\ndeadline_round = [600.0]\n",
        )
        .unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert_eq!(points[0].cfg.budget_round, 2.0);
        assert_eq!(points[1].cfg.budget_round, 4.0);
        assert_eq!(points[0].cfg.deadline_round, 600.0);
        assert_eq!(points[0].tag("budget_round"), "2");
        assert_eq!(points[1].tag("budget_round"), "4");
        assert_eq!(points[1].tag("deadline_round"), "600");
        // Un-swept specs leave the config unconstrained.
        let plain = SweepSpec::from_toml("[grid]\napps = [\"til\"]\n").unwrap();
        let p = plain.expand().unwrap();
        assert!(p[0].cfg.budget_round.is_infinite());
        assert!(p[0].cfg.deadline_round.is_infinite());
        // Non-positive entries are rejected.
        assert!(
            SweepSpec::from_toml("[grid]\napps = [\"til\"]\nbudget_round = [0.0]\n").is_err()
        );
        assert!(
            SweepSpec::from_toml("[grid]\napps = [\"til\"]\ndeadline_round = [-5.0]\n").is_err()
        );
    }

    #[test]
    fn mapper_axis_expands_and_tags() {
        let spec = SweepSpec::from_toml(
            "[grid]\napps = [\"til\"]\nmappers = [\"exact\", \"cheapest\"]\n",
        )
        .unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert_eq!(points[0].cfg.mapper, MapperKind::Exact);
        assert_eq!(points[1].cfg.mapper, MapperKind::Cheapest);
        assert_eq!(points[0].tag("mapper"), "exact");
        assert_eq!(points[1].tag("mapper"), "cheapest");
        assert!(
            SweepSpec::from_toml("[grid]\napps = [\"til\"]\nmappers = [\"nope\"]\n").is_err()
        );
    }

    #[test]
    fn checkpoint_axes_expand_with_fig2_semantics() {
        let spec = SweepSpec::from_toml(
            "rounds = 80\n[grid]\napps = [\"til\"]\nserver_ckpt_every = [0, 10]\nclient_checkpoint = [false, true]\n",
        )
        .unwrap();
        assert_eq!(spec.n_points(), 4);
        let points = spec.expand().unwrap();
        // (0, false): nothing checkpointed → the Fig. 2 baseline.
        assert!(!points[0].cfg.checkpoints_enabled);
        assert_eq!(points[0].cfg.ft.server_every_rounds, u32::MAX);
        assert_eq!(points[0].tag("server_ckpt_every"), "0");
        assert_eq!(points[0].tag("client_checkpoint"), "false");
        // (0, true): client-only checkpointing.
        assert!(points[1].cfg.checkpoints_enabled);
        assert!(points[1].cfg.ft.client_checkpoint);
        assert_eq!(points[1].cfg.ft.server_every_rounds, u32::MAX);
        // (10, false): server-only cadence X=10.
        assert!(points[2].cfg.checkpoints_enabled);
        assert!(!points[2].cfg.ft.client_checkpoint);
        assert_eq!(points[2].cfg.ft.server_every_rounds, 10);
        // (10, true): both.
        assert!(points[3].cfg.ft.client_checkpoint);
        assert_eq!(points[3].cfg.ft.server_every_rounds, 10);
    }

    #[test]
    fn max_revocations_axis_expands_and_conflicts_with_scalar() {
        let spec = SweepSpec::from_toml(
            "[grid]\napps = [\"til\"]\nmax_revocations_per_task = [1, 2]\n",
        )
        .unwrap();
        let points = spec.expand().unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].cfg.max_revocations_per_task, Some(1));
        assert_eq!(points[1].cfg.max_revocations_per_task, Some(2));
        assert_eq!(points[1].tag("max_revocations_per_task"), "2");
        // Scalar and axis together are ambiguous → rejected.
        assert!(SweepSpec::from_toml(
            "max_revocations_per_task = 1\n[grid]\napps = [\"til\"]\nmax_revocations_per_task = [1, 2]\n"
        )
        .is_err());
        // Negative axis entries are rejected.
        assert!(SweepSpec::from_toml(
            "[grid]\napps = [\"til\"]\nserver_ckpt_every = [-1]\n"
        )
        .is_err());
    }

    #[test]
    fn markets_axis_expands_resolves_and_tags() {
        let spec = SweepSpec::from_toml(
            r#"
[grid]
apps = ["til"]
markets = ["exponential", "volatile"]

[[market]]
name = "volatile"
revocation = "trace"
revocation_times = [3600.0]
price = "steps"
price_times = [0.0, 1800.0]
price_factors = [1.0, 1.5]
"#,
        )
        .unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert!(points[0].cfg.market.is_default());
        assert_eq!(points[0].tag("market"), "exponential");
        assert_eq!(points[1].tag("market"), "volatile");
        assert_eq!(
            points[1].cfg.market.revocation,
            crate::market::RevocationSpec::Trace { times: vec![3600.0] }
        );
        // Unknown names and unknown [[market]] keys are rejected by name.
        let err = SweepSpec::from_toml("[grid]\napps = [\"til\"]\nmarkets = [\"nope\"]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown market nope"), "{err}");
        let err = SweepSpec::from_toml(
            "[grid]\napps = [\"til\"]\n\n[[market]]\nname = \"m\"\nwild = 1\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown key `wild`"), "{err}");
    }

    #[test]
    fn outlooks_axis_expands_resolves_and_tags() {
        let spec = SweepSpec::from_toml(
            r#"
[grid]
apps = ["til"]
outlooks = ["off", "aware"]

[[outlook]]
name = "aware"
horizon = 3600.0
defer = true
"#,
        )
        .unwrap();
        assert_eq!(spec.n_points(), 2);
        let points = spec.expand().unwrap();
        assert!(!points[0].cfg.outlook.enabled);
        assert_eq!(points[0].tag("outlook"), "off");
        assert!(points[1].cfg.outlook.enabled && points[1].cfg.outlook.defer);
        assert_eq!(points[1].tag("outlook"), "aware");
        // Unknown names are rejected; unswept specs stay outlook-off.
        let err = SweepSpec::from_toml("[grid]\napps = [\"til\"]\noutlooks = [\"nope\"]\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown outlook nope"), "{err}");
        let plain = SweepSpec::from_toml("[grid]\napps = [\"til\"]\n").unwrap();
        assert!(plain.outlooks.is_none());
        let p = plain.expand().unwrap();
        assert!(!p[0].cfg.outlook.enabled);
        assert_eq!(p[0].tag("outlook"), "", "no outlook tag when not swept");
    }

    #[test]
    fn unswept_markets_leave_points_and_seeds_untouched() {
        // A spec without a markets axis must expand to the exact same
        // points (default market) and seed schedule as before the axis
        // existed. (Recorded campaigns still refingerprint — the point
        // fingerprint hashes the full SimConfig, which gained the `market`
        // field — so old records recompute once, to identical values.)
        let spec = SweepSpec::from_toml(SPEC).unwrap();
        assert!(spec.markets.is_none());
        let points = spec.expand().unwrap();
        for p in &points {
            assert!(p.cfg.market.is_default());
            assert_eq!(p.tag("market"), "", "no market tag when not swept");
        }
    }

    #[test]
    fn legacy_specs_expand_to_identical_seeds() {
        // The optional axes must not perturb the seed schedule of specs
        // that do not use them (resume-compatibility with old campaigns).
        let spec = SweepSpec::from_toml(SPEC).unwrap();
        let points = spec.expand().unwrap();
        let root = crate::simul::Rng::seeded(spec.seed);
        let mut global = 0u64;
        for p in &points {
            for &s in &p.seeds {
                assert_eq!(s, root.split_seed(global));
                global += 1;
            }
        }
    }
}
