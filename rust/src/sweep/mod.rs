//! The experiment-campaign engine: expands declarative config grids into
//! trial configurations and fans them out across a fixed-size OS-thread
//! worker pool (the same `std::thread` + channel idiom as the in-process FL
//! runtime in [`crate::fl`]).
//!
//! Determinism contract: every trial's [`SimConfig`] — including its RNG
//! seed — is fixed *before* any worker starts, and outcomes are re-assembled
//! in expansion order. Aggregates are therefore bit-identical regardless of
//! worker count or completion order (enforced by `tests/sweep_determinism.rs`
//! and the CI smoke job).
//!
//! Layering: [`spec`] parses `multi-fedls sweep --spec` TOML grids into
//! [`PointSpec`]s; [`run_campaign`] executes them through a shared
//! [`Framework`] stack whose Pre-Scheduling module is backed by one
//! [`EnvCache`] — so each environment's slowdown report is measured once
//! per campaign, not once per trial; [`persist`] records per-point results
//! under `results/` and powers `--resume`. Both
//! [`crate::coordinator::run_trials`] and the `trace::experiments` table
//! drivers are thin layers over the same pool.

pub mod persist;
pub mod spec;

pub use spec::SweepSpec;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::sim::{SimConfig, SimOutcome};
use crate::framework::{EnvCache, Framework};

/// One fully-resolved trial: the index of the campaign point it belongs to
/// and the exact simulator configuration (seed included) to run.
#[derive(Debug, Clone)]
pub struct TrialConfig {
    pub point: usize,
    pub cfg: SimConfig,
}

/// The scalar metrics extracted from one simulated execution.
#[derive(Debug, Clone, Copy)]
pub struct TrialOutcome {
    pub revocations: f64,
    /// FL execution time only (first round start → last round end).
    pub fl_exec_secs: f64,
    /// Whole framework time (provisioning → teardown).
    pub total_secs: f64,
    pub cost: f64,
    pub rounds_completed: u32,
}

impl From<&SimOutcome> for TrialOutcome {
    fn from(o: &SimOutcome) -> TrialOutcome {
        TrialOutcome {
            revocations: o.n_revocations as f64,
            fl_exec_secs: o.fl_exec_secs,
            total_secs: o.total_secs,
            cost: o.total_cost,
            rounds_completed: o.rounds_completed,
        }
    }
}

/// Mean, sample standard deviation, min/max and a 95% confidence interval
/// for one metric over a point's trials.
#[derive(Debug, Clone, Copy)]
pub struct MetricAgg {
    pub n: usize,
    pub mean: f64,
    /// Sample standard deviation (n − 1 denominator); 0 for n < 2.
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    /// Half-width of the normal-approximation 95% CI: `1.96·stddev/√n`
    /// (0 for n < 2).
    pub ci95: f64,
}

impl MetricAgg {
    pub fn from_samples(xs: &[f64]) -> MetricAgg {
        assert!(!xs.is_empty(), "MetricAgg over zero samples");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        let (stddev, ci95) = if n > 1 {
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
            let sd = var.sqrt();
            (sd, 1.96 * sd / (n as f64).sqrt())
        } else {
            (0.0, 0.0)
        };
        MetricAgg { n, mean, stddev, min, max, ci95 }
    }

    /// Render as a JSON object (`{mean, stddev, min, max, ci95}`).
    pub fn json(&self) -> crate::util::Json {
        crate::util::Json::obj()
            .set("mean", self.mean)
            .set("stddev", self.stddev)
            .set("min", self.min)
            .set("max", self.max)
            .set("ci95", self.ci95)
    }
}

/// One grid point of a campaign: a base configuration plus the explicit
/// per-trial seeds. `tags` carries the axis values (app, scenario, …) for
/// output rendering; the engine itself never reads them.
#[derive(Debug, Clone)]
pub struct PointSpec {
    pub tags: Vec<(String, String)>,
    pub cfg: SimConfig,
    pub seeds: Vec<u64>,
}

impl PointSpec {
    /// Look up an axis value by tag name (rendering helper).
    pub fn tag(&self, key: &str) -> &str {
        self.tags
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .unwrap_or("")
    }
}

/// Resolve a worker-count request: 0 = one worker per available core,
/// always clamped to the number of trials.
pub fn effective_jobs(jobs: usize, n_trials: usize) -> usize {
    let jobs = if jobs == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        jobs
    };
    jobs.clamp(1, n_trials.max(1))
}

/// Run `n` independent tasks, `jobs` at a time, over an OS-thread pool,
/// returning results in index order.
///
/// Workers pull the next task index from a shared atomic cursor and report
/// `(index, result)` over a channel; the assembly into the result vector is
/// by index, so completion order cannot influence the output. This is the
/// one worker-pool implementation shared by the sweep trial pool and the
/// workload trial pool ([`crate::workload::run_trials`]).
pub fn run_indexed<T: Send>(
    n: usize,
    jobs: usize,
    task: impl Fn(usize) -> anyhow::Result<T> + Sync,
) -> anyhow::Result<Vec<T>> {
    let jobs = effective_jobs(jobs, n);
    if jobs == 1 {
        return (0..n).map(&task).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<T>)>();
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    for _ in 0..n {
        slots.push(None);
    }
    let run: anyhow::Result<()> = std::thread::scope(|s| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let next = &next;
            let task = &task;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = task(i);
                if tx.send((i, out)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, out) in rx {
            slots[i] = Some(out?);
        }
        Ok(())
    });
    run?;
    Ok(slots.into_iter().map(|s| s.expect("every task reported")).collect())
}

/// Run every trial through the default module stack (no cross-trial
/// sharing). See [`run_pool_with`].
pub fn run_pool(trials: &[TrialConfig], jobs: usize) -> anyhow::Result<Vec<TrialOutcome>> {
    run_pool_with(trials, jobs, &Framework::default_stack())
}

/// Run every trial, `jobs` at a time, through `fw`'s module stack,
/// returning outcomes in input order (see [`run_indexed`]).
pub fn run_pool_with(
    trials: &[TrialConfig],
    jobs: usize,
    fw: &Framework,
) -> anyhow::Result<Vec<TrialOutcome>> {
    run_indexed(trials.len(), jobs, |i| Ok(TrialOutcome::from(&fw.run(&trials[i].cfg)?)))
}

/// Run a whole campaign with a fresh environment cache: each distinct
/// environment's Pre-Scheduling report is measured exactly once and shared
/// across every trial. See [`run_campaign_with`].
pub fn run_campaign(
    points: &[PointSpec],
    jobs: usize,
) -> anyhow::Result<Vec<crate::coordinator::TrialStats>> {
    run_campaign_with(points, jobs, &Framework::with_env_cache(Arc::new(EnvCache::new())))
}

/// Run a whole campaign through `fw`'s module stack: flatten every point's
/// trials, push them through one shared worker pool, and re-group per-point
/// aggregate statistics in point order.
pub fn run_campaign_with(
    points: &[PointSpec],
    jobs: usize,
    fw: &Framework,
) -> anyhow::Result<Vec<crate::coordinator::TrialStats>> {
    run_campaign_streaming(points, jobs, fw, |_, _| Ok(()))
}

/// Like [`run_campaign_with`], but invokes `on_point_done(index, stats)` as
/// soon as *all* of a point's trials have completed (in completion order,
/// not input order), so callers can persist partial campaign progress
/// before the whole campaign — or the process — ends. The returned vector
/// is in point order and bit-identical to [`run_campaign_with`]'s.
pub fn run_campaign_streaming(
    points: &[PointSpec],
    jobs: usize,
    fw: &Framework,
    mut on_point_done: impl FnMut(usize, &crate::coordinator::TrialStats) -> anyhow::Result<()>,
) -> anyhow::Result<Vec<crate::coordinator::TrialStats>> {
    let mut trials = Vec::new();
    // First flattened trial index of each point (a point's trials are
    // contiguous in expansion order).
    let mut point_start = Vec::with_capacity(points.len());
    for (pi, p) in points.iter().enumerate() {
        anyhow::ensure!(!p.seeds.is_empty(), "campaign point {pi} has no trials");
        point_start.push(trials.len());
        for &seed in &p.seeds {
            let mut cfg = p.cfg.clone();
            cfg.seed = seed;
            trials.push(TrialConfig { point: pi, cfg });
        }
    }
    let mut slots: Vec<Option<TrialOutcome>> = vec![None; trials.len()];
    let mut remaining: Vec<usize> = points.iter().map(|p| p.seeds.len()).collect();
    let mut stats: Vec<Option<crate::coordinator::TrialStats>> = vec![None; points.len()];

    // Record one finished trial; when its point is complete, aggregate (in
    // trial input order, so aggregates are independent of completion order)
    // and notify the caller.
    macro_rules! record {
        ($i:expr, $out:expr) => {{
            let i: usize = $i;
            slots[i] = Some($out);
            let pi = trials[i].point;
            remaining[pi] -= 1;
            if remaining[pi] == 0 {
                let outs: Vec<TrialOutcome> = (point_start[pi]
                    ..point_start[pi] + points[pi].seeds.len())
                    .map(|j| slots[j].expect("trial recorded"))
                    .collect();
                let s = crate::coordinator::TrialStats::from_outcomes(&outs);
                on_point_done(pi, &s)?;
                stats[pi] = Some(s);
            }
        }};
    }

    let jobs = effective_jobs(jobs, trials.len());
    if jobs == 1 {
        for i in 0..trials.len() {
            let out = TrialOutcome::from(&fw.run(&trials[i].cfg)?);
            record!(i, out);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<TrialOutcome>)>();
        let run: anyhow::Result<()> = std::thread::scope(|s| {
            for _ in 0..jobs {
                let tx = tx.clone();
                let next = &next;
                let trials = &trials;
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= trials.len() {
                        break;
                    }
                    let out = fw.run(&trials[i].cfg).map(|o| TrialOutcome::from(&o));
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, out) in rx {
                let out = out?;
                record!(i, out);
            }
            Ok(())
        });
        run?;
    }
    Ok(stats.into_iter().map(|s| s.expect("every point finalized")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::Scenario;

    fn outcome(cost: f64) -> TrialOutcome {
        TrialOutcome {
            revocations: cost / 10.0,
            fl_exec_secs: cost * 2.0,
            total_secs: cost * 3.0,
            cost,
            rounds_completed: 10,
        }
    }

    #[test]
    fn metric_agg_hand_computed_three_samples() {
        // Samples 10, 20, 30: mean 20, sample stddev 10 (variance 100),
        // 95% CI half-width 1.96·10/√3 = 11.3160904…
        let a = MetricAgg::from_samples(&[10.0, 20.0, 30.0]);
        assert_eq!(a.n, 3);
        assert!((a.mean - 20.0).abs() < 1e-12);
        assert!((a.stddev - 10.0).abs() < 1e-12);
        assert!((a.min - 10.0).abs() < 1e-12);
        assert!((a.max - 30.0).abs() < 1e-12);
        let expected_ci = 1.96 * 10.0 / 3f64.sqrt();
        assert!((a.ci95 - expected_ci).abs() < 1e-12);
        assert!((a.ci95 - 11.316).abs() < 1e-3);
    }

    #[test]
    fn metric_agg_single_sample_has_zero_spread() {
        let a = MetricAgg::from_samples(&[5.0]);
        assert_eq!(a.n, 1);
        assert_eq!(a.stddev, 0.0);
        assert_eq!(a.ci95, 0.0);
        assert_eq!(a.min, 5.0);
        assert_eq!(a.max, 5.0);
    }

    #[test]
    fn pool_preserves_input_order_across_worker_counts() {
        // A small real campaign: outcomes must line up with their configs no
        // matter how many workers raced over the queue.
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 0);
        cfg.checkpoints_enabled = false;
        let trials: Vec<TrialConfig> = (0..6)
            .map(|i| {
                let mut c = cfg.clone();
                c.seed = 1000 + i;
                TrialConfig { point: i as usize, cfg: c }
            })
            .collect();
        let serial = run_pool(&trials, 1).unwrap();
        let parallel = run_pool(&trials, 4).unwrap();
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
            assert_eq!(a.fl_exec_secs.to_bits(), b.fl_exec_secs.to_bits());
            assert_eq!(a.revocations, b.revocations);
        }
    }

    #[test]
    fn campaign_groups_by_point() {
        let cfg = {
            let mut c = SimConfig::new(apps::til(), Scenario::AllOnDemand, 0);
            c.checkpoints_enabled = false;
            c
        };
        let points = vec![
            PointSpec { tags: vec![], cfg: cfg.clone(), seeds: vec![1, 2] },
            PointSpec { tags: vec![], cfg: cfg.clone(), seeds: vec![3, 4, 5] },
        ];
        let stats = run_campaign(&points, 0).unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].trials, 2);
        assert_eq!(stats[1].trials, 3);
    }

    #[test]
    fn streaming_callback_fires_once_per_completed_point() {
        let cfg = {
            let mut c = SimConfig::new(apps::til(), Scenario::AllOnDemand, 0);
            c.checkpoints_enabled = false;
            c.n_rounds = 2;
            c
        };
        let points = vec![
            PointSpec { tags: vec![], cfg: cfg.clone(), seeds: vec![1, 2] },
            PointSpec { tags: vec![], cfg: cfg.clone(), seeds: vec![3] },
        ];
        let fw = Framework::default_stack();
        let mut seen: Vec<usize> = Vec::new();
        let stats = run_campaign_streaming(&points, 2, &fw, |i, s| {
            assert!(s.trials > 0);
            seen.push(i);
            Ok(())
        })
        .unwrap();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1], "each point finalized exactly once");
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].trials, 2);
        assert_eq!(stats[1].trials, 1);
        // A callback error aborts the campaign instead of being swallowed.
        let err = run_campaign_streaming(&points, 1, &fw, |_, _| anyhow::bail!("disk full"));
        assert!(err.is_err());
    }

    #[test]
    fn effective_jobs_clamps() {
        assert_eq!(effective_jobs(8, 3), 3);
        assert_eq!(effective_jobs(2, 100), 2);
        assert!(effective_jobs(0, 100) >= 1);
        assert_eq!(effective_jobs(1, 0), 1);
    }

    #[test]
    fn outcome_helper_is_consistent() {
        let o = outcome(10.0);
        assert_eq!(o.cost, 10.0);
        assert_eq!(o.total_secs, 30.0);
    }
}
