//! Campaign persistence and `--resume`.
//!
//! Each campaign gets a directory `results/<name>-<fingerprint>/` where
//! `<fingerprint>` hashes everything that determines the campaign's trials
//! (every point's axis tags, full simulator configuration, and seed
//! schedule). Inside, every grid point is recorded as `point-NNNN.toml`
//! (mini-TOML so the offline parser can read it back losslessly — floats
//! round-trip via shortest-representation formatting), and the rendered
//! campaign outputs land next to them as `campaign.json` / `campaign.csv`
//! (the ROADMAP "sweep-level outputs" item).
//!
//! `--resume` loads every recorded point whose per-point fingerprint still
//! matches the spec, runs only the missing points (through the shared
//! environment cache), and re-renders the combined outputs. Because every
//! trial's seed is fixed at expansion time, a resumed campaign is
//! byte-identical to a from-scratch run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::TrialStats;
use crate::framework::{EnvCache, Framework};
use crate::util::tomlmini::{self, Value};

use super::spec::{render_csv, render_json};
use super::{MetricAgg, PointSpec, SweepSpec};

/// FNV-1a over a byte string (same constants as the presched fingerprint).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint of one expanded grid point: axis tags + the full simulator
/// configuration + the trial seed schedule.
pub fn point_fingerprint(point: &PointSpec) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for (k, v) in &point.tags {
        let _ = write!(s, "{k}={v};");
    }
    let _ = write!(s, "cfg={:?};seeds={:?}", point.cfg, point.seeds);
    format!("{:016x}", fnv1a(&s))
}

/// Fingerprint of a whole campaign: the combined point fingerprints.
pub fn campaign_fingerprint(points: &[PointSpec]) -> String {
    let mut s = String::new();
    for p in points {
        s.push_str(&point_fingerprint(p));
        s.push('|');
    }
    format!("{:016x}", fnv1a(&s))
}

/// Fingerprint of one expanded workload point: axis tags + every trial's
/// full job list (configurations, seeds, arrival times) + admission policy.
pub fn workload_point_fingerprint(point: &crate::workload::WorkloadPoint) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for (k, v) in &point.tags {
        let _ = write!(s, "{k}={v};");
    }
    for w in &point.trials {
        let _ = write!(s, "wl={w:?};");
    }
    format!("{:016x}", fnv1a(&s))
}

/// A named `[[metric]]` entry for one aggregate.
fn agg_table(name: &str, agg: &MetricAgg) -> BTreeMap<String, Value> {
    let mut m: BTreeMap<String, Value> = BTreeMap::new();
    m.insert("name".into(), Value::Str(name.into()));
    m.insert("n".into(), Value::Int(agg.n as i64));
    m.insert("mean".into(), Value::Float(agg.mean));
    m.insert("stddev".into(), Value::Float(agg.stddev));
    m.insert("min".into(), Value::Float(agg.min));
    m.insert("max".into(), Value::Float(agg.max));
    m.insert("ci95".into(), Value::Float(agg.ci95));
    m
}

fn read_agg_table(m: &BTreeMap<String, Value>) -> Option<MetricAgg> {
    Some(MetricAgg {
        n: m.get("n")?.as_int()? as usize,
        mean: m.get("mean")?.as_float()?,
        stddev: m.get("stddev")?.as_float()?,
        min: m.get("min")?.as_float()?,
        max: m.get("max")?.as_float()?,
        ci95: m.get("ci95")?.as_float()?,
    })
}

/// Flatten one aggregate into `<prefix>_*` keys of an existing row.
fn flatten_agg(row: &mut BTreeMap<String, Value>, prefix: &str, agg: &MetricAgg) {
    row.insert(format!("{prefix}_n"), Value::Int(agg.n as i64));
    row.insert(format!("{prefix}_mean"), Value::Float(agg.mean));
    row.insert(format!("{prefix}_stddev"), Value::Float(agg.stddev));
    row.insert(format!("{prefix}_min"), Value::Float(agg.min));
    row.insert(format!("{prefix}_max"), Value::Float(agg.max));
    row.insert(format!("{prefix}_ci95"), Value::Float(agg.ci95));
}

fn read_flat_agg(row: &BTreeMap<String, Value>, prefix: &str) -> Option<MetricAgg> {
    Some(MetricAgg {
        n: row.get(&format!("{prefix}_n"))?.as_int()? as usize,
        mean: row.get(&format!("{prefix}_mean"))?.as_float()?,
        stddev: row.get(&format!("{prefix}_stddev"))?.as_float()?,
        min: row.get(&format!("{prefix}_min"))?.as_float()?,
        max: row.get(&format!("{prefix}_max"))?.as_float()?,
        ci95: row.get(&format!("{prefix}_ci95"))?.as_float()?,
    })
}

/// Directory-safe form of a campaign name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect()
}

/// One campaign's on-disk store.
pub struct CampaignStore {
    dir: PathBuf,
    point_fps: Vec<String>,
}

impl CampaignStore {
    /// Open (creating if needed) the store for this spec + expansion under
    /// `results_dir`.
    pub fn open(
        results_dir: &Path,
        spec: &SweepSpec,
        points: &[PointSpec],
    ) -> anyhow::Result<CampaignStore> {
        Self::open_raw(results_dir, &spec.name, points.iter().map(point_fingerprint).collect())
    }

    /// Open a store for a workload campaign (per-point fingerprints over the
    /// full seeded trial set).
    pub fn open_workload(
        results_dir: &Path,
        spec: &crate::workload::WorkloadSpec,
        points: &[crate::workload::WorkloadPoint],
    ) -> anyhow::Result<CampaignStore> {
        Self::open_raw(
            results_dir,
            &spec.name,
            points.iter().map(workload_point_fingerprint).collect(),
        )
    }

    fn open_raw(
        results_dir: &Path,
        name: &str,
        point_fps: Vec<String>,
    ) -> anyhow::Result<CampaignStore> {
        let mut combined = String::new();
        for fp in &point_fps {
            combined.push_str(fp);
            combined.push('|');
        }
        let dir =
            results_dir.join(format!("{}-{:016x}", sanitize(name), fnv1a(&combined)));
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        Ok(CampaignStore { dir, point_fps })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn point_path(&self, idx: usize) -> PathBuf {
        self.dir.join(format!("point-{idx:04}.toml"))
    }

    /// Where one workload point's telemetry trace lands (JSONL; written only
    /// when some job has `[telemetry]` enabled).
    pub fn trace_path(&self, idx: usize) -> PathBuf {
        self.dir.join(format!("trace-{idx:04}.jsonl"))
    }

    /// Record one point's aggregates.
    pub fn save_point(
        &self,
        idx: usize,
        point: &PointSpec,
        stats: &TrialStats,
    ) -> anyhow::Result<()> {
        let mut root: BTreeMap<String, Value> = BTreeMap::new();
        root.insert("schema".into(), Value::Int(1));
        root.insert("point".into(), Value::Int(idx as i64));
        root.insert("fingerprint".into(), Value::Str(self.point_fps[idx].clone()));
        root.insert("trials".into(), Value::Int(stats.trials as i64));
        let mut tags: BTreeMap<String, Value> = BTreeMap::new();
        for (k, v) in &point.tags {
            tags.insert(k.clone(), Value::Str(v.clone()));
        }
        root.insert("tags".into(), Value::Table(tags));
        let mut metrics: Vec<BTreeMap<String, Value>> = Vec::new();
        for (name, agg) in [
            ("revocations", &stats.revocations),
            ("fl_exec_secs", &stats.exec_secs),
            ("total_secs", &stats.total_secs),
            ("cost", &stats.cost),
        ] {
            let mut m: BTreeMap<String, Value> = BTreeMap::new();
            m.insert("name".into(), Value::Str(name.into()));
            m.insert("n".into(), Value::Int(agg.n as i64));
            m.insert("mean".into(), Value::Float(agg.mean));
            m.insert("stddev".into(), Value::Float(agg.stddev));
            m.insert("min".into(), Value::Float(agg.min));
            m.insert("max".into(), Value::Float(agg.max));
            m.insert("ci95".into(), Value::Float(agg.ci95));
            metrics.push(m);
        }
        root.insert("metric".into(), Value::TableArray(metrics));
        let path = self.point_path(idx);
        std::fs::write(&path, tomlmini::write(&root))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load one recorded point. Returns `None` when the file is missing,
    /// unreadable, or stale (its fingerprint no longer matches the spec) —
    /// in all of which cases the caller recomputes the point.
    pub fn load_point(&self, idx: usize) -> Option<TrialStats> {
        let expected_fp = self.point_fps.get(idx)?;
        let text = std::fs::read_to_string(self.point_path(idx)).ok()?;
        let root = tomlmini::parse(&text).ok()?;
        if root.get("fingerprint")?.as_str()? != expected_fp.as_str() {
            return None;
        }
        let trials = root.get("trials")?.as_int()?;
        if trials <= 0 {
            return None;
        }
        let mut by_name: BTreeMap<String, MetricAgg> = BTreeMap::new();
        for m in root.get("metric")?.as_table_array()? {
            let name = m.get("name")?.as_str()?.to_string();
            let agg = MetricAgg {
                n: m.get("n")?.as_int()? as usize,
                mean: m.get("mean")?.as_float()?,
                stddev: m.get("stddev")?.as_float()?,
                min: m.get("min")?.as_float()?,
                max: m.get("max")?.as_float()?,
                ci95: m.get("ci95")?.as_float()?,
            };
            by_name.insert(name, agg);
        }
        Some(TrialStats {
            trials: trials as usize,
            revocations: *by_name.get("revocations")?,
            exec_secs: *by_name.get("fl_exec_secs")?,
            total_secs: *by_name.get("total_secs")?,
            cost: *by_name.get("cost")?,
        })
    }

    /// Record one workload point's aggregates, including the per-job
    /// completion/wait/cost/revocation metrics.
    pub fn save_workload_point(
        &self,
        idx: usize,
        point: &crate::workload::WorkloadPoint,
        agg: &crate::workload::WorkloadAgg,
    ) -> anyhow::Result<()> {
        let mut root: BTreeMap<String, Value> = BTreeMap::new();
        root.insert("schema".into(), Value::Int(2));
        root.insert("point".into(), Value::Int(idx as i64));
        root.insert("fingerprint".into(), Value::Str(self.point_fps[idx].clone()));
        root.insert("trials".into(), Value::Int(agg.trials as i64));
        let mut tags: BTreeMap<String, Value> = BTreeMap::new();
        for (k, v) in &point.tags {
            tags.insert(k.clone(), Value::Str(v.clone()));
        }
        root.insert("tags".into(), Value::Table(tags));
        let mut metrics: Vec<BTreeMap<String, Value>> = Vec::new();
        for (name, a) in [
            ("makespan_secs", &agg.makespan),
            ("mean_wait_secs", &agg.mean_wait),
            ("total_cost", &agg.total_cost),
            ("admitted", &agg.admitted),
            ("queued", &agg.queued),
            ("rejected", &agg.rejected),
            ("preemptions", &agg.preemptions),
        ] {
            metrics.push(agg_table(name, a));
        }
        root.insert("metric".into(), Value::TableArray(metrics));
        let mut job_rows: Vec<BTreeMap<String, Value>> = Vec::new();
        for j in &agg.jobs {
            let mut row: BTreeMap<String, Value> = BTreeMap::new();
            row.insert("name".into(), Value::Str(j.name.clone()));
            for (m, a) in [
                ("wait", &j.wait),
                ("completion", &j.completion),
                ("cost", &j.cost),
                ("revocations", &j.revocations),
                ("preemptions", &j.preemptions),
            ] {
                flatten_agg(&mut row, m, a);
            }
            job_rows.push(row);
        }
        root.insert("job".into(), Value::TableArray(job_rows));
        let path = self.point_path(idx);
        std::fs::write(&path, tomlmini::write(&root))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load one recorded workload point (same staleness rules as
    /// [`Self::load_point`]).
    pub fn load_workload_point(&self, idx: usize) -> Option<crate::workload::WorkloadAgg> {
        let expected_fp = self.point_fps.get(idx)?;
        let text = std::fs::read_to_string(self.point_path(idx)).ok()?;
        let root = tomlmini::parse(&text).ok()?;
        if root.get("fingerprint")?.as_str()? != expected_fp.as_str() {
            return None;
        }
        let trials = root.get("trials")?.as_int()?;
        if trials <= 0 {
            return None;
        }
        let mut by_name: BTreeMap<String, MetricAgg> = BTreeMap::new();
        for m in root.get("metric")?.as_table_array()? {
            let name = m.get("name")?.as_str()?.to_string();
            by_name.insert(name, read_agg_table(m)?);
        }
        let mut jobs = Vec::new();
        for row in root.get("job")?.as_table_array()? {
            jobs.push(crate::workload::JobAgg {
                name: row.get("name")?.as_str()?.to_string(),
                wait: read_flat_agg(row, "wait")?,
                completion: read_flat_agg(row, "completion")?,
                cost: read_flat_agg(row, "cost")?,
                revocations: read_flat_agg(row, "revocations")?,
                preemptions: read_flat_agg(row, "preemptions")?,
            });
        }
        Some(crate::workload::WorkloadAgg {
            trials: trials as usize,
            makespan: *by_name.get("makespan_secs")?,
            mean_wait: *by_name.get("mean_wait_secs")?,
            total_cost: *by_name.get("total_cost")?,
            admitted: *by_name.get("admitted")?,
            queued: *by_name.get("queued")?,
            rejected: *by_name.get("rejected")?,
            preemptions: *by_name.get("preemptions")?,
            jobs,
        })
    }

    /// Write the rendered campaign-level outputs (`campaign.json`,
    /// `campaign.csv`), returning their paths.
    pub fn write_campaign_outputs(
        &self,
        spec: &SweepSpec,
        points: &[PointSpec],
        stats: &[TrialStats],
    ) -> anyhow::Result<(PathBuf, PathBuf)> {
        let json_path = self.dir.join("campaign.json");
        let csv_path = self.dir.join("campaign.csv");
        let mut json = render_json(spec, points, stats).to_string_pretty();
        json.push('\n');
        std::fs::write(&json_path, json)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", json_path.display()))?;
        std::fs::write(&csv_path, render_csv(points, stats))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

/// Run a campaign with persistence: when `resume` is set, recorded points
/// are loaded instead of recomputed; everything else runs through the
/// shared-cache worker pool with each point's record written *as soon as
/// its trials complete* — so a killed campaign leaves every finished point
/// on disk for the next `--resume`. Finally the campaign JSON/CSV are
/// (re)written. Returns the full per-point stats plus the campaign
/// directory.
pub fn run_campaign_persistent(
    spec: &SweepSpec,
    points: &[PointSpec],
    jobs: usize,
    results_dir: &Path,
    resume: bool,
) -> anyhow::Result<(Vec<TrialStats>, PathBuf)> {
    let store = CampaignStore::open(results_dir, spec, points)?;
    let mut stats: Vec<Option<TrialStats>> = vec![None; points.len()];
    if resume {
        for (i, slot) in stats.iter_mut().enumerate() {
            *slot = store.load_point(i);
        }
    }
    let missing: Vec<usize> =
        (0..points.len()).filter(|&i| stats[i].is_none()).collect();
    if !missing.is_empty() {
        let subset: Vec<PointSpec> = missing.iter().map(|&i| points[i].clone()).collect();
        let fw = Framework::with_env_cache(Arc::new(EnvCache::new()));
        let computed = super::run_campaign_streaming(&subset, jobs, &fw, |sub_idx, s| {
            // Record immediately (completion order): a killed or failing
            // campaign keeps every finished point.
            store.save_point(missing[sub_idx], &points[missing[sub_idx]], s)
        })?;
        for (&i, s) in missing.iter().zip(computed) {
            stats[i] = Some(s);
        }
    }
    let stats: Vec<TrialStats> =
        stats.into_iter().map(|s| s.expect("every point loaded or computed")).collect();
    store.write_campaign_outputs(spec, points, &stats)?;
    Ok((stats, store.dir().to_path_buf()))
}

/// Workload analogue of [`run_campaign_persistent`]: recorded points are
/// loaded on `--resume`; the missing points' trials are flattened into one
/// shared worker pool (parallelism spans points) and every recomputed point
/// is recorded before the campaign JSON/CSV are (re)written.
pub fn run_workload_campaign_persistent(
    spec: &crate::workload::WorkloadSpec,
    points: &[crate::workload::WorkloadPoint],
    jobs: usize,
    results_dir: &Path,
    resume: bool,
) -> anyhow::Result<(Vec<crate::workload::WorkloadAgg>, PathBuf)> {
    let store = CampaignStore::open_workload(results_dir, spec, points)?;
    let mut aggs: Vec<Option<crate::workload::WorkloadAgg>> = vec![None; points.len()];
    if resume {
        for (i, slot) in aggs.iter_mut().enumerate() {
            *slot = store.load_workload_point(i);
        }
    }
    let missing: Vec<usize> = (0..points.len()).filter(|&i| aggs[i].is_none()).collect();
    if !missing.is_empty() {
        let cache = Arc::new(EnvCache::new());
        let flat: Vec<crate::workload::Workload> = missing
            .iter()
            .flat_map(|&i| points[i].trials.iter().cloned())
            .collect();
        let outs = crate::workload::run_trials(&flat, jobs, &cache)?;
        let mut idx = 0;
        for &i in &missing {
            let n = points[i].trials.len();
            let point_outs = &outs[idx..idx + n];
            idx += n;
            let agg = crate::workload::WorkloadAgg::from_outcomes(point_outs);
            store.save_workload_point(i, &points[i], &agg)?;
            // Telemetry trace (jobs with `[telemetry]` enabled): one JSONL
            // file per recomputed point, trials concatenated in trial order.
            let mut text = String::new();
            for (ti, out) in point_outs.iter().enumerate() {
                text.push_str(&crate::telemetry::trace_jsonl(i, ti, &out.trace));
            }
            if !text.is_empty() {
                let path = store.trace_path(i);
                std::fs::write(&path, text)
                    .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
            }
            aggs[i] = Some(agg);
        }
    }
    let aggs: Vec<crate::workload::WorkloadAgg> =
        aggs.into_iter().map(|a| a.expect("every point loaded or computed")).collect();
    let json_path = store.dir.join("campaign.json");
    let csv_path = store.dir.join("campaign.csv");
    let mut json = crate::workload::spec::render_json(spec, points, &aggs).to_string_pretty();
    json.push('\n');
    std::fs::write(&json_path, json)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", json_path.display()))?;
    std::fs::write(&csv_path, crate::workload::spec::render_csv(points, &aggs))
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", csv_path.display()))?;
    Ok((aggs, store.dir().to_path_buf()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::TrialOutcome;

    fn spec_and_points() -> (SweepSpec, Vec<PointSpec>) {
        let spec = SweepSpec::from_toml(
            "name = \"unit\"\ntrials = 2\nrounds = 5\n[grid]\napps = [\"til\"]\n",
        )
        .unwrap();
        let points = spec.expand().unwrap();
        (spec, points)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mfls-persist-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fake_stats() -> TrialStats {
        // Deliberately awkward floats: 0.1 + 0.2 etc. must survive the
        // TOML round trip bit-for-bit.
        let outs = [0.1f64 + 0.2, 1.0 / 3.0, 15.0]
            .iter()
            .map(|&c| TrialOutcome {
                revocations: c / 7.0,
                fl_exec_secs: c * std::f64::consts::PI,
                total_secs: c * 3.0,
                cost: c,
                rounds_completed: 5,
            })
            .collect::<Vec<_>>();
        TrialStats::from_outcomes(&outs)
    }

    #[test]
    fn point_round_trip_is_bit_exact() {
        let (spec, points) = spec_and_points();
        let dir = tmpdir("roundtrip");
        let store = CampaignStore::open(&dir, &spec, &points).unwrap();
        let stats = fake_stats();
        store.save_point(0, &points[0], &stats).unwrap();
        let loaded = store.load_point(0).expect("fresh record");
        assert_eq!(loaded.trials, stats.trials);
        for (a, b) in [
            (&loaded.revocations, &stats.revocations),
            (&loaded.exec_secs, &stats.exec_secs),
            (&loaded.total_secs, &stats.total_secs),
            (&loaded.cost, &stats.cost),
        ] {
            assert_eq!(a.n, b.n);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.stddev.to_bits(), b.stddev.to_bits());
            assert_eq!(a.min.to_bits(), b.min.to_bits());
            assert_eq!(a.max.to_bits(), b.max.to_bits());
            assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_fingerprint_is_ignored() {
        let (spec, points) = spec_and_points();
        let dir = tmpdir("stale");
        let store = CampaignStore::open(&dir, &spec, &points).unwrap();
        store.save_point(0, &points[0], &fake_stats()).unwrap();
        // Corrupt the fingerprint → the record must be treated as missing.
        let path = store.point_path(0);
        let text = std::fs::read_to_string(&path).unwrap();
        let text = text.replace(&point_fingerprint(&points[0]), "0000000000000000");
        std::fs::write(&path, text).unwrap();
        assert!(store.load_point(0).is_none());
        assert!(store.load_point(1).is_none(), "never-written point is missing");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_tracks_spec_changes() {
        let (_, points) = spec_and_points();
        let other = SweepSpec::from_toml(
            "name = \"unit\"\ntrials = 2\nrounds = 6\n[grid]\napps = [\"til\"]\n",
        )
        .unwrap();
        let other_points = other.expand().unwrap();
        assert_ne!(campaign_fingerprint(&points), campaign_fingerprint(&other_points));
        assert_ne!(point_fingerprint(&points[0]), point_fingerprint(&other_points[0]));
    }

    #[test]
    fn workload_point_round_trip_is_bit_exact() {
        let spec = crate::workload::WorkloadSpec::from_toml(
            "name = \"wl-unit\"\ntrials = 3\nseed = 4\n[[job]]\napp = \"til\"\nrounds = 2\ncount = 2\n",
        )
        .unwrap();
        let points = spec.expand().unwrap();
        let dir = tmpdir("wl-roundtrip");
        let store = CampaignStore::open_workload(&dir, &spec, &points).unwrap();
        // Synthetic aggregates with awkward floats (never run the engine).
        let mk = |x: f64| MetricAgg::from_samples(&[x, x * std::f64::consts::PI, 0.1 + 0.2]);
        let agg = crate::workload::WorkloadAgg {
            trials: 3,
            makespan: mk(100.0),
            mean_wait: mk(1.0),
            total_cost: mk(3.5),
            admitted: mk(2.0),
            queued: mk(0.0),
            rejected: mk(0.0),
            preemptions: mk(1.0),
            jobs: vec![crate::workload::JobAgg {
                name: "til-0".into(),
                wait: mk(0.5),
                completion: mk(900.0),
                cost: mk(1.75),
                revocations: mk(0.0),
                preemptions: mk(1.0),
            }],
        };
        store.save_workload_point(0, &points[0], &agg).unwrap();
        let loaded = store.load_workload_point(0).expect("fresh record");
        assert_eq!(loaded.trials, 3);
        for (a, b) in [
            (&loaded.makespan, &agg.makespan),
            (&loaded.mean_wait, &agg.mean_wait),
            (&loaded.total_cost, &agg.total_cost),
            (&loaded.admitted, &agg.admitted),
            (&loaded.preemptions, &agg.preemptions),
        ] {
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
        }
        assert_eq!(loaded.jobs.len(), 1);
        assert_eq!(loaded.jobs[0].name, "til-0");
        assert_eq!(loaded.jobs[0].completion.mean.to_bits(), agg.jobs[0].completion.mean.to_bits());
        // A different expansion (other seed) must not resolve the record.
        let other = crate::workload::WorkloadSpec::from_toml(
            "name = \"wl-unit\"\ntrials = 3\nseed = 5\n[[job]]\napp = \"til\"\nrounds = 2\ncount = 2\n",
        )
        .unwrap();
        let other_points = other.expand().unwrap();
        assert_ne!(
            workload_point_fingerprint(&points[0]),
            workload_point_fingerprint(&other_points[0])
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sanitize_keeps_names_path_safe() {
        assert_eq!(sanitize("til failures/5.6"), "til-failures-5-6");
        assert_eq!(sanitize("ok-name_2"), "ok-name_2");
    }
}
