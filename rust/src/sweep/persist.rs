//! Campaign persistence and `--resume`.
//!
//! Each campaign gets a directory `results/<name>-<fingerprint>/` where
//! `<fingerprint>` hashes everything that determines the campaign's trials
//! (every point's axis tags, full simulator configuration, and seed
//! schedule). Inside, every grid point is recorded as `point-NNNN.toml`
//! (mini-TOML so the offline parser can read it back losslessly — floats
//! round-trip via shortest-representation formatting), and the rendered
//! campaign outputs land next to them as `campaign.json` / `campaign.csv`
//! (the ROADMAP "sweep-level outputs" item).
//!
//! `--resume` loads every recorded point whose per-point fingerprint still
//! matches the spec, runs only the missing points (through the shared
//! environment cache), and re-renders the combined outputs. Because every
//! trial's seed is fixed at expansion time, a resumed campaign is
//! byte-identical to a from-scratch run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::TrialStats;
use crate::framework::{EnvCache, Framework};
use crate::util::tomlmini::{self, Value};

use super::spec::{render_csv, render_json};
use super::{MetricAgg, PointSpec, SweepSpec};

/// FNV-1a over a byte string (same constants as the presched fingerprint).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fingerprint of one expanded grid point: axis tags + the full simulator
/// configuration + the trial seed schedule.
pub fn point_fingerprint(point: &PointSpec) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    for (k, v) in &point.tags {
        let _ = write!(s, "{k}={v};");
    }
    let _ = write!(s, "cfg={:?};seeds={:?}", point.cfg, point.seeds);
    format!("{:016x}", fnv1a(&s))
}

/// Fingerprint of a whole campaign: the combined point fingerprints.
pub fn campaign_fingerprint(points: &[PointSpec]) -> String {
    let mut s = String::new();
    for p in points {
        s.push_str(&point_fingerprint(p));
        s.push('|');
    }
    format!("{:016x}", fnv1a(&s))
}

/// Directory-safe form of a campaign name.
fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
        .collect()
}

/// One campaign's on-disk store.
pub struct CampaignStore {
    dir: PathBuf,
    point_fps: Vec<String>,
}

impl CampaignStore {
    /// Open (creating if needed) the store for this spec + expansion under
    /// `results_dir`.
    pub fn open(
        results_dir: &Path,
        spec: &SweepSpec,
        points: &[PointSpec],
    ) -> anyhow::Result<CampaignStore> {
        let dir = results_dir
            .join(format!("{}-{}", sanitize(&spec.name), campaign_fingerprint(points)));
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", dir.display()))?;
        let point_fps = points.iter().map(point_fingerprint).collect();
        Ok(CampaignStore { dir, point_fps })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn point_path(&self, idx: usize) -> PathBuf {
        self.dir.join(format!("point-{idx:04}.toml"))
    }

    /// Record one point's aggregates.
    pub fn save_point(
        &self,
        idx: usize,
        point: &PointSpec,
        stats: &TrialStats,
    ) -> anyhow::Result<()> {
        let mut root: BTreeMap<String, Value> = BTreeMap::new();
        root.insert("schema".into(), Value::Int(1));
        root.insert("point".into(), Value::Int(idx as i64));
        root.insert("fingerprint".into(), Value::Str(self.point_fps[idx].clone()));
        root.insert("trials".into(), Value::Int(stats.trials as i64));
        let mut tags: BTreeMap<String, Value> = BTreeMap::new();
        for (k, v) in &point.tags {
            tags.insert(k.clone(), Value::Str(v.clone()));
        }
        root.insert("tags".into(), Value::Table(tags));
        let mut metrics: Vec<BTreeMap<String, Value>> = Vec::new();
        for (name, agg) in [
            ("revocations", &stats.revocations),
            ("fl_exec_secs", &stats.exec_secs),
            ("total_secs", &stats.total_secs),
            ("cost", &stats.cost),
        ] {
            let mut m: BTreeMap<String, Value> = BTreeMap::new();
            m.insert("name".into(), Value::Str(name.into()));
            m.insert("n".into(), Value::Int(agg.n as i64));
            m.insert("mean".into(), Value::Float(agg.mean));
            m.insert("stddev".into(), Value::Float(agg.stddev));
            m.insert("min".into(), Value::Float(agg.min));
            m.insert("max".into(), Value::Float(agg.max));
            m.insert("ci95".into(), Value::Float(agg.ci95));
            metrics.push(m);
        }
        root.insert("metric".into(), Value::TableArray(metrics));
        let path = self.point_path(idx);
        std::fs::write(&path, tomlmini::write(&root))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))?;
        Ok(())
    }

    /// Load one recorded point. Returns `None` when the file is missing,
    /// unreadable, or stale (its fingerprint no longer matches the spec) —
    /// in all of which cases the caller recomputes the point.
    pub fn load_point(&self, idx: usize) -> Option<TrialStats> {
        let expected_fp = self.point_fps.get(idx)?;
        let text = std::fs::read_to_string(self.point_path(idx)).ok()?;
        let root = tomlmini::parse(&text).ok()?;
        if root.get("fingerprint")?.as_str()? != expected_fp.as_str() {
            return None;
        }
        let trials = root.get("trials")?.as_int()?;
        if trials <= 0 {
            return None;
        }
        let mut by_name: BTreeMap<String, MetricAgg> = BTreeMap::new();
        for m in root.get("metric")?.as_table_array()? {
            let name = m.get("name")?.as_str()?.to_string();
            let agg = MetricAgg {
                n: m.get("n")?.as_int()? as usize,
                mean: m.get("mean")?.as_float()?,
                stddev: m.get("stddev")?.as_float()?,
                min: m.get("min")?.as_float()?,
                max: m.get("max")?.as_float()?,
                ci95: m.get("ci95")?.as_float()?,
            };
            by_name.insert(name, agg);
        }
        Some(TrialStats {
            trials: trials as usize,
            revocations: *by_name.get("revocations")?,
            exec_secs: *by_name.get("fl_exec_secs")?,
            total_secs: *by_name.get("total_secs")?,
            cost: *by_name.get("cost")?,
        })
    }

    /// Write the rendered campaign-level outputs (`campaign.json`,
    /// `campaign.csv`), returning their paths.
    pub fn write_campaign_outputs(
        &self,
        spec: &SweepSpec,
        points: &[PointSpec],
        stats: &[TrialStats],
    ) -> anyhow::Result<(PathBuf, PathBuf)> {
        let json_path = self.dir.join("campaign.json");
        let csv_path = self.dir.join("campaign.csv");
        let mut json = render_json(spec, points, stats).to_string_pretty();
        json.push('\n');
        std::fs::write(&json_path, json)
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", json_path.display()))?;
        std::fs::write(&csv_path, render_csv(points, stats))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", csv_path.display()))?;
        Ok((json_path, csv_path))
    }
}

/// Run a campaign with persistence: when `resume` is set, recorded points
/// are loaded instead of recomputed; everything else runs through the
/// shared-cache worker pool with each point's record written *as soon as
/// its trials complete* — so a killed campaign leaves every finished point
/// on disk for the next `--resume`. Finally the campaign JSON/CSV are
/// (re)written. Returns the full per-point stats plus the campaign
/// directory.
pub fn run_campaign_persistent(
    spec: &SweepSpec,
    points: &[PointSpec],
    jobs: usize,
    results_dir: &Path,
    resume: bool,
) -> anyhow::Result<(Vec<TrialStats>, PathBuf)> {
    let store = CampaignStore::open(results_dir, spec, points)?;
    let mut stats: Vec<Option<TrialStats>> = vec![None; points.len()];
    if resume {
        for (i, slot) in stats.iter_mut().enumerate() {
            *slot = store.load_point(i);
        }
    }
    let missing: Vec<usize> =
        (0..points.len()).filter(|&i| stats[i].is_none()).collect();
    if !missing.is_empty() {
        let subset: Vec<PointSpec> = missing.iter().map(|&i| points[i].clone()).collect();
        let fw = Framework::with_env_cache(Arc::new(EnvCache::new()));
        let computed = super::run_campaign_streaming(&subset, jobs, &fw, |sub_idx, s| {
            // Record immediately (completion order): a killed or failing
            // campaign keeps every finished point.
            store.save_point(missing[sub_idx], &points[missing[sub_idx]], s)
        })?;
        for (&i, s) in missing.iter().zip(computed) {
            stats[i] = Some(s);
        }
    }
    let stats: Vec<TrialStats> =
        stats.into_iter().map(|s| s.expect("every point loaded or computed")).collect();
    store.write_campaign_outputs(spec, points, &stats)?;
    Ok((stats, store.dir().to_path_buf()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::TrialOutcome;

    fn spec_and_points() -> (SweepSpec, Vec<PointSpec>) {
        let spec = SweepSpec::from_toml(
            "name = \"unit\"\ntrials = 2\nrounds = 5\n[grid]\napps = [\"til\"]\n",
        )
        .unwrap();
        let points = spec.expand().unwrap();
        (spec, points)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mfls-persist-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn fake_stats() -> TrialStats {
        // Deliberately awkward floats: 0.1 + 0.2 etc. must survive the
        // TOML round trip bit-for-bit.
        let outs = [0.1f64 + 0.2, 1.0 / 3.0, 15.0]
            .iter()
            .map(|&c| TrialOutcome {
                revocations: c / 7.0,
                fl_exec_secs: c * std::f64::consts::PI,
                total_secs: c * 3.0,
                cost: c,
                rounds_completed: 5,
            })
            .collect::<Vec<_>>();
        TrialStats::from_outcomes(&outs)
    }

    #[test]
    fn point_round_trip_is_bit_exact() {
        let (spec, points) = spec_and_points();
        let dir = tmpdir("roundtrip");
        let store = CampaignStore::open(&dir, &spec, &points).unwrap();
        let stats = fake_stats();
        store.save_point(0, &points[0], &stats).unwrap();
        let loaded = store.load_point(0).expect("fresh record");
        assert_eq!(loaded.trials, stats.trials);
        for (a, b) in [
            (&loaded.revocations, &stats.revocations),
            (&loaded.exec_secs, &stats.exec_secs),
            (&loaded.total_secs, &stats.total_secs),
            (&loaded.cost, &stats.cost),
        ] {
            assert_eq!(a.n, b.n);
            assert_eq!(a.mean.to_bits(), b.mean.to_bits());
            assert_eq!(a.stddev.to_bits(), b.stddev.to_bits());
            assert_eq!(a.min.to_bits(), b.min.to_bits());
            assert_eq!(a.max.to_bits(), b.max.to_bits());
            assert_eq!(a.ci95.to_bits(), b.ci95.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_fingerprint_is_ignored() {
        let (spec, points) = spec_and_points();
        let dir = tmpdir("stale");
        let store = CampaignStore::open(&dir, &spec, &points).unwrap();
        store.save_point(0, &points[0], &fake_stats()).unwrap();
        // Corrupt the fingerprint → the record must be treated as missing.
        let path = store.point_path(0);
        let text = std::fs::read_to_string(&path).unwrap();
        let text = text.replace(&point_fingerprint(&points[0]), "0000000000000000");
        std::fs::write(&path, text).unwrap();
        assert!(store.load_point(0).is_none());
        assert!(store.load_point(1).is_none(), "never-written point is missing");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_tracks_spec_changes() {
        let (_, points) = spec_and_points();
        let other = SweepSpec::from_toml(
            "name = \"unit\"\ntrials = 2\nrounds = 6\n[grid]\napps = [\"til\"]\n",
        )
        .unwrap();
        let other_points = other.expand().unwrap();
        assert_ne!(campaign_fingerprint(&points), campaign_fingerprint(&other_points));
        assert_ne!(point_fingerprint(&points[0]), point_fingerprint(&other_points[0]));
    }

    #[test]
    fn sanitize_keeps_names_path_safe() {
        assert_eq!(sanitize("til failures/5.6"), "til-failures-5-6");
        assert_eq!(sanitize("ok-name_2"), "ok-name_2");
    }
}
