//! Experiment drivers: one function per table/figure in the paper's §5,
//! shared by the `cargo bench` binaries and the CLI's `experiment`
//! subcommand. Each prints the paper-format rows and returns machine-usable
//! numbers (also exposed as JSON for EXPERIMENTS.md).

pub mod experiments;

pub use experiments::*;
