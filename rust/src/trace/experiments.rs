//! Reproduction drivers for every table and figure in §5 of the paper.

use crate::apps;
use crate::cloud::{tables, Market};
use crate::cloudsim::{MultiCloud, RevocationModel};
use crate::coordinator::{run_trials, Scenario, SimConfig, TrialStats};
use crate::dynsched::DynSchedPolicy;
use crate::mapping::problem::MappingProblem;
use crate::presched::PreScheduler;
use crate::simul::SimTime;
use crate::sweep::{self, PointSpec};
use crate::util::bench::Table;
use crate::util::Json;

/// Rounds used for the long-running TIL failure/checkpoint experiments
/// (§5.5 "the number of rounds of the application was increased"; 80 rounds
/// reproduces the ≈3 h executions of Fig. 2 / Tables 5–6).
pub const TIL_EXTENDED_ROUNDS: u32 = 80;

/// The paper's tables use 3-run averages.
pub const TRIALS: usize = 3;

fn cloudlab_sim() -> MultiCloud {
    MultiCloud::new(
        tables::cloudlab(),
        tables::cloudlab_ground_truth(),
        RevocationModel::none(),
        1,
    )
}

/// Table 3: execution slowdowns of every VM type (dummy TIL client, two
/// rounds, baseline vm121).
pub fn table3() -> (Table, Json) {
    let mc = cloudlab_sim();
    let report = PreScheduler::new(&mc).measure_defaults();
    let mut t = Table::new(
        "Table 3 — execution slowdowns (dummy app, baseline vm121)",
        &["Cloud", "Region", "VM", "Train r1", "Train r2", "Test r1", "Test r2", "Slowdown"],
    );
    let mut rows = Vec::new();
    let mut vms: Vec<_> = mc.catalog.vm_ids().collect();
    vms.sort_by_key(|&v| mc.catalog.vm(v).id.clone());
    for vm in vms {
        let spec = mc.catalog.vm(vm);
        let region = mc.catalog.region(spec.region);
        let provider = mc.catalog.provider(region.provider);
        let d = report.dummy_runs[&vm];
        let sl = report.sl_inst(vm);
        t.row(&[
            provider.name.clone(),
            region.name.clone(),
            spec.id.clone(),
            format!("{:.2}", d.train_r1),
            format!("{:.2}", d.train_r2),
            format!("{:.2}", d.test_r1),
            format!("{:.2}", d.test_r2),
            format!("{sl:.3}"),
        ]);
        rows.push(Json::obj().set("vm", spec.id.clone()).set("slowdown", sl));
    }
    (t, Json::obj().set("table", "3").set("rows", Json::Arr(rows)))
}

/// Table 4: communication slowdowns of every region pair (2 GB train + 1 GB
/// test messages, baseline APT–APT).
pub fn table4() -> (Table, Json) {
    let mc = cloudlab_sim();
    let report = PreScheduler::new(&mc).measure_defaults();
    let mut t = Table::new(
        "Table 4 — communication slowdowns (baseline APT–APT)",
        &["Pair of regions", "Training (s)", "Test (s)", "Slowdown"],
    );
    let mut rows = Vec::new();
    let mut pairs: Vec<_> = report.comm_runs.keys().copied().collect();
    pairs.sort();
    for (a, b) in pairs {
        let c = report.comm_runs[&(a, b)];
        let sl = report.sl_comm(a, b);
        let name = format!(
            "{}-{}",
            mc.catalog.region(a).name,
            mc.catalog.region(b).name
        );
        t.row(&[
            name.clone(),
            format!("{:.2}", c.train_secs),
            format!("{:.2}", c.test_secs),
            format!("{sl:.3}"),
        ]);
        rows.push(Json::obj().set("pair", name).set("slowdown", sl));
    }
    (t, Json::obj().set("table", "4").set("rows", Json::Arr(rows)))
}

/// §5.4 validation: Initial Mapping prediction vs simulated execution for
/// the 10-round TIL job on on-demand VMs.
pub fn validation_5_4() -> (Table, Json) {
    let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
    cfg.checkpoints_enabled = false;
    let out = crate::coordinator::simulate(&cfg).expect("simulation");
    let predicted_10 = out.predicted_round_makespan * 10.0;
    let mut t = Table::new(
        "§5.4 — Initial Mapping validation (TIL, 10 rounds, on-demand)",
        &["Quantity", "Model prediction", "Simulated execution", "Paper (predicted/measured)"],
    );
    t.row(&[
        "FL execution time".into(),
        SimTime::from_secs(predicted_10).hms(),
        SimTime::from_secs(out.fl_exec_secs).hms(),
        "22:38 / 24:47".into(),
    ]);
    t.row(&[
        "Cost".into(),
        format!("${:.2}", out.predicted_round_cost * 10.0),
        format!("${:.2}", out.total_cost),
        "$15.44 / $16.18".into(),
    ]);
    t.row(&[
        "Server VM".into(),
        out.initial_server.clone(),
        out.initial_server.clone(),
        "vm121".into(),
    ]);
    t.row(&[
        "Client VMs".into(),
        format!("4×{}", out.initial_clients[0]),
        format!("4×{}", out.initial_clients[0]),
        "4×vm126".into(),
    ]);
    let j = Json::obj()
        .set("experiment", "validation-5.4")
        .set("predicted_secs", predicted_10)
        .set("simulated_secs", out.fl_exec_secs)
        .set("predicted_cost", out.predicted_round_cost * 10.0)
        .set("simulated_cost", out.total_cost)
        .set("server", out.initial_server)
        .set("clients", out.initial_clients);
    (t, j)
}

/// Fig. 2: server checkpoint overhead for X ∈ {10,20,30,40} plus the client
/// per-round checkpoint overhead (§5.5), on the extended TIL run.
pub fn fig2() -> (Table, Json) {
    let base = |seed: u64| {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, seed);
        cfg.n_rounds = TIL_EXTENDED_ROUNDS;
        cfg
    };
    // Baseline: no checkpoints at all.
    let mut no_ckpt = base(42);
    no_ckpt.checkpoints_enabled = false;
    let t_none = crate::coordinator::simulate(&no_ckpt).unwrap();

    let mut t = Table::new(
        "Fig. 2 — checkpoint overhead (TIL, 80 rounds)",
        &["Configuration", "Multi-FedLS time", "FL exec time", "Overhead vs no ckpt", "Paper"],
    );
    let mut rows = Vec::new();
    t.row(&[
        "no checkpoints".into(),
        SimTime::from_secs(t_none.total_secs).hms(),
        SimTime::from_secs(t_none.fl_exec_secs).hms(),
        "—".into(),
        "—".into(),
    ]);
    for (x, paper) in [(10u32, "7.55%"), (20, "~7%"), (30, "6.29%"), (40, "~6.5%")] {
        let mut cfg = base(42);
        cfg.ft.server_every_rounds = x;
        cfg.ft.client_checkpoint = false;
        let out = crate::coordinator::simulate(&cfg).unwrap();
        let ovh = (out.fl_exec_secs - t_none.fl_exec_secs) / t_none.fl_exec_secs * 100.0;
        t.row(&[
            format!("server ckpt every {x} rounds"),
            SimTime::from_secs(out.total_secs).hms(),
            SimTime::from_secs(out.fl_exec_secs).hms(),
            format!("{ovh:.2}%"),
            paper.into(),
        ]);
        rows.push(Json::obj().set("every", x as i64).set("overhead_pct", ovh));
    }
    // Client checkpoint every round (server ckpt off).
    let mut cfg = base(42);
    cfg.ft.client_checkpoint = true;
    cfg.ft.server_every_rounds = u32::MAX;
    let out = crate::coordinator::simulate(&cfg).unwrap();
    // Disable the server's armed-checkpoint constant for this row by
    // comparing against the armed baseline: the paper measures the client
    // overhead separately at 2.17%.
    let client_only_ovh = (out.fl_exec_secs - t_none.fl_exec_secs) / t_none.fl_exec_secs * 100.0
        - cfg.ft.server_round_overhead_secs * TIL_EXTENDED_ROUNDS as f64 / t_none.fl_exec_secs
            * 100.0;
    t.row(&[
        "client ckpt every round".into(),
        SimTime::from_secs(out.total_secs).hms(),
        SimTime::from_secs(out.fl_exec_secs).hms(),
        format!("{client_only_ovh:.2}%"),
        "2.17%".into(),
    ]);
    rows.push(Json::obj().set("every", "client").set("overhead_pct", client_only_ovh));
    (t, Json::obj().set("figure", "2").set("rows", Json::Arr(rows)))
}

/// A failure-simulation table (Tables 5–8 share this shape).
///
/// The scenario × k_r grid is expanded into sweep campaign points and runs
/// across the worker pool; the per-point seed bases (`seed + rate_index·1000`,
/// trials `base..base+3`) match the historical serial driver, so every value
/// is unchanged — the table is just produced N-way parallel now.
fn failure_table(
    title: &str,
    app: apps::AppSpec,
    n_rounds: u32,
    rates: &[f64],
    policy: DynSchedPolicy,
    seed: u64,
    paper_rows: &[(&str, f64, &str, &str)],
) -> (Table, Json) {
    let mut points = Vec::new();
    for scenario in [Scenario::AllSpot, Scenario::OnDemandServer] {
        for (ri, &k_r) in rates.iter().enumerate() {
            let mut cfg = SimConfig::new(app.clone(), scenario, seed);
            cfg.n_rounds = n_rounds;
            cfg.revocation_mean_secs = Some(k_r);
            cfg.dynsched_policy = policy;
            // §5.6.1: the paper observed at most one revocation per task.
            cfg.max_revocations_per_task = Some(1);
            // Scenarios share the same seed base per rate so their client
            // revocation draws are comparable (the server simply has no
            // revocation in the on-demand scenario).
            let base = seed + ri as u64 * 1000;
            points.push(PointSpec {
                tags: vec![
                    ("scenario".to_string(), scenario.key().to_string()),
                    ("k_r".to_string(), format!("{k_r}")),
                ],
                cfg,
                seeds: (0..TRIALS as u64).map(|t| base + t).collect(),
            });
        }
    }
    let stats_list = sweep::run_campaign(&points, 0).expect("campaign");

    let mut t = Table::new(
        title,
        &[
            "Scenario",
            "k_r",
            "Avg # revoc.",
            "Avg exec. time",
            "Avg total costs",
            "Paper (revoc/time/cost)",
        ],
    );
    let mut rows = Vec::new();
    for (p, stats) in points.iter().zip(&stats_list) {
        let scenario = Scenario::from_key(p.tag("scenario")).expect("tag written above");
        let k_r: f64 = p.tag("k_r").parse().expect("tag written above");
        let paper = paper_rows
            .iter()
            .find(|(s, k, _, _)| {
                *k == k_r
                    && ((matches!(scenario, Scenario::AllSpot) && s.contains("spot"))
                        || (matches!(scenario, Scenario::OnDemandServer) && s.contains("demand")))
            })
            .map(|(_, _, time, cost)| format!("{time} / {cost}"))
            .unwrap_or_else(|| "—".into());
        t.row(&[
            scenario.label().into(),
            format!("{}h", k_r / 3600.0),
            format!("{:.2}", stats.revocations.mean),
            stats.exec_hms(),
            format!("${:.2}", stats.cost.mean),
            paper,
        ]);
        rows.push(
            Json::obj()
                .set("scenario", scenario.label())
                .set("k_r", k_r)
                .set("avg_revocations", stats.revocations.mean)
                .set("avg_total_secs", stats.total_secs.mean)
                .set("avg_cost", stats.cost.mean)
                .set("cost_stddev", stats.cost.stddev)
                .set("cost_ci95", stats.cost.ci95)
                .set("total_secs_stddev", stats.total_secs.stddev)
                .set("total_secs_ci95", stats.total_secs.ci95),
        );
    }
    (t, Json::obj().set("table", title).set("rows", Json::Arr(rows)))
}

/// Table 5: TIL failure simulation, restart on a *different* VM type.
pub fn table5() -> (Table, Json) {
    failure_table(
        "Table 5 — TIL failure simulation (restart on different VM type)",
        apps::til(),
        TIL_EXTENDED_ROUNDS,
        &[7200.0, 14400.0],
        DynSchedPolicy::different_vm(),
        50,
        &[
            ("spot", 7200.0, "10:01:46", "$81.12"),
            ("spot", 14400.0, "3:04:37", "$15.64"),
            ("on-demand", 7200.0, "6:31:44", "$55.60"),
            ("on-demand", 14400.0, "3:05:39", "$19.27"),
        ],
    )
}

/// Table 6: TIL failure simulation, same VM type allowed on restart.
pub fn table6() -> (Table, Json) {
    failure_table(
        "Table 6 — TIL failure simulation (restart on same VM type)",
        apps::til(),
        TIL_EXTENDED_ROUNDS,
        &[7200.0, 14400.0],
        DynSchedPolicy::same_vm_allowed(),
        60,
        &[
            ("spot", 7200.0, "4:14:16", "$22.55"),
            ("spot", 14400.0, "3:04:35", "$15.64"),
            ("on-demand", 7200.0, "3:14:38", "$20.16"),
            ("on-demand", 14400.0, "3:01:49", "$18.99"),
        ],
    )
}

/// Table 7: Shakespeare failure simulation (20 rounds × 20 epochs).
pub fn table7() -> (Table, Json) {
    failure_table(
        "Table 7 — Shakespeare failure simulation (same VM type)",
        apps::shakespeare(),
        20,
        &[3600.0, 7200.0],
        DynSchedPolicy::same_vm_allowed(),
        70,
        &[
            ("spot", 3600.0, "2:17:12", "$20.02"),
            ("spot", 7200.0, "1:58:31", "$17.03"),
            ("on-demand", 3600.0, "2:32:12", "$23.46"),
            ("on-demand", 7200.0, "1:57:56", "$17.27"),
        ],
    )
}

/// Table 8: FEMNIST failure simulation (100 rounds × 100 epochs).
pub fn table8() -> (Table, Json) {
    failure_table(
        "Table 8 — FEMNIST failure simulation (same VM type)",
        apps::femnist(),
        100,
        &[3600.0, 7200.0],
        DynSchedPolicy::same_vm_allowed(),
        80,
        &[
            ("spot", 3600.0, "2:34:33", "$14.63"),
            ("spot", 7200.0, "1:52:21", "$10.21"),
            ("on-demand", 3600.0, "2:38:05", "$16.10"),
            ("on-demand", 7200.0, "1:56:02", "$11.35"),
        ],
    )
}

/// §5.7: AWS/GCP proof of concept — on-demand vs all-spot with k_r = 2 h.
pub fn poc_aws_gcp() -> (Table, Json) {
    let mut od = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 90);
    od.checkpoints_enabled = false;
    let od_stats = run_trials(&od, TRIALS, 90).unwrap();

    let mut spot = SimConfig::new(apps::til_aws_gcp(), Scenario::AllSpot, 91);
    spot.revocation_mean_secs = Some(7200.0);
    spot.dynsched_policy = DynSchedPolicy::different_vm();
    spot.max_revocations_per_task = Some(1); // §5.6.1 observed regime
    spot.checkpoints_enabled = true;
    let spot_stats = run_trials(&spot, TRIALS, 91).unwrap();

    let cost_reduction = (od_stats.cost.mean - spot_stats.cost.mean) / od_stats.cost.mean * 100.0;
    let time_increase = (spot_stats.total_secs.mean - od_stats.total_secs.mean)
        / od_stats.total_secs.mean
        * 100.0;

    let mut t = Table::new(
        "§5.7 — AWS/GCP proof of concept (TIL, 2 clients, 10 rounds)",
        &["Configuration", "Avg # revoc.", "Avg time", "Avg cost", "Paper"],
    );
    t.row(&[
        "all on-demand".into(),
        format!("{:.2}", od_stats.revocations.mean),
        od_stats.exec_hms(),
        format!("${:.2}", od_stats.cost.mean),
        "0 / 2:00:18 / $3.28".into(),
    ]);
    t.row(&[
        "all spot, k_r = 2h".into(),
        format!("{:.2}", spot_stats.revocations.mean),
        spot_stats.exec_hms(),
        format!("${:.2}", spot_stats.cost.mean),
        "1.33 / 2:06:51 / $1.41".into(),
    ]);
    t.row(&[
        "cost reduction / time increase".into(),
        "—".into(),
        format!("+{time_increase:.2}%"),
        format!("-{cost_reduction:.2}%"),
        "-56.92% cost, +5.44% time".into(),
    ]);
    let j = Json::obj()
        .set("experiment", "poc-aws-gcp")
        .set("on_demand_cost", od_stats.cost.mean)
        .set("spot_cost", spot_stats.cost.mean)
        .set("cost_reduction_pct", cost_reduction)
        .set("time_increase_pct", time_increase)
        .set("on_demand_secs", od_stats.total_secs.mean)
        .set("spot_secs", spot_stats.total_secs.mean)
        .set("on_demand_cost_ci95", od_stats.cost.ci95)
        .set("spot_cost_ci95", spot_stats.cost.ci95);
    (t, j)
}

/// Solver comparison (ours): exact vs linearized-MILP vs greedy baselines on
/// the TIL instance — the quality/latency ablation DESIGN.md calls out.
pub fn mapping_comparison() -> (Table, Json) {
    let mc = cloudlab_sim();
    let sl = PreScheduler::new(&mc).measure_defaults();
    let job = apps::til().profile();
    let mut t = Table::new(
        "Initial Mapping — solver comparison (TIL on CloudLab)",
        &["alpha", "Solver", "Objective", "Makespan (s)", "Cost ($/round)", "Feasible"],
    );
    let mut rows = Vec::new();
    for alpha in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let exact = crate::mapping::exact::solve(&p).unwrap();
        t.row(&[
            format!("{alpha}"),
            "exact (ours)".into(),
            format!("{:.5}", exact.eval.objective),
            format!("{:.1}", exact.eval.makespan),
            format!("{:.4}", exact.eval.total_cost),
            "yes".into(),
        ]);
        rows.push(
            Json::obj()
                .set("alpha", alpha)
                .set("solver", "exact")
                .set("objective", exact.eval.objective),
        );
        for (name, mapping) in crate::mapping::baselines::all(&p) {
            if let Some(m) = mapping {
                let ev = p.evaluate(&m);
                t.row(&[
                    format!("{alpha}"),
                    name.into(),
                    format!("{:.5}", ev.objective),
                    format!("{:.1}", ev.makespan),
                    format!("{:.4}", ev.total_cost),
                    if ev.feasible { "yes".into() } else { "no".into() },
                ]);
                rows.push(
                    Json::obj()
                        .set("alpha", alpha)
                        .set("solver", name)
                        .set("objective", ev.objective),
                );
            }
        }
    }
    (t, Json::obj().set("experiment", "mapping-comparison").set("rows", Json::Arr(rows)))
}

/// Ablation (ours): how the user weight α trades cost for makespan on the
/// TIL/CloudLab instance — sweeps the whole [0,1] range and reports the
/// chosen placement at each point.
pub fn alpha_sweep() -> (Table, Json) {
    let mc = cloudlab_sim();
    let sl = PreScheduler::new(&mc).measure_defaults();
    let job = apps::til().profile();
    let mut t = Table::new(
        "Ablation — α sweep (TIL on CloudLab, spot prices)",
        &["alpha", "Server", "Clients", "Makespan (s)", "Cost ($/round)"],
    );
    let mut rows = Vec::new();
    for i in 0..=10 {
        let alpha = i as f64 / 10.0;
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha,
            market: Market::Spot,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let sol = crate::mapping::exact::solve(&p).expect("feasible");
        let mut names: Vec<String> = sol
            .mapping
            .clients
            .iter()
            .map(|&v| mc.catalog.vm(v).id.clone())
            .collect();
        names.sort();
        names.dedup();
        t.row(&[
            format!("{alpha:.1}"),
            mc.catalog.vm(sol.mapping.server).id.clone(),
            names.join("+"),
            format!("{:.1}", sol.eval.makespan),
            format!("{:.4}", sol.eval.total_cost),
        ]);
        rows.push(
            Json::obj()
                .set("alpha", alpha)
                .set("makespan", sol.eval.makespan)
                .set("cost", sol.eval.total_cost),
        );
    }
    (t, Json::obj().set("experiment", "alpha-sweep").set("rows", Json::Arr(rows)))
}

/// Multi-application extension demo (§6 future work): three apps share the
/// AWS+GCP quota; FIFO vs shortest-makespan-first admission.
pub fn multijob() -> (Table, Json) {
    use crate::coordinator::multijob::{AdmissionPolicy, MultiJobScheduler};
    let mc = MultiCloud::new(
        tables::aws_gcp(),
        tables::aws_gcp_ground_truth(),
        RevocationModel::none(),
        1,
    );
    let sl = PreScheduler::new(&mc).measure_defaults();
    let apps_list = vec![apps::til_aws_gcp(), apps::til_aws_gcp(), apps::til_aws_gcp()];
    let mut t = Table::new(
        "Extension — concurrent FL applications on shared AWS+GCP quota",
        &["Policy", "Job", "Server", "Clients", "Round makespan (s)"],
    );
    let mut rows = Vec::new();
    for policy in [AdmissionPolicy::Fifo, AdmissionPolicy::ShortestMakespanFirst] {
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        let plan = sched.plan(&apps_list, policy);
        for (i, j) in plan.admitted.iter().enumerate() {
            let clients: Vec<String> =
                j.mapping.clients.iter().map(|&v| mc.catalog.vm(v).id.clone()).collect();
            t.row(&[
                format!("{policy:?}"),
                format!("job-{i}"),
                mc.catalog.vm(j.mapping.server).id.clone(),
                clients.join("+"),
                format!("{:.1}", j.predicted_makespan),
            ]);
            rows.push(
                Json::obj()
                    .set("policy", format!("{policy:?}"))
                    .set("job", i)
                    .set("makespan", j.predicted_makespan),
            );
        }
        for q in &plan.queued {
            t.row(&[
                format!("{policy:?}"),
                q.clone(),
                "(queued)".into(),
                "—".into(),
                "—".into(),
            ]);
        }
    }
    (t, Json::obj().set("experiment", "multijob").set("rows", Json::Arr(rows)))
}

/// Ablation (ours, closing the ROADMAP "Framework ablation studies" item):
/// the paper's Dynamic Scheduler (Algorithms 1–3) against the
/// restart-same-type baseline on the Table 5 configuration (TIL, all-spot,
/// different-VM policy, ≤1 revocation per task) — isolates the benefit of
/// Algorithm 3's re-optimization after each revocation.
pub fn dynsched_ablation() -> (Table, Json) {
    use crate::framework::{CachedPreSched, EnvCache, Framework, PaperDynSched, RestartSameType};
    use std::sync::Arc;

    let rates = [7200.0, 14400.0];
    let points: Vec<PointSpec> = rates
        .iter()
        .enumerate()
        .map(|(ri, &k_r)| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
            cfg.n_rounds = TIL_EXTENDED_ROUNDS;
            cfg.revocation_mean_secs = Some(k_r);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            cfg.max_revocations_per_task = Some(1);
            // Same seed bases as the Table 5 driver so the paper-stack rows
            // line up with the published table.
            let base = 50 + ri as u64 * 1000;
            PointSpec {
                tags: vec![("k_r".to_string(), format!("{k_r}"))],
                cfg,
                seeds: (0..TRIALS as u64).map(|t| base + t).collect(),
            }
        })
        .collect();

    let cache = Arc::new(EnvCache::new());
    let paper_fw = Framework::builder()
        .pre_sched(CachedPreSched::new(cache.clone()))
        .dynsched(PaperDynSched)
        .build();
    let baseline_fw = Framework::builder()
        .pre_sched(CachedPreSched::new(cache.clone()))
        .dynsched(RestartSameType)
        .build();
    let paper_stats = sweep::run_campaign_with(&points, 0, &paper_fw).expect("campaign");
    let baseline_stats = sweep::run_campaign_with(&points, 0, &baseline_fw).expect("campaign");

    let mut t = Table::new(
        "Ablation — Dynamic Scheduler (TIL, all-spot, different-VM policy)",
        &["k_r", "Scheduler", "Avg # revoc.", "Avg exec. time", "Avg total costs", "Δcost vs Alg. 1–3"],
    );
    let mut rows = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let k_r: f64 = p.tag("k_r").parse().expect("tag written above");
        for (label, stats, reference) in [
            ("algorithms-1-3", &paper_stats[i], None),
            ("restart-same-type", &baseline_stats[i], Some(&paper_stats[i])),
        ] {
            let delta = match reference {
                None => "—".to_string(),
                Some(r) => {
                    format!("{:+.2}%", (stats.cost.mean - r.cost.mean) / r.cost.mean * 100.0)
                }
            };
            t.row(&[
                format!("{}h", k_r / 3600.0),
                label.into(),
                format!("{:.2}", stats.revocations.mean),
                stats.exec_hms(),
                format!("${:.2}", stats.cost.mean),
                delta,
            ]);
            rows.push(
                Json::obj()
                    .set("k_r", k_r)
                    .set("scheduler", label)
                    .set("avg_revocations", stats.revocations.mean)
                    .set("avg_total_secs", stats.total_secs.mean)
                    .set("avg_cost", stats.cost.mean)
                    .set("cost_ci95", stats.cost.ci95),
            );
        }
    }
    (t, Json::obj().set("experiment", "dynsched-ablation").set("rows", Json::Arr(rows)))
}

/// Ablation (ours, closing the ROADMAP "workload-level dynamic scheduling"
/// item): one contended workload — four low-priority jobs whose per-round
/// deadline forces GPU placements (saturating the 8 AWS+GCP GPUs from
/// t = 0) plus a priority-10 job arriving mid-execution — run under every
/// workload scheduling policy. Isolates what checkpoint-preemption buys the
/// high-priority job (wait time) against what it costs the preempted victim
/// (rounds lost — zero with client checkpoints on, the §4.3 restore path).
pub fn preempt_ablation() -> (Table, Json) {
    use crate::coordinator::multijob::{AdmissionPolicy, SchedulerPolicy};
    use crate::framework::EnvCache;
    use crate::workload::{JobRequest, Workload};
    use std::sync::Arc;

    let gpu_job = |seed: u64| {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
        cfg.deadline_round = 4000.0; // CPU types are ~20x slower: GPUs only
        cfg
    };
    let build = |scheduler: SchedulerPolicy| {
        let mut jobs: Vec<JobRequest> = (0..4)
            .map(|i| {
                let mut j = JobRequest::new(format!("low-{i}"), 0.0, gpu_job(10 + i as u64));
                j.tenant = if i < 2 { "acme".into() } else { "zeta".into() };
                j
            })
            .collect();
        let mut hi = JobRequest::new("high", 3000.0, gpu_job(99));
        hi.priority = 10;
        hi.tenant = "acme".into();
        jobs.push(hi);
        Workload {
            name: "preempt-ablation".into(),
            jobs,
            admission: AdmissionPolicy::Fifo,
            scheduler,
        }
    };

    let cache = Arc::new(EnvCache::new());
    let mut t = Table::new(
        "Ablation — workload scheduling policies (contended AWS+GCP GPUs)",
        &[
            "Scheduler",
            "Makespan",
            "Mean wait (s)",
            "High-pri wait (s)",
            "Total costs",
            "Preempt.",
            "Rounds lost",
        ],
    );
    let mut rows = Vec::new();
    for policy in
        [SchedulerPolicy::NoPreempt, SchedulerPolicy::PriorityPreempt, SchedulerPolicy::FairShare]
    {
        let out = build(policy).run_with_cache(&cache).expect("workload");
        let hi = out.jobs.iter().find(|j| j.name == "high").expect("high-priority job");
        let rounds_lost: u32 = out.jobs.iter().map(|j| j.rounds_lost).sum();
        t.row(&[
            policy.key().into(),
            SimTime::from_secs(out.stats.makespan_secs).hms(),
            format!("{:.0}", out.stats.mean_wait_secs),
            format!("{:.0}", hi.wait_secs),
            format!("${:.2}", out.stats.total_cost),
            format!("{}", out.stats.preemptions),
            format!("{rounds_lost}"),
        ]);
        rows.push(
            Json::obj()
                .set("scheduler", policy.key())
                .set("makespan_secs", out.stats.makespan_secs)
                .set("mean_wait_secs", out.stats.mean_wait_secs)
                .set("high_pri_wait_secs", hi.wait_secs)
                .set("total_cost", out.stats.total_cost)
                .set("preemptions", u64::from(out.stats.preemptions))
                .set("rounds_lost", u64::from(rounds_lost)),
        );
    }
    (t, Json::obj().set("experiment", "preempt-ablation").set("rows", Json::Arr(rows)))
}

/// Ablation (ours, closing the ROADMAP "mapper-swap tables" item): every
/// Initial Mapping implementation — exact, linearized MILP, the greedy
/// cheapest/fastest baselines, uniform-random, and single-cloud — on the
/// Table 5 configuration (TIL, all-spot, k_r = 2 h, different-VM policy,
/// ≤1 revocation per task), isolating how much the exact solver's placement
/// quality is worth once revocations and replacements are in play.
pub fn mapper_ablation() -> (Table, Json) {
    use crate::mapping::MapperKind;

    let points: Vec<PointSpec> = MapperKind::all()
        .iter()
        .map(|&kind| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
            cfg.n_rounds = TIL_EXTENDED_ROUNDS;
            cfg.revocation_mean_secs = Some(7200.0);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            cfg.max_revocations_per_task = Some(1);
            cfg.mapper = kind;
            // Same seed base as the Table 5 driver so the exact-mapper row
            // lines up with the published table.
            PointSpec {
                tags: vec![("mapper".to_string(), kind.key().to_string())],
                cfg,
                seeds: (0..TRIALS as u64).map(|t| 50 + t).collect(),
            }
        })
        .collect();
    let stats_list = sweep::run_campaign(&points, 0).expect("campaign");

    let mut t = Table::new(
        "Ablation — Initial Mapping modules (TIL, all-spot, k_r = 2h, Table 5 config)",
        &["Mapper", "Avg # revoc.", "Avg exec. time", "Avg total costs", "Δcost vs exact"],
    );
    let mut rows = Vec::new();
    // Baseline by tag, not position — robust to MapperKind::all() ordering.
    let exact_cost = points
        .iter()
        .zip(&stats_list)
        .find(|(p, _)| p.tag("mapper") == "exact")
        .map(|(_, s)| s.cost.mean)
        .expect("exact mapper in the ablation grid");
    for (p, stats) in points.iter().zip(&stats_list) {
        let delta = if p.tag("mapper") == "exact" {
            "—".to_string()
        } else {
            format!("{:+.2}%", (stats.cost.mean - exact_cost) / exact_cost * 100.0)
        };
        t.row(&[
            p.tag("mapper").to_string(),
            format!("{:.2}", stats.revocations.mean),
            stats.exec_hms(),
            format!("${:.2}", stats.cost.mean),
            delta,
        ]);
        rows.push(
            Json::obj()
                .set("mapper", p.tag("mapper"))
                .set("avg_revocations", stats.revocations.mean)
                .set("avg_total_secs", stats.total_secs.mean)
                .set("avg_cost", stats.cost.mean)
                .set("cost_ci95", stats.cost.ci95),
        );
    }
    (t, Json::obj().set("experiment", "mapper-ablation").set("rows", Json::Arr(rows)))
}

/// Market-sensitivity study (ours): the Table 5 configuration re-run under
/// different spot-market models — the paper's exponential clock, an
/// age-dependent Weibull hazard, a diurnal seasonal process, a deterministic
/// interruption-trace replay, and a volatile price-step market with
/// bid-priced VMs — quantifying how much the market model (not the
/// scheduler) drives cost and makespan.
pub fn market_sensitivity() -> (Table, Json) {
    use crate::market::{MarketSpec, PriceSpec, RevocationSpec};

    let markets: Vec<(&str, MarketSpec)> = vec![
        ("exponential", MarketSpec::default()),
        (
            "weibull",
            MarketSpec {
                revocation: RevocationSpec::Weibull { scale_secs: 7200.0, shape: 0.7 },
                ..MarketSpec::default()
            },
        ),
        (
            "seasonal",
            MarketSpec {
                revocation: RevocationSpec::Seasonal {
                    mean_secs: 7200.0,
                    period_secs: 14_400.0,
                    amplitude: 0.8,
                    phase_secs: 0.0,
                },
                ..MarketSpec::default()
            },
        ),
        (
            "trace-replay",
            MarketSpec {
                revocation: RevocationSpec::Trace {
                    times: vec![4000.0, 4300.0, 9000.0, 16_000.0],
                },
                ..MarketSpec::default()
            },
        ),
        (
            "volatile-price",
            MarketSpec {
                price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8), (10_800.0, 0.6)]),
                ..MarketSpec::default()
            },
        ),
        (
            "bid-priced",
            MarketSpec {
                price: PriceSpec::Steps(vec![(0.0, 1.0), (5000.0, 1.6), (9000.0, 1.0)]),
                bid_factor: Some(1.5),
                ..MarketSpec::default()
            },
        ),
    ];
    let points: Vec<PointSpec> = markets
        .iter()
        .map(|(name, market)| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
            cfg.n_rounds = TIL_EXTENDED_ROUNDS;
            cfg.revocation_mean_secs = Some(7200.0);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            cfg.max_revocations_per_task = Some(1);
            cfg.market = market.clone();
            PointSpec {
                tags: vec![("market".to_string(), name.to_string())],
                cfg,
                seeds: (0..TRIALS as u64).map(|t| 50 + t).collect(),
            }
        })
        .collect();
    let stats_list = sweep::run_campaign(&points, 0).expect("campaign");

    let mut t = Table::new(
        "Market sensitivity — spot-market models (TIL, all-spot, Table 5 config)",
        &["Market", "Avg # revoc.", "Avg exec. time", "Avg total costs", "Δcost vs exponential"],
    );
    let mut rows = Vec::new();
    // Baseline by tag, not position (same rationale as mapper_ablation).
    let base_cost = points
        .iter()
        .zip(&stats_list)
        .find(|(p, _)| p.tag("market") == "exponential")
        .map(|(_, s)| s.cost.mean)
        .expect("exponential market in the sensitivity grid");
    for (p, stats) in points.iter().zip(&stats_list) {
        let delta = if p.tag("market") == "exponential" {
            "—".to_string()
        } else {
            format!("{:+.2}%", (stats.cost.mean - base_cost) / base_cost * 100.0)
        };
        t.row(&[
            p.tag("market").to_string(),
            format!("{:.2}", stats.revocations.mean),
            stats.exec_hms(),
            format!("${:.2}", stats.cost.mean),
            delta,
        ]);
        rows.push(
            Json::obj()
                .set("market", p.tag("market"))
                .set("avg_revocations", stats.revocations.mean)
                .set("avg_total_secs", stats.total_secs.mean)
                .set("avg_cost", stats.cost.mean)
                .set("cost_ci95", stats.cost.ci95),
        );
    }
    (t, Json::obj().set("experiment", "market-sensitivity").set("rows", Json::Arr(rows)))
}

/// Outlook ablation (ours): the Table 5 configuration on a volatile
/// price-step market (1.0× → 1.8× spike at 1 h → 0.6× trough at 3 h), run
/// outlook-off, outlook-aware without deferral (windowed candidate pricing
/// only), and outlook-aware with deferral — isolating how much of the
/// saving comes from pricing replacements over the remaining-rounds window
/// versus waiting out the spike before provisioning at all.
pub fn outlook_ablation() -> (Table, Json) {
    use crate::market::{MarketSpec, PriceSpec};
    use crate::outlook::OutlookSpec;

    let volatile = MarketSpec {
        price: PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8), (10_800.0, 0.6)]),
        ..MarketSpec::default()
    };
    let variants: Vec<(&str, OutlookSpec)> = vec![
        ("off", OutlookSpec::default()),
        (
            "windowed",
            OutlookSpec { enabled: true, horizon_secs: Some(14_400.0), bid_risk: 0.1, defer: false },
        ),
        (
            "defer",
            OutlookSpec { enabled: true, horizon_secs: Some(14_400.0), bid_risk: 0.1, defer: true },
        ),
    ];
    let points: Vec<PointSpec> = variants
        .iter()
        .map(|(name, outlook)| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 50);
            cfg.n_rounds = TIL_EXTENDED_ROUNDS;
            cfg.revocation_mean_secs = Some(7200.0);
            cfg.dynsched_policy = DynSchedPolicy::different_vm();
            cfg.max_revocations_per_task = Some(1);
            cfg.market = volatile.clone();
            cfg.outlook = outlook.clone();
            PointSpec {
                tags: vec![("outlook".to_string(), name.to_string())],
                cfg,
                seeds: (0..TRIALS as u64).map(|t| 50 + t).collect(),
            }
        })
        .collect();
    let stats_list = sweep::run_campaign(&points, 0).expect("campaign");

    let mut t = Table::new(
        "Ablation — market outlook (TIL, all-spot, volatile price steps, Table 5 config)",
        &["Outlook", "Avg # revoc.", "Avg exec. time", "Avg total costs", "Δcost vs off"],
    );
    let mut rows = Vec::new();
    // Baseline by tag, not position (same rationale as mapper_ablation).
    let off_cost = points
        .iter()
        .zip(&stats_list)
        .find(|(p, _)| p.tag("outlook") == "off")
        .map(|(_, s)| s.cost.mean)
        .expect("outlook-off baseline in the ablation grid");
    for (p, stats) in points.iter().zip(&stats_list) {
        let delta = if p.tag("outlook") == "off" {
            "—".to_string()
        } else {
            format!("{:+.2}%", (stats.cost.mean - off_cost) / off_cost * 100.0)
        };
        t.row(&[
            p.tag("outlook").to_string(),
            format!("{:.2}", stats.revocations.mean),
            stats.exec_hms(),
            format!("${:.2}", stats.cost.mean),
            delta,
        ]);
        rows.push(
            Json::obj()
                .set("outlook", p.tag("outlook"))
                .set("avg_revocations", stats.revocations.mean)
                .set("avg_total_secs", stats.total_secs.mean)
                .set("avg_cost", stats.cost.mean)
                .set("cost_ci95", stats.cost.ci95),
        );
    }
    (t, Json::obj().set("experiment", "outlook-ablation").set("rows", Json::Arr(rows)))
}

/// Table 2 / Table 9 catalog dump.
pub fn catalog_table(which: &str) -> Table {
    let cat = if which == "aws-gcp" { tables::aws_gcp() } else { tables::cloudlab() };
    let mut t = Table::new(
        format!("Catalog — {}", cat.name),
        &["Cloud", "Region", "VM", "hw", "vCPUs", "GPUs", "RAM", "On-demand $/h", "Spot $/h"],
    );
    for v in cat.vm_ids() {
        let spec = cat.vm(v);
        let region = cat.region(spec.region);
        t.row(&[
            cat.provider(region.provider).name.clone(),
            region.name.clone(),
            spec.id.clone(),
            spec.hw_name.clone(),
            spec.vcpus.to_string(),
            spec.gpus.to_string(),
            format!("{:.0}", spec.ram_gb),
            format!("{:.3}", spec.on_demand_hourly),
            format!("{:.3}", spec.spot_hourly),
        ]);
    }
    t
}

/// Accessor used by benches to render & persist.
pub fn stats_row(stats: &TrialStats) -> String {
    format!(
        "revoc={:.2} exec={} cost=${:.2} (±{:.2} 95% CI)",
        stats.revocations.mean,
        stats.exec_hms(),
        stats.cost.mean,
        stats.cost.ci95
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_renders_13_vms() {
        let (t, j) = table3();
        let s = t.render();
        assert!(s.contains("vm126") && s.contains("0.045"));
        assert!(s.contains("vm212") && s.contains("2.328"));
        assert!(j.to_string_compact().contains("\"slowdown\""));
    }

    #[test]
    fn table4_renders_15_pairs() {
        let (t, _) = table4();
        let s = t.render();
        assert!(s.contains("Massachusetts-Wisconsin") || s.contains("Wisconsin-Massachusetts"));
        // measured via the network model (includes per-message latency): ≈24.5
        assert!(s.contains("24."));
    }

    #[test]
    fn validation_produces_paper_scale_numbers() {
        let (t, j) = validation_5_4();
        let s = t.render();
        assert!(s.contains("vm126"));
        let js = j.to_string_compact();
        assert!(js.contains("simulated_secs"));
    }

    #[test]
    fn catalog_tables_render() {
        assert!(catalog_table("cloudlab").render().contains("c240g5"));
        assert!(catalog_table("aws-gcp").render().contains("g4dn.2xlarge"));
    }
}
