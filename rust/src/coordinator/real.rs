//! Real-compute execution: the same Multi-FedLS pipeline with the FL round
//! protocol actually training models through the PJRT runtime (AOT JAX +
//! Pallas artifacts), in wall-clock time.
//!
//! Used by the `examples/` drivers. The cloud layer is still the simulator
//! (we have no AWS account here), but all *compute* is real: per-client
//! local SGD on private shards, FedAvg aggregation, checkpoint/restore.

use std::path::Path;

use crate::apps::AppSpec;
use crate::data;
use crate::fl::{self, FedAvg, FlConfig, FlOutcome, Trainer};
use crate::ft::CheckpointStore;
use crate::runtime::{Engine, Manifest, PjrtTrainer};

/// Configuration for a real-compute federated run.
pub struct RealRunConfig {
    pub app: AppSpec,
    /// Rounds to run (examples use fewer than the paper's counts).
    pub rounds: u32,
    /// Local epochs per round.
    pub local_epochs: u32,
    /// Dataset scale vs the paper's sample counts (1.0 = full).
    pub data_scale: f64,
    pub seed: u64,
    /// Server checkpoint cadence (None disables).
    pub server_ckpt_every: Option<u32>,
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

impl RealRunConfig {
    pub fn quick(app: AppSpec) -> Self {
        Self {
            app,
            rounds: 5,
            local_epochs: 1,
            data_scale: 0.05,
            seed: 42,
            server_ckpt_every: None,
            checkpoint_dir: None,
        }
    }
}

/// Load artifacts, build one PJRT trainer per silo, and run the federated
/// job end-to-end. Returns the round history (loss curve).
pub fn run(artifacts_dir: &Path, cfg: &RealRunConfig) -> anyhow::Result<FlOutcome> {
    let manifest = Manifest::load(artifacts_dir)?;
    let art = manifest.app(cfg.app.artifact_prefix)?;
    let engine = Engine::cpu()?;

    let shards = data::shards_for_app(&cfg.app, cfg.seed, cfg.data_scale);
    let mut trainers: Vec<Box<dyn Trainer>> = Vec::with_capacity(shards.len());
    for shard in shards {
        trainers.push(Box::new(PjrtTrainer::new(&engine, art, shard, cfg.local_epochs)?));
    }

    let initial = art.load_init_params()?;
    let store = match &cfg.checkpoint_dir {
        Some(dir) => Some(CheckpointStore::new(dir.join("local"), Some(dir.join("stable")))?),
        None => None,
    };
    // Real-compute runs report genuine wall time: inject an Instant-based
    // clock (this module is the wall-clock lint's allowed zone — the fl
    // library itself only ever sees the injected handle).
    let epoch = std::time::Instant::now();
    fl::run_federated(
        trainers,
        &FedAvg,
        initial,
        FlConfig {
            rounds: cfg.rounds,
            server_ckpt_every: cfg.server_ckpt_every,
            checkpoint_store: store,
            resume_from: None,
            clock: Box::new(move || epoch.elapsed().as_secs_f64()),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Real-compute runs need `make artifacts`; tests that depend on them
    /// are exercised via `rust/tests/e2e_artifacts.rs` (integration) so the
    /// unit suite stays artifact-free. Here we only check config plumbing.
    #[test]
    fn quick_config_defaults() {
        let cfg = RealRunConfig::quick(crate::apps::femnist());
        assert_eq!(cfg.rounds, 5);
        assert!(cfg.data_scale < 1.0);
    }

    #[test]
    fn missing_artifacts_yield_clear_error() {
        let cfg = RealRunConfig::quick(crate::apps::femnist());
        let err = run(Path::new("/definitely/not/there"), &cfg).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
