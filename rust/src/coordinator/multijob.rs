//! Multi-application extension (§6 future work): "the extension of
//! Multi-FedLS for executing several FL applications simultaneously".
//!
//! Several Cross-Silo FL jobs share one multi-cloud: their placements must
//! jointly satisfy the provider/region GPU and vCPU quotas, and later jobs
//! see only the capacity earlier ones left. We implement the natural
//! extension of the Initial Mapping: jobs are admitted in arrival order
//! (FIFO) or by a shortest-expected-makespan rule, each solved with the
//! exact per-job solver against the *residual* quota, with reservations
//! released as jobs finish. A job whose mapping is infeasible under the
//! residual quota is queued until capacity frees up.

use crate::apps::AppSpec;
use crate::cloud::quota::QuotaTracker;
use crate::cloud::{Catalog, Market, VmTypeId};
use crate::mapping::problem::{Mapping, MappingProblem};
use crate::presched::SlowdownReport;

/// Admission order for queued applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// First-come, first-served.
    Fifo,
    /// Shortest predicted round makespan first (reduces mean waiting time,
    /// classic SJF argument).
    ShortestMakespanFirst,
}

impl AdmissionPolicy {
    /// Stable config-file key (workload specs and grid axes).
    pub fn key(self) -> &'static str {
        match self {
            AdmissionPolicy::Fifo => "fifo",
            AdmissionPolicy::ShortestMakespanFirst => "sjf",
        }
    }

    pub fn from_key(key: &str) -> Option<AdmissionPolicy> {
        match key {
            "fifo" => Some(AdmissionPolicy::Fifo),
            "sjf" => Some(AdmissionPolicy::ShortestMakespanFirst),
            _ => None,
        }
    }
}

/// Workload-level dynamic-scheduling policy: what the workload engine may
/// do *beyond* ordering admissions when quota is short. Selects one of the
/// built-in [`crate::workload::WorkloadScheduler`] implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerPolicy {
    /// Admit-and-run-to-completion (the pre-preemption engine, bit-identical).
    #[default]
    NoPreempt,
    /// Higher-priority queued jobs may checkpoint-preempt the
    /// lowest-priority running job when the quota is short; preempted jobs
    /// resume from their freshest checkpoint, not from scratch.
    PriorityPreempt,
    /// Deficit-weighted round-robin over tenants at every release event.
    FairShare,
}

impl SchedulerPolicy {
    /// Stable config-file key (workload specs and grid axes).
    pub fn key(self) -> &'static str {
        match self {
            SchedulerPolicy::NoPreempt => "no-preempt",
            SchedulerPolicy::PriorityPreempt => "priority-preempt",
            SchedulerPolicy::FairShare => "fair-share",
        }
    }

    pub fn from_key(key: &str) -> Option<SchedulerPolicy> {
        match key {
            "no-preempt" => Some(SchedulerPolicy::NoPreempt),
            "priority-preempt" => Some(SchedulerPolicy::PriorityPreempt),
            "fair-share" => Some(SchedulerPolicy::FairShare),
            _ => None,
        }
    }
}

/// One admitted job: its placement plus the quota it holds.
#[derive(Debug, Clone)]
pub struct AdmittedJob {
    pub name: String,
    pub mapping: Mapping,
    pub predicted_makespan: f64,
    pub predicted_round_cost: f64,
}

/// Outcome of planning a batch of applications.
#[derive(Debug)]
pub struct MultiJobPlan {
    pub admitted: Vec<AdmittedJob>,
    /// Apps that did not fit the residual quota (to retry after releases).
    pub queued: Vec<String>,
}

/// The multi-application scheduler state.
pub struct MultiJobScheduler<'a> {
    catalog: &'a Catalog,
    slowdowns: &'a SlowdownReport,
    quota: QuotaTracker,
    alpha: f64,
    market: Market,
}

impl<'a> MultiJobScheduler<'a> {
    pub fn new(
        catalog: &'a Catalog,
        slowdowns: &'a SlowdownReport,
        alpha: f64,
        market: Market,
    ) -> Self {
        Self { catalog, slowdowns, quota: QuotaTracker::new(), alpha, market }
    }

    /// Reserve a whole mapping against the shared quota; rolls back on any
    /// failure so reservations are atomic per job.
    fn try_reserve(&mut self, mapping: &Mapping) -> bool {
        let mut taken: Vec<VmTypeId> = Vec::new();
        let mut vms = mapping.clients.clone();
        vms.push(mapping.server);
        for vm in vms {
            if self.quota.allocate(self.catalog, vm).is_ok() {
                taken.push(vm);
            } else {
                for t in taken {
                    self.quota.release(self.catalog, t);
                }
                return false;
            }
        }
        true
    }

    /// Release a finished job's reservation.
    pub fn release(&mut self, job: &AdmittedJob) {
        self.quota.release(self.catalog, job.mapping.server);
        for &vm in &job.mapping.clients {
            self.quota.release(self.catalog, vm);
        }
    }

    /// Solve one app against the residual quota. The exact solver enforces
    /// *absolute* quota bounds internally, so we re-check the combined
    /// reservation and fall back to excluding saturated placements by
    /// shrinking the candidate set via trial-reservation.
    fn solve_residual(&mut self, app: &AppSpec) -> Option<AdmittedJob> {
        let job = app.profile();
        let p = MappingProblem {
            catalog: self.catalog,
            slowdowns: self.slowdowns,
            job: &job,
            alpha: self.alpha,
            market: self.market,
            spot_price_factor: 1.0,
            budget_round: f64::INFINITY,
            deadline_round: f64::INFINITY,
            outlook: None,
        };
        // First try the unconstrained optimum: often it fits.
        if let Some(sol) = crate::mapping::exact::solve(&p) {
            if self.try_reserve(&sol.mapping) {
                return Some(AdmittedJob {
                    name: app.name.to_string(),
                    mapping: sol.mapping,
                    predicted_makespan: sol.eval.makespan,
                    predicted_round_cost: sol.eval.total_cost,
                });
            }
            // Residual-quota retry: solve over a catalog whose quotas are
            // reduced by current usage.
            let mut reduced = self.catalog.clone();
            for (pi, prov) in reduced.providers.iter_mut().enumerate() {
                if let Some(maxg) = prov.max_gpus {
                    let used = self.quota.provider_gpus_in_use(crate::cloud::ProviderId(pi));
                    prov.max_gpus = Some(maxg.saturating_sub(used));
                }
                if let Some(maxc) = prov.max_vcpus {
                    let used = self.quota.provider_vcpus_in_use(crate::cloud::ProviderId(pi));
                    prov.max_vcpus = Some(maxc.saturating_sub(used));
                }
            }
            for (ri, region) in reduced.regions.iter_mut().enumerate() {
                if let Some(maxg) = region.max_gpus {
                    let used = self.quota.region_gpus_in_use(crate::cloud::RegionId(ri));
                    region.max_gpus = Some(maxg.saturating_sub(used));
                }
            }
            // The slowdown report is keyed by VM-type/region indices into the
            // original catalog. `reduced` above only shrinks the quota
            // *bounds* — it never adds, drops, or reorders providers, regions
            // or VM types — so every index (and therefore every slowdown key)
            // is valid unchanged against the reduced catalog and the report
            // can be reused as-is. (A former `remap` helper cloned the report
            // while ignoring both catalogs; this invariant is what it relied
            // on.)
            let p2 = MappingProblem {
                catalog: &reduced,
                slowdowns: self.slowdowns,
                job: &job,
                alpha: self.alpha,
                market: self.market,
                spot_price_factor: 1.0,
                budget_round: f64::INFINITY,
                deadline_round: f64::INFINITY,
                outlook: None,
            };
            if let Some(sol) = crate::mapping::exact::solve(&p2) {
                // Translate ids (same order: reduced keeps all vm_types).
                let mapping = Mapping {
                    server: sol.mapping.server,
                    clients: sol.mapping.clients.clone(),
                    market: self.market,
                };
                if self.try_reserve(&mapping) {
                    return Some(AdmittedJob {
                        name: app.name.to_string(),
                        mapping,
                        predicted_makespan: sol.eval.makespan,
                        predicted_round_cost: sol.eval.total_cost,
                    });
                }
            }
        }
        None
    }

    /// Plan a batch of applications under the admission policy.
    pub fn plan(&mut self, apps: &[AppSpec], policy: AdmissionPolicy) -> MultiJobPlan {
        let mut order: Vec<usize> = (0..apps.len()).collect();
        if policy == AdmissionPolicy::ShortestMakespanFirst {
            // Predict each app's solo makespan for ordering.
            let mut keyed: Vec<(usize, f64)> = order
                .iter()
                .map(|&i| {
                    let job = apps[i].profile();
                    let p = MappingProblem {
                        catalog: self.catalog,
                        slowdowns: self.slowdowns,
                        job: &job,
                        alpha: self.alpha,
                        market: self.market,
                        spot_price_factor: 1.0,
                        budget_round: f64::INFINITY,
                        deadline_round: f64::INFINITY,
                        outlook: None,
                    };
                    let m = crate::mapping::exact::solve(&p)
                        .map(|s| s.eval.makespan)
                        .unwrap_or(f64::INFINITY);
                    (i, m)
                })
                .collect();
            crate::mapping::rank::sort_by_key_f64(&mut keyed, |x| x.1);
            order = keyed.into_iter().map(|(i, _)| i).collect();
        }
        let mut admitted = Vec::new();
        let mut queued = Vec::new();
        for i in order {
            match self.solve_residual(&apps[i]) {
                Some(job) => admitted.push(job),
                None => queued.push(apps[i].name.to_string()),
            }
        }
        MultiJobPlan { admitted, queued }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;
    use crate::cloudsim::{MultiCloud, RevocationModel};
    use crate::presched::PreScheduler;

    fn aws_env() -> (MultiCloud, SlowdownReport) {
        let mc = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            1,
        );
        let sl = PreScheduler::new(&mc).measure_defaults();
        (mc, sl)
    }

    fn two_client_til() -> AppSpec {
        crate::apps::til_aws_gcp()
    }

    #[test]
    fn single_job_admission_matches_solo_solve() {
        let (mc, sl) = aws_env();
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        let plan = sched.plan(&[two_client_til()], AdmissionPolicy::Fifo);
        assert_eq!(plan.admitted.len(), 1);
        assert!(plan.queued.is_empty());
        assert_eq!(mc.catalog.vm(plan.admitted[0].mapping.server).id, "vm313");
    }

    #[test]
    fn concurrent_jobs_share_quota_without_violation() {
        // Three 2-client TIL jobs want 2 GPUs each; AWS+GCP offer 4+4.
        // Admitting all three must spread across clouds / CPU VMs without
        // ever exceeding a provider's 4-GPU bound.
        let (mc, sl) = aws_env();
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        let apps = vec![two_client_til(), two_client_til(), two_client_til()];
        let plan = sched.plan(&apps, AdmissionPolicy::Fifo);
        // At least two jobs must be admitted (8 GPUs total across clouds),
        // and the combined reservation must satisfy all quotas.
        assert!(plan.admitted.len() >= 2, "admitted {}", plan.admitted.len());
        let mut all_vms = Vec::new();
        for j in &plan.admitted {
            all_vms.push(j.mapping.server);
            all_vms.extend(&j.mapping.clients);
        }
        assert!(crate::cloud::quota::assignment_fits(&mc.catalog, &all_vms).is_ok());
        for prov in mc.catalog.provider_ids() {
            let gpus: u32 = all_vms
                .iter()
                .filter(|&&v| mc.catalog.provider_of(v) == prov)
                .map(|&v| mc.catalog.vm(v).gpus)
                .sum();
            assert!(gpus <= 4, "provider {prov:?} over quota: {gpus}");
        }
    }

    #[test]
    fn release_lets_queued_job_in() {
        // Tighten the vCPU quota so the environment genuinely saturates
        // (with the stock 128-vCPU quota, CPU fallbacks absorb any load).
        let mut cat = tables::aws_gcp();
        for p in cat.providers.iter_mut() {
            p.max_vcpus = Some(24);
        }
        for r in cat.regions.iter_mut() {
            r.max_vcpus = Some(24);
        }
        let mc = MultiCloud::new(cat, tables::aws_gcp_ground_truth(), RevocationModel::none(), 1);
        let sl = PreScheduler::new(&mc).measure_defaults();
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        let apps = vec![two_client_til(); 6];
        let plan = sched.plan(&apps, AdmissionPolicy::Fifo);
        assert!(!plan.queued.is_empty(), "expected saturation with 6 jobs on 48 vCPUs");
        assert!(!plan.admitted.is_empty());
        let first = plan.admitted[0].clone();
        sched.release(&first);
        // The freed reservation admits another copy.
        let retry = sched.plan(&[two_client_til()], AdmissionPolicy::Fifo);
        assert_eq!(retry.admitted.len(), 1);
    }

    #[test]
    fn sjf_orders_by_predicted_makespan() {
        let (mc, sl) = aws_env();
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        // A slow app (big baseline) and a fast app.
        let mut slow = two_client_til();
        slow.name = "slow";
        slow.exec_bl_secs = 5000.0;
        let mut fast = two_client_til();
        fast.name = "fast";
        fast.exec_bl_secs = 100.0;
        let plan = sched.plan(&[slow, fast], AdmissionPolicy::ShortestMakespanFirst);
        assert_eq!(plan.admitted[0].name, "fast");
        assert!(plan.admitted[0].predicted_makespan < plan.admitted[1].predicted_makespan);
    }

    #[test]
    fn unbounded_cloudlab_admits_everything() {
        let mc = MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::none(),
            1,
        );
        let sl = PreScheduler::new(&mc).measure_defaults();
        let mut sched = MultiJobScheduler::new(&mc.catalog, &sl, 0.5, Market::OnDemand);
        let apps = vec![
            crate::apps::til(),
            crate::apps::shakespeare(),
            crate::apps::femnist(),
        ];
        let plan = sched.plan(&apps, AdmissionPolicy::Fifo);
        assert_eq!(plan.admitted.len(), 3);
        assert!(plan.queued.is_empty());
    }
}
