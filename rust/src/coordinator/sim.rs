//! Simulated-time execution of a whole Multi-FedLS job (§5 experiments).
//!
//! Drives the paper's full pipeline against the simulated multi-cloud:
//! Pre-Scheduling → Initial Mapping → provisioning (boot/preparation time)
//! → synchronous FL rounds → spot revocations (Poisson, §5.6) → Dynamic
//! Scheduler replacement → checkpoint-based recovery → teardown; with
//! per-second billing throughout. Reproduces Tables 5–8, Fig. 2 and the
//! §5.4/§5.7 validations.
//!
//! The FL application itself is round-synchronous (§3): a round's duration
//! is the makespan of its slowest client (exec + comm) plus server
//! aggregation and checkpoint overheads; a revocation anywhere restarts the
//! interrupted round once the replacement VM has booted (weights are re-sent
//! by the server, clients recompute — §4.3), and a server loss additionally
//! rolls back to the freshest checkpoint.

use crate::apps::AppSpec;
use crate::cloud::{Market, VmTypeId};
use crate::cloudsim::{MultiCloud, RevocationModel, VmId};
use crate::dynsched::{self, CurrentMap, DynSchedPolicy, FaultyTask};
use crate::ft::FtConfig;
use crate::mapping::problem::{JobProfile, MappingProblem};
use crate::mapping::{self, Mapping};
use crate::presched::{PreScheduler, SlowdownReport};
use crate::simul::SimTime;

/// Market scenario (§5.6): which tasks ride spot VMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// "Server and clients on spot VMs".
    AllSpot,
    /// "Server on an on-demand VM and clients on spot VMs".
    OnDemandServer,
    /// The no-revocation comparison rows ("only on-demand VMs").
    AllOnDemand,
}

impl Scenario {
    pub fn server_market(self) -> Market {
        match self {
            Scenario::AllSpot => Market::Spot,
            _ => Market::OnDemand,
        }
    }
    pub fn client_market(self) -> Market {
        match self {
            Scenario::AllOnDemand => Market::OnDemand,
            _ => Market::Spot,
        }
    }
    pub fn label(self) -> &'static str {
        match self {
            Scenario::AllSpot => "server and clients on spot VMs",
            Scenario::OnDemandServer => "server on-demand, clients on spot",
            Scenario::AllOnDemand => "all on-demand",
        }
    }

    /// Stable config-file key (job specs and sweep grids).
    pub fn key(self) -> &'static str {
        match self {
            Scenario::AllSpot => "all-spot",
            Scenario::OnDemandServer => "on-demand-server",
            Scenario::AllOnDemand => "all-on-demand",
        }
    }

    pub fn from_key(key: &str) -> Option<Scenario> {
        match key {
            "all-spot" => Some(Scenario::AllSpot),
            "on-demand-server" => Some(Scenario::OnDemandServer),
            "all-on-demand" => Some(Scenario::AllOnDemand),
            _ => None,
        }
    }
}

/// One experiment configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub app: AppSpec,
    /// Rounds to execute (overrides `app.n_rounds`; the §5.5/§5.6 TIL runs
    /// extend the application to ~80 rounds for longer executions).
    pub n_rounds: u32,
    pub alpha: f64,
    pub scenario: Scenario,
    /// Mean time between revocations `k_r` (None = no failures).
    pub revocation_mean_secs: Option<f64>,
    pub dynsched_policy: DynSchedPolicy,
    pub ft: FtConfig,
    /// Disable checkpointing entirely (the "without checkpoints" rows).
    pub checkpoints_enabled: bool,
    /// Cap on revocations per task. The paper's §5.6 runs observed "at most
    /// one revocation per task in each execution"; Tables 5–8 reproduce that
    /// regime with `Some(1)`. `None` = the unbounded Poisson process.
    pub max_revocations_per_task: Option<u32>,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(app: AppSpec, scenario: Scenario, seed: u64) -> Self {
        let n_rounds = app.n_rounds;
        Self {
            app,
            n_rounds,
            alpha: 0.5,
            scenario,
            revocation_mean_secs: None,
            dynsched_policy: DynSchedPolicy::same_vm_allowed(),
            ft: FtConfig::default(),
            checkpoints_enabled: true,
            max_revocations_per_task: None,
            seed,
        }
    }
}

/// Timestamped trace entry.
#[derive(Debug, Clone)]
pub struct SimEvent {
    pub at: SimTime,
    pub what: String,
}

/// End-to-end results of one simulated Multi-FedLS execution.
#[derive(Debug)]
pub struct SimOutcome {
    /// FL execution time only (first round start → last round end).
    pub fl_exec_secs: f64,
    /// Whole framework time (provisioning → teardown).
    pub total_secs: f64,
    pub total_cost: f64,
    pub vm_cost: f64,
    pub egress_cost: f64,
    pub n_revocations: u32,
    pub rounds_completed: u32,
    /// Chosen initial mapping (VM ids per task).
    pub initial_server: String,
    pub initial_clients: Vec<String>,
    pub events: Vec<SimEvent>,
    /// Predicted (model) per-round makespan/cost from the Initial Mapping.
    pub predicted_round_makespan: f64,
    pub predicted_round_cost: f64,
}

struct TaskState {
    vm_type: VmTypeId,
    instance: VmId,
    /// Rounds completed on this instance (warm-up applies on its first).
    rounds_on_instance: u32,
}

/// Run one simulated Multi-FedLS execution.
pub fn simulate(cfg: &SimConfig) -> anyhow::Result<SimOutcome> {
    let (catalog, ground_truth) = environment_for(&cfg.app);
    let mut mc = MultiCloud::new(
        catalog,
        ground_truth,
        match cfg.revocation_mean_secs {
            Some(k) => RevocationModel::poisson(k),
            None => RevocationModel::none(),
        },
        cfg.seed,
    );
    let mut events = Vec::new();
    let mut now = SimTime::ZERO;

    // --- Pre-Scheduling (cached in real deployments; §4.1) ---
    let slowdowns = PreScheduler::new(&mc).measure_defaults();
    let job = cfg.app.profile();

    // --- Initial Mapping (§4.2) ---
    // (The problem borrows a snapshot of the catalog so the simulator can be
    // mutated while the dynamic scheduler keeps consulting prices/slowdowns.)
    let catalog = mc.catalog.clone();
    let problem = MappingProblem {
        catalog: &catalog,
        slowdowns: &slowdowns,
        job: &job,
        alpha: cfg.alpha,
        market: cfg.scenario.client_market(),
        budget_round: f64::INFINITY,
        deadline_round: f64::INFINITY,
    };
    let sol = mapping::exact::solve(&problem)
        .ok_or_else(|| anyhow::anyhow!("initial mapping infeasible"))?;
    let initial: Mapping = sol.mapping.clone();
    events.push(SimEvent {
        at: now,
        what: format!(
            "initial mapping: server={} clients={:?} (predicted round {:.1}s, ${:.4})",
            mc.catalog.vm(initial.server).id,
            initial.clients.iter().map(|&v| mc.catalog.vm(v).id.clone()).collect::<Vec<_>>(),
            sol.eval.makespan,
            sol.eval.total_cost
        ),
    });

    // --- provision all tasks (boot in parallel) ---
    let server_market = cfg.scenario.server_market();
    let client_market = cfg.scenario.client_market();
    let mut server = TaskState {
        vm_type: initial.server,
        instance: mc.provision(now, initial.server, server_market)?,
        rounds_on_instance: 0,
    };
    let mut clients: Vec<TaskState> = Vec::new();
    for &vm in &initial.clients {
        clients.push(TaskState {
            vm_type: vm,
            instance: mc.provision(now, vm, client_market)?,
            rounds_on_instance: 0,
        });
    }
    let mut ready_at = mc.instance(server.instance).ready_at;
    for c in &clients {
        ready_at = ready_at.max(mc.instance(c.instance).ready_at);
    }
    now = ready_at;
    mc.mark_running(server.instance);
    for c in &clients {
        mc.mark_running(c.instance);
    }
    events.push(SimEvent { at: now, what: "all VMs prepared; FL execution starts".into() });
    let fl_start = now;

    // Dynamic Scheduler candidate sets (I_t), per task (§4.4).
    let all_vms: Vec<VmTypeId> = mc.catalog.vm_ids().collect();
    let mut server_set = all_vms.clone();
    let mut client_sets: Vec<Vec<VmTypeId>> = vec![all_vms.clone(); clients.len()];

    let mut n_revocations = 0u32;
    let mut revocations_per_task: Vec<u32> = vec![0; clients.len() + 1]; // [server, clients...]
    let mut completed = 0u32; // fully completed rounds
    // Freshest server-side checkpoint round (replicated → survives loss).
    let mut server_ckpt_round = 0u32;
    let mut safety = 0usize;

    while completed < cfg.n_rounds {
        safety += 1;
        anyhow::ensure!(safety < 200_000, "simulation did not converge (runaway revocations)");
        let round = completed + 1;

        // Round duration with the current placement.
        let duration = round_duration(cfg, &mc, &slowdowns, &job, &server, &clients);
        let end = now + duration;

        // Earliest spot revocation strictly before the round completes.
        let mut hit: Option<(SimTime, FaultyTask)> = None;
        let consider = |at: Option<SimTime>, task: FaultyTask, hit: &mut Option<(SimTime, FaultyTask)>| {
            if let Some(t) = at {
                if t > now && t <= end {
                    let better = hit.map_or(true, |(bt, _)| t < bt);
                    if better {
                        *hit = Some((t, task));
                    }
                }
            }
        };
        consider(mc.instance(server.instance).revocation_at, FaultyTask::Server, &mut hit);
        for (i, c) in clients.iter().enumerate() {
            consider(mc.instance(c.instance).revocation_at, FaultyTask::Client(i), &mut hit);
        }

        match hit {
            None => {
                // Round completes.
                now = end;
                server.rounds_on_instance += 1;
                for c in clients.iter_mut() {
                    c.rounds_on_instance += 1;
                }
                completed = round;
                if cfg.checkpoints_enabled && round % cfg.ft.server_every_rounds == 0 {
                    server_ckpt_round = round;
                }
                // Message-exchange costs (Eq. 6) for this round.
                for c in &clients {
                    let m = &job.msg;
                    mc.charge_egress(now, server.vm_type, m.s_train_gb + m.s_aggreg_gb, "server msgs");
                    mc.charge_egress(now, c.vm_type, m.c_train_gb + m.c_test_gb, "client msgs");
                }
            }
            Some((t_rev, faulty)) => {
                // Revocation interrupts the round; the round's work is lost.
                now = t_rev;
                n_revocations += 1;
                let current_map = CurrentMap {
                    server: server.vm_type,
                    clients: clients.iter().map(|c| c.vm_type).collect(),
                };
                let (task_name, old_type, set): (String, VmTypeId, &mut Vec<VmTypeId>) = match faulty {
                    FaultyTask::Server => ("server".into(), server.vm_type, &mut server_set),
                    FaultyTask::Client(i) => {
                        (format!("client-{i}"), clients[i].vm_type, &mut client_sets[i])
                    }
                };
                // Revoke in the platform (blocks the type per policy).
                let inst = match faulty {
                    FaultyTask::Server => server.instance,
                    FaultyTask::Client(i) => clients[i].instance,
                };
                mc.revoke(now, inst, cfg.dynsched_policy.remove_revoked);
                events.push(SimEvent {
                    at: now,
                    what: format!(
                        "revocation: {task_name} on {} during round {round}",
                        mc.catalog.vm(old_type).id
                    ),
                });

                // Dynamic Scheduler (Algorithm 3) picks the replacement.
                let (selection, new_set) = dynsched::select_instance(
                    &problem,
                    &current_map,
                    faulty,
                    set,
                    old_type,
                    cfg.dynsched_policy,
                );
                *set = new_set;
                let sel = selection
                    .ok_or_else(|| anyhow::anyhow!("dynamic scheduler exhausted candidates"))?;

                // Provision the replacement; everyone waits for its boot
                // (the server requires all clients each round, §4.3). When
                // the per-task revocation cap is reached the replacement is
                // not re-exposed to the Poisson process (§5.6.1's observed
                // "at most one revocation per task" regime).
                let task_idx = match faulty {
                    FaultyTask::Server => 0,
                    FaultyTask::Client(i) => i + 1,
                };
                revocations_per_task[task_idx] += 1;
                let allow_more = cfg
                    .max_revocations_per_task
                    .map_or(true, |cap| revocations_per_task[task_idx] < cap);
                let new_inst = mc.provision_with(
                    now,
                    sel.vm,
                    match faulty {
                        FaultyTask::Server => server_market,
                        FaultyTask::Client(_) => client_market,
                    },
                    allow_more,
                )?;
                let boot_done = mc.instance(new_inst).ready_at;
                events.push(SimEvent {
                    at: now,
                    what: format!(
                        "dynamic scheduler: {task_name} → {} (value {:.5}); booting until {}",
                        mc.catalog.vm(sel.vm).id,
                        sel.value,
                        boot_done.hms()
                    ),
                });
                match faulty {
                    FaultyTask::Server => {
                        server = TaskState { vm_type: sel.vm, instance: new_inst, rounds_on_instance: 0 };
                        // Recovery (§4.3): clients checkpoint every round →
                        // freshest state is round `completed`; without client
                        // checkpoints we fall back to the last server one.
                        let restore = if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
                            completed
                        } else if cfg.checkpoints_enabled {
                            server_ckpt_round
                        } else {
                            0
                        };
                        if restore < completed {
                            events.push(SimEvent {
                                at: now,
                                what: format!(
                                    "server restore from round {restore} (lost {} rounds)",
                                    completed - restore
                                ),
                            });
                            completed = restore;
                        }
                    }
                    FaultyTask::Client(i) => {
                        clients[i] =
                            TaskState { vm_type: sel.vm, instance: new_inst, rounds_on_instance: 0 };
                    }
                }
                // Other tasks idle (and bill) until the replacement is up.
                now = boot_done;
                mc.mark_running(new_inst);
            }
        }
    }

    let fl_end = now;
    // Teardown: terminate every live instance.
    let live: Vec<VmId> = mc.live_instances().map(|v| v.id).collect();
    for id in live {
        mc.terminate(now, id);
    }
    events.push(SimEvent { at: now, what: "all rounds complete; VMs terminated".into() });

    Ok(SimOutcome {
        fl_exec_secs: fl_end - fl_start,
        total_secs: now.secs(),
        total_cost: mc.total_cost(now),
        vm_cost: mc.ledger.vm_cost(now),
        egress_cost: mc.ledger.egress_cost(),
        n_revocations,
        rounds_completed: completed,
        initial_server: mc.catalog.vm(initial.server).id.clone(),
        initial_clients: initial
            .clients
            .iter()
            .map(|&v| mc.catalog.vm(v).id.clone())
            .collect(),
        events,
        predicted_round_makespan: sol.eval.makespan,
        predicted_round_cost: sol.eval.total_cost,
    })
}

/// Duration of one FL round for the current placement, including first-round
/// warm-up on fresh instances and checkpoint overheads (§5.5).
fn round_duration(
    cfg: &SimConfig,
    mc: &MultiCloud,
    slowdowns: &SlowdownReport,
    job: &JobProfile,
    server: &TaskState,
    clients: &[TaskState],
) -> f64 {
    let round_index = clients
        .iter()
        .map(|c| c.rounds_on_instance)
        .chain(std::iter::once(server.rounds_on_instance));
    let _ = round_index;
    let mut makespan: f64 = 0.0;
    for (i, c) in clients.iter().enumerate() {
        let first = c.rounds_on_instance == 0;
        let exec = mc.exec_secs(c.vm_type, job.client_train_bl[i] + job.client_test_bl[i], first);
        let comm = (job.train_comm_bl + job.test_comm_bl)
            * slowdowns.sl_comm(mc.catalog.region_of(c.vm_type), mc.catalog.region_of(server.vm_type));
        let mut t = exec + comm;
        // Client checkpoint: save received weights locally each round.
        if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
            t += cfg.ft.client_save_overhead_secs(cfg.app.checkpoint_gb);
        }
        makespan = makespan.max(t);
    }
    let agg = job.agg_bl * slowdowns.sl_inst(server.vm_type);
    let mut total = makespan + agg;
    // Server checkpoint every X rounds (local save is synchronous; the
    // replication overlaps the next round's waiting, §5.5).
    let next_round_number = {
        // round index being executed = completed + 1; pass via rounds_on_instance
        // is instance-local, so approximate with server instance age + 1.
        server.rounds_on_instance + 1
    };
    if cfg.checkpoints_enabled {
        // Constant bookkeeping overhead while server checkpointing is armed
        // plus the periodic synchronous save (Fig. 2 calibration).
        total += cfg.ft.server_round_overhead_secs;
        if next_round_number % cfg.ft.server_every_rounds == 0 {
            total += cfg.ft.save_overhead_secs(cfg.app.checkpoint_gb);
        }
    }
    total
}

/// The environment each application runs on (§5.2 / §5.7).
pub fn environment_for(app: &AppSpec) -> (crate::cloud::Catalog, crate::cloud::tables::GroundTruth) {
    use crate::cloud::tables;
    if app.name == "til-aws-gcp" {
        (tables::aws_gcp(), tables::aws_gcp_ground_truth())
    } else {
        (tables::cloudlab(), tables::cloudlab_ground_truth())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn til_on_demand_validation_matches_section_5_4() {
        // §5.4: model predicts 22:38 (1358 s) FL time and ~$15–16 total for
        // the 10-round TIL run; measured 24:47. Our simulated FL-exec time
        // must land in that window (warm-up puts us between the two).
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.rounds_completed, 10);
        assert_eq!(out.n_revocations, 0);
        assert!(
            out.fl_exec_secs > 1300.0 && out.fl_exec_secs < 1600.0,
            "fl_exec={}",
            out.fl_exec_secs
        );
        // Boot (39:43) dominates the total time on CloudLab, §5.4.
        assert!(out.total_secs > 2383.0 + out.fl_exec_secs - 1.0);
        // Initial mapping is the paper's (modulo the vm121/vm124 price tie).
        assert!(out.initial_server == "vm121" || out.initial_server == "vm124");
        assert_eq!(out.initial_clients, vec!["vm126"; 4]);
    }

    #[test]
    fn no_revocations_without_spot() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 7);
        cfg.revocation_mean_secs = Some(600.0); // aggressive, but no spot VMs
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.n_revocations, 0);
    }

    #[test]
    fn spot_run_with_failures_costs_more_time() {
        let mut base = SimConfig::new(apps::til(), Scenario::AllSpot, 1);
        base.n_rounds = 40;
        base.checkpoints_enabled = true;
        let calm = simulate(&base).unwrap();
        let mut stormy = base.clone();
        stormy.revocation_mean_secs = Some(3600.0);
        stormy.dynsched_policy = DynSchedPolicy::same_vm_allowed();
        let with_failures = simulate(&stormy).unwrap();
        assert!(with_failures.n_revocations > 0, "expected revocations at k_r=1h");
        assert!(with_failures.total_secs > calm.total_secs);
        assert_eq!(with_failures.rounds_completed, 40);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 99);
        cfg.n_rounds = 30;
        cfg.revocation_mean_secs = Some(7200.0);
        let a = simulate(&cfg).unwrap();
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.n_revocations, b.n_revocations);
        assert!((a.total_secs - b.total_secs).abs() < 1e-9);
        assert!((a.total_cost - b.total_cost).abs() < 1e-12);
    }

    #[test]
    fn different_vm_policy_blocks_reselection() {
        // With remove_revoked, a revoked client on vm126 must restart on a
        // different type (the paper observed vm138).
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 5);
        cfg.n_rounds = 60;
        cfg.revocation_mean_secs = Some(3600.0);
        cfg.dynsched_policy = DynSchedPolicy::different_vm();
        let out = simulate(&cfg).unwrap();
        assert!(out.n_revocations > 0, "expected revocations at k_r=1h over 60 rounds");
        // Every replacement must differ from the revoked type.
        let mut last_revoked: Option<String> = None;
        for e in &out.events {
            if let Some(rest) = e.what.strip_prefix("revocation: ") {
                // "revocation: <task> on <vm> during round N"
                let vm = rest.split(" on ").nth(1).unwrap().split(' ').next().unwrap();
                last_revoked = Some(vm.to_string());
            } else if e.what.starts_with("dynamic scheduler:") {
                let chosen = e.what.split("→ ").nth(1).unwrap().split(' ').next().unwrap();
                let revoked = last_revoked.take().expect("selection follows revocation");
                assert_ne!(chosen, revoked, "reselected the revoked type: {}", e.what);
            }
        }
    }

    #[test]
    fn server_loss_without_client_ckpt_rolls_back() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 2);
        cfg.n_rounds = 50;
        cfg.revocation_mean_secs = Some(2500.0);
        cfg.ft.client_checkpoint = false;
        cfg.ft.server_every_rounds = 10;
        let out = simulate(&cfg).unwrap();
        // Either some run lost rounds (restore event) or no server was hit;
        // both valid — but the run must still complete all rounds.
        assert_eq!(out.rounds_completed, 50);
    }

    #[test]
    fn on_demand_server_scenario_never_revokes_server() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::OnDemandServer, 3);
        cfg.n_rounds = 60;
        cfg.revocation_mean_secs = Some(3600.0);
        let out = simulate(&cfg).unwrap();
        for e in &out.events {
            assert!(
                !e.what.contains("revocation: server"),
                "server revoked in on-demand scenario: {}",
                e.what
            );
        }
    }

    #[test]
    fn checkpoint_overhead_increases_with_frequency() {
        // Fig. 2's shape: more frequent server checkpoints → more FL time.
        let mk = |every: u32| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 8);
            cfg.n_rounds = 80;
            cfg.ft.server_every_rounds = every;
            cfg.ft.client_checkpoint = false;
            simulate(&cfg).unwrap().fl_exec_secs
        };
        let t10 = mk(10);
        let t40 = mk(40);
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 8);
        cfg.n_rounds = 80;
        cfg.checkpoints_enabled = false;
        let t_none = simulate(&cfg).unwrap().fl_exec_secs;
        assert!(t10 > t40, "X=10 ({t10}) should cost more than X=40 ({t40})");
        assert!(t40 > t_none);
        // Overhead band: paper reports 6.29%–7.55% for X in 10..40.
        let ovh10 = (t10 - t_none) / t_none * 100.0;
        let ovh40 = (t40 - t_none) / t_none * 100.0;
        assert!(ovh10 > 5.5 && ovh10 < 9.5, "ovh10={ovh10}%");
        assert!(ovh40 > 4.5 && ovh40 < ovh10, "ovh40={ovh40}%");
    }

    #[test]
    fn aws_gcp_poc_runs_end_to_end() {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 4);
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.initial_server, "vm313");
        assert_eq!(out.initial_clients, vec!["vm311"; 2]);
        // §5.7: ~2:00:18 total, ~$3.28.
        assert!(
            out.total_secs > 6600.0 && out.total_secs < 8000.0,
            "total={}",
            out.total_secs
        );
        assert!(out.total_cost > 2.5 && out.total_cost < 4.5, "cost={}", out.total_cost);
    }
}
