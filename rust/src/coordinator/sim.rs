//! Simulated-time execution of a whole Multi-FedLS job (§5 experiments):
//! configuration ([`SimConfig`]), market scenarios ([`Scenario`]), outcomes
//! ([`SimOutcome`]) and the [`simulate`] entry point.
//!
//! The pipeline itself — Pre-Scheduling → Initial Mapping → provisioning →
//! synchronous FL rounds → spot revocations (Poisson, §5.6) → Dynamic
//! Scheduler replacement → checkpoint-based recovery → teardown, with
//! per-second billing throughout — lives in the composable
//! [`crate::framework`] event loop; [`simulate`] is a thin wrapper over the
//! default module stack and reproduces Tables 5–8, Fig. 2 and the §5.4/§5.7
//! validations.
//!
//! The FL application itself is round-synchronous (§3): a round's duration
//! is the makespan of its slowest client (exec + comm) plus server
//! aggregation and checkpoint overheads; a revocation anywhere restarts the
//! interrupted round once the replacement VM has booted (weights are re-sent
//! by the server, clients recompute — §4.3), and a server loss additionally
//! rolls back to the freshest checkpoint.

use crate::apps::AppSpec;
use crate::cloud::Market;
use crate::dynsched::DynSchedPolicy;
use crate::ft::FtConfig;
use crate::mapping::MapperKind;
use crate::market::MarketSpec;
use crate::outlook::OutlookSpec;
use crate::simul::SimTime;
use crate::telemetry::{EventKind, JobTelemetry, TelemetrySpec};

/// Market scenario (§5.6): which tasks ride spot VMs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// "Server and clients on spot VMs".
    AllSpot,
    /// "Server on an on-demand VM and clients on spot VMs".
    OnDemandServer,
    /// The no-revocation comparison rows ("only on-demand VMs").
    AllOnDemand,
}

impl Scenario {
    pub fn server_market(self) -> Market {
        match self {
            Scenario::AllSpot => Market::Spot,
            _ => Market::OnDemand,
        }
    }
    pub fn client_market(self) -> Market {
        match self {
            Scenario::AllOnDemand => Market::OnDemand,
            _ => Market::Spot,
        }
    }
    pub fn label(self) -> &'static str {
        match self {
            Scenario::AllSpot => "server and clients on spot VMs",
            Scenario::OnDemandServer => "server on-demand, clients on spot",
            Scenario::AllOnDemand => "all on-demand",
        }
    }

    /// Stable config-file key (job specs and sweep grids).
    pub fn key(self) -> &'static str {
        match self {
            Scenario::AllSpot => "all-spot",
            Scenario::OnDemandServer => "on-demand-server",
            Scenario::AllOnDemand => "all-on-demand",
        }
    }

    pub fn from_key(key: &str) -> Option<Scenario> {
        match key {
            "all-spot" => Some(Scenario::AllSpot),
            "on-demand-server" => Some(Scenario::OnDemandServer),
            "all-on-demand" => Some(Scenario::AllOnDemand),
            _ => None,
        }
    }
}

/// One experiment configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub app: AppSpec,
    /// Rounds to execute (overrides `app.n_rounds`; the §5.5/§5.6 TIL runs
    /// extend the application to ~80 rounds for longer executions).
    pub n_rounds: u32,
    pub alpha: f64,
    pub scenario: Scenario,
    /// Mean time between revocations `k_r` (None = no failures). Consumed
    /// by the default (exponential) market; other markets carry their own
    /// revocation parameters in [`SimConfig::market`].
    pub revocation_mean_secs: Option<f64>,
    /// The spot-market model: revocation process, price series, optional
    /// bid threshold (the `[market]` job-spec table / `markets` sweep axis).
    /// The default reproduces the paper's fixed-rate Poisson market.
    pub market: MarketSpec,
    /// Market-outlook configuration (the `[outlook]` job-spec table /
    /// `outlooks` sweep axis): when enabled, the planning stack consults a
    /// [`crate::outlook::MarketOutlook`] built from [`SimConfig::market`] —
    /// windowed candidate pricing in the Dynamic Scheduler and (with
    /// `defer = true`) delayed-start decisions in the Initial Mapping. The
    /// disabled default keeps every consumer on the flat expected-factor
    /// path, bit-identical to the outlook-less planner.
    pub outlook: OutlookSpec,
    /// Which Initial Mapping implementation to use (module selection; the
    /// `mapper` job-spec key / `mappers` sweep axis).
    pub mapper: MapperKind,
    pub dynsched_policy: DynSchedPolicy,
    pub ft: FtConfig,
    /// Disable checkpointing entirely (the "without checkpoints" rows).
    pub checkpoints_enabled: bool,
    /// Cap on revocations per task. The paper's §5.6 runs observed "at most
    /// one revocation per task in each execution"; Tables 5–8 reproduce that
    /// regime with `Some(1)`. `None` = the unbounded Poisson process.
    pub max_revocations_per_task: Option<u32>,
    /// `B_round` (Constraint 8): per-round budget in $ handed to the Initial
    /// Mapping solver. `INFINITY` = unconstrained (the historical behaviour).
    pub budget_round: f64,
    /// `T_round` (Constraint 9): per-round deadline in seconds.
    pub deadline_round: f64,
    /// Telemetry configuration (the `[telemetry]` job-spec table). Disabled
    /// by default; the event log itself is always collected (it is part of
    /// [`SimOutcome`]), but spans/metrics and the telemetry-only event kinds
    /// are only produced when enabled.
    pub telemetry: TelemetrySpec,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(app: AppSpec, scenario: Scenario, seed: u64) -> Self {
        let n_rounds = app.n_rounds;
        Self {
            app,
            n_rounds,
            alpha: 0.5,
            scenario,
            revocation_mean_secs: None,
            market: MarketSpec::default(),
            outlook: OutlookSpec::default(),
            mapper: MapperKind::Exact,
            dynsched_policy: DynSchedPolicy::same_vm_allowed(),
            ft: FtConfig::default(),
            checkpoints_enabled: true,
            max_revocations_per_task: None,
            budget_round: f64::INFINITY,
            deadline_round: f64::INFINITY,
            telemetry: TelemetrySpec::default(),
            seed,
        }
    }

    /// The crude pre-mapping job-length estimate — `n_rounds` baseline
    /// rounds — used as the planning horizon for expected-spot-price
    /// averaging. One definition shared by `framework::exec` (run-time
    /// planning) and the workload engine (admission-time planning) so both
    /// always price against the same horizon.
    pub fn planning_horizon_secs(&self) -> f64 {
        self.n_rounds as f64 * self.app.exec_bl_secs
    }

    /// Apply a `server_ckpt_every` setting: `X > 0` sets the server
    /// checkpoint cadence; `0` turns the periodic server save off, and —
    /// when the client side is also off — disables checkpointing entirely.
    /// Shared by job specs and the sweep-grid axis so both configuration
    /// surfaces keep identical semantics. Call after any
    /// `client_checkpoint` / `checkpoints` settings have been applied.
    pub fn set_server_ckpt_every(&mut self, every: u32) {
        if every == 0 {
            self.ft.server_every_rounds = u32::MAX;
            if !self.ft.client_checkpoint {
                self.checkpoints_enabled = false;
            }
        } else {
            self.ft.server_every_rounds = every;
        }
    }
}

/// Timestamped trace entry: a typed [`EventKind`] on the simulated clock.
/// [`SimEvent::what`] renders the historical human-readable line.
#[derive(Debug, Clone)]
pub struct SimEvent {
    pub at: SimTime,
    pub kind: EventKind,
}

impl SimEvent {
    /// The human-readable trace line (the pre-telemetry `what` string).
    pub fn what(&self) -> String {
        self.kind.render(self.at)
    }
}

/// End-to-end results of one simulated Multi-FedLS execution.
#[derive(Debug)]
pub struct SimOutcome {
    /// FL execution time only (first round start → last round end).
    pub fl_exec_secs: f64,
    /// Whole framework time (provisioning → teardown).
    pub total_secs: f64,
    pub total_cost: f64,
    pub vm_cost: f64,
    pub egress_cost: f64,
    pub n_revocations: u32,
    pub rounds_completed: u32,
    /// Chosen initial mapping (VM ids per task).
    pub initial_server: String,
    pub initial_clients: Vec<String>,
    pub events: Vec<SimEvent>,
    /// Predicted (model) per-round makespan/cost from the Initial Mapping.
    pub predicted_round_makespan: f64,
    pub predicted_round_cost: f64,
    /// Spans + metrics, present iff `cfg.telemetry.enabled`.
    pub telemetry: Option<JobTelemetry>,
}

/// Run one simulated Multi-FedLS execution through the default module stack
/// (thin wrapper over [`crate::framework::Framework::default_stack`]).
pub fn simulate(cfg: &SimConfig) -> anyhow::Result<SimOutcome> {
    crate::framework::Framework::default_stack().run(cfg)
}

/// The environment each application runs on (§5.2 / §5.7).
pub fn environment_for(app: &AppSpec) -> (crate::cloud::Catalog, crate::cloud::tables::GroundTruth) {
    use crate::cloud::tables;
    if app.name == "til-aws-gcp" {
        (tables::aws_gcp(), tables::aws_gcp_ground_truth())
    } else {
        (tables::cloudlab(), tables::cloudlab_ground_truth())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn til_on_demand_validation_matches_section_5_4() {
        // §5.4: model predicts 22:38 (1358 s) FL time and ~$15–16 total for
        // the 10-round TIL run; measured 24:47. Our simulated FL-exec time
        // must land in that window (warm-up puts us between the two).
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.rounds_completed, 10);
        assert_eq!(out.n_revocations, 0);
        assert!(
            out.fl_exec_secs > 1300.0 && out.fl_exec_secs < 1600.0,
            "fl_exec={}",
            out.fl_exec_secs
        );
        // Boot (39:43) dominates the total time on CloudLab, §5.4.
        assert!(out.total_secs > 2383.0 + out.fl_exec_secs - 1.0);
        // Initial mapping is the paper's (modulo the vm121/vm124 price tie).
        assert!(out.initial_server == "vm121" || out.initial_server == "vm124");
        assert_eq!(out.initial_clients, vec!["vm126"; 4]);
    }

    #[test]
    fn no_revocations_without_spot() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 7);
        cfg.revocation_mean_secs = Some(600.0); // aggressive, but no spot VMs
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.n_revocations, 0);
    }

    #[test]
    fn spot_run_with_failures_costs_more_time() {
        let mut base = SimConfig::new(apps::til(), Scenario::AllSpot, 1);
        base.n_rounds = 40;
        base.checkpoints_enabled = true;
        let calm = simulate(&base).unwrap();
        let mut stormy = base.clone();
        stormy.revocation_mean_secs = Some(3600.0);
        stormy.dynsched_policy = DynSchedPolicy::same_vm_allowed();
        let with_failures = simulate(&stormy).unwrap();
        assert!(with_failures.n_revocations > 0, "expected revocations at k_r=1h");
        assert!(with_failures.total_secs > calm.total_secs);
        assert_eq!(with_failures.rounds_completed, 40);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 99);
        cfg.n_rounds = 30;
        cfg.revocation_mean_secs = Some(7200.0);
        let a = simulate(&cfg).unwrap();
        let b = simulate(&cfg).unwrap();
        assert_eq!(a.n_revocations, b.n_revocations);
        assert!((a.total_secs - b.total_secs).abs() < 1e-9);
        assert!((a.total_cost - b.total_cost).abs() < 1e-12);
    }

    #[test]
    fn different_vm_policy_blocks_reselection() {
        // With remove_revoked, a revoked client on vm126 must restart on a
        // different type (the paper observed vm138).
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 5);
        cfg.n_rounds = 60;
        cfg.revocation_mean_secs = Some(3600.0);
        cfg.dynsched_policy = DynSchedPolicy::different_vm();
        let out = simulate(&cfg).unwrap();
        assert!(out.n_revocations > 0, "expected revocations at k_r=1h over 60 rounds");
        // Every replacement must differ from the revoked type.
        let mut last_revoked: Option<String> = None;
        for e in &out.events {
            let w = e.what();
            if let Some(rest) = w.strip_prefix("revocation: ") {
                // "revocation: <task> on <vm> during round N"
                let vm = rest.split(" on ").nth(1).unwrap().split(' ').next().unwrap();
                last_revoked = Some(vm.to_string());
            } else if w.starts_with("dynamic scheduler:") {
                let chosen = w.split("→ ").nth(1).unwrap().split(' ').next().unwrap();
                let revoked = last_revoked.take().expect("selection follows revocation");
                assert_ne!(chosen, revoked, "reselected the revoked type: {w}");
            }
        }
    }

    #[test]
    fn server_loss_without_client_ckpt_rolls_back() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllSpot, 2);
        cfg.n_rounds = 50;
        cfg.revocation_mean_secs = Some(2500.0);
        cfg.ft.client_checkpoint = false;
        cfg.ft.server_every_rounds = 10;
        let out = simulate(&cfg).unwrap();
        // Either some run lost rounds (restore event) or no server was hit;
        // both valid — but the run must still complete all rounds.
        assert_eq!(out.rounds_completed, 50);
    }

    #[test]
    fn on_demand_server_scenario_never_revokes_server() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::OnDemandServer, 3);
        cfg.n_rounds = 60;
        cfg.revocation_mean_secs = Some(3600.0);
        let out = simulate(&cfg).unwrap();
        for e in &out.events {
            let w = e.what();
            assert!(!w.contains("revocation: server"), "server revoked in on-demand scenario: {w}");
        }
    }

    #[test]
    fn checkpoint_overhead_increases_with_frequency() {
        // Fig. 2's shape: more frequent server checkpoints → more FL time.
        let mk = |every: u32| {
            let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 8);
            cfg.n_rounds = 80;
            cfg.ft.server_every_rounds = every;
            cfg.ft.client_checkpoint = false;
            simulate(&cfg).unwrap().fl_exec_secs
        };
        let t10 = mk(10);
        let t40 = mk(40);
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 8);
        cfg.n_rounds = 80;
        cfg.checkpoints_enabled = false;
        let t_none = simulate(&cfg).unwrap().fl_exec_secs;
        assert!(t10 > t40, "X=10 ({t10}) should cost more than X=40 ({t40})");
        assert!(t40 > t_none);
        // Overhead band: paper reports 6.29%–7.55% for X in 10..40.
        let ovh10 = (t10 - t_none) / t_none * 100.0;
        let ovh40 = (t40 - t_none) / t_none * 100.0;
        assert!(ovh10 > 5.5 && ovh10 < 9.5, "ovh10={ovh10}%");
        assert!(ovh40 > 4.5 && ovh40 < ovh10, "ovh40={ovh40}%");
    }

    #[test]
    fn aws_gcp_poc_runs_end_to_end() {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, 4);
        cfg.checkpoints_enabled = false;
        let out = simulate(&cfg).unwrap();
        assert_eq!(out.initial_server, "vm313");
        assert_eq!(out.initial_clients, vec!["vm311"; 2]);
        // §5.7: ~2:00:18 total, ~$3.28.
        assert!(
            out.total_secs > 6600.0 && out.total_secs < 8000.0,
            "total={}",
            out.total_secs
        );
        assert!(out.total_cost > 2.5 && out.total_cost < 4.5, "cost={}", out.total_cost);
    }
}
