//! The Multi-FedLS coordinator: configuration (TOML job specs), the
//! simulated-time experiment driver ([`sim`]), the real-compute driver
//! ([`real`]) and multi-trial aggregation (the paper averages 3 executions
//! per table row).

pub mod multijob;
pub mod real;
pub mod sim;

pub use sim::{simulate, Scenario, SimConfig, SimOutcome};

use crate::dynsched::DynSchedPolicy;
use crate::simul::SimTime;

/// Averages over repeated executions of one configuration (the paper's
/// tables report 3-run averages).
#[derive(Debug, Clone)]
pub struct TrialStats {
    pub trials: usize,
    pub avg_revocations: f64,
    pub avg_exec_secs: f64,
    pub avg_total_secs: f64,
    pub avg_cost: f64,
    pub min_cost: f64,
    pub max_cost: f64,
}

impl TrialStats {
    pub fn exec_hms(&self) -> String {
        SimTime::from_secs(self.avg_total_secs).hms()
    }
    pub fn fl_hms(&self) -> String {
        SimTime::from_secs(self.avg_exec_secs).hms()
    }
}

/// Run `trials` executions with seeds `base_seed..base_seed+trials`.
pub fn run_trials(cfg: &SimConfig, trials: usize, base_seed: u64) -> anyhow::Result<TrialStats> {
    anyhow::ensure!(trials > 0);
    let mut revocations = 0.0;
    let mut exec = 0.0;
    let mut total = 0.0;
    let mut cost = 0.0;
    let mut min_cost = f64::INFINITY;
    let mut max_cost = f64::NEG_INFINITY;
    for t in 0..trials {
        let mut c = cfg.clone();
        c.seed = base_seed + t as u64;
        let out = sim::simulate(&c)?;
        revocations += out.n_revocations as f64;
        exec += out.fl_exec_secs;
        total += out.total_secs;
        cost += out.total_cost;
        min_cost = min_cost.min(out.total_cost);
        max_cost = max_cost.max(out.total_cost);
    }
    let n = trials as f64;
    Ok(TrialStats {
        trials,
        avg_revocations: revocations / n,
        avg_exec_secs: exec / n,
        avg_total_secs: total / n,
        avg_cost: cost / n,
        min_cost,
        max_cost,
    })
}

/// A TOML job specification (the framework's user-facing config):
///
/// ```toml
/// app = "til"
/// rounds = 80
/// alpha = 0.5
/// scenario = "all-spot"        # all-spot | on-demand-server | all-on-demand
/// revocation_mean_secs = 7200.0 # omit for no failures
/// remove_revoked_type = true    # Algorithm 3 policy
/// server_ckpt_every = 10
/// client_checkpoint = true
/// checkpoints = true
/// seed = 42
/// trials = 3
/// ```
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: SimConfig,
    pub trials: usize,
}

impl JobSpec {
    pub fn from_toml(text: &str) -> anyhow::Result<JobSpec> {
        let root = crate::util::tomlmini::parse(text)?;
        let app_name = root
            .get("app")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("job spec missing `app`"))?;
        let app = crate::apps::by_name(app_name)
            .ok_or_else(|| anyhow::anyhow!("unknown app {app_name}"))?;
        let scenario = match root.get("scenario").and_then(|v| v.as_str()).unwrap_or("all-on-demand") {
            "all-spot" => Scenario::AllSpot,
            "on-demand-server" => Scenario::OnDemandServer,
            "all-on-demand" => Scenario::AllOnDemand,
            other => anyhow::bail!("unknown scenario {other}"),
        };
        let seed = root.get("seed").and_then(|v| v.as_int()).unwrap_or(42) as u64;
        let mut config = SimConfig::new(app, scenario, seed);
        if let Some(r) = root.get("rounds").and_then(|v| v.as_int()) {
            config.n_rounds = r as u32;
        }
        if let Some(a) = root.get("alpha").and_then(|v| v.as_float()) {
            anyhow::ensure!((0.0..=1.0).contains(&a), "alpha must be in [0,1]");
            config.alpha = a;
        }
        config.revocation_mean_secs = root.get("revocation_mean_secs").and_then(|v| v.as_float());
        if let Some(b) = root.get("remove_revoked_type").and_then(|v| v.as_bool()) {
            config.dynsched_policy = if b {
                DynSchedPolicy::different_vm()
            } else {
                DynSchedPolicy::same_vm_allowed()
            };
        }
        if let Some(x) = root.get("server_ckpt_every").and_then(|v| v.as_int()) {
            config.ft.server_every_rounds = x as u32;
        }
        if let Some(b) = root.get("client_checkpoint").and_then(|v| v.as_bool()) {
            config.ft.client_checkpoint = b;
        }
        if let Some(b) = root.get("checkpoints").and_then(|v| v.as_bool()) {
            config.checkpoints_enabled = b;
        }
        let trials = root.get("trials").and_then(|v| v.as_int()).unwrap_or(1) as usize;
        Ok(JobSpec { config, trials })
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<JobSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_parses_full_config() {
        let spec = JobSpec::from_toml(
            r#"
app = "til"
rounds = 80
alpha = 0.4
scenario = "all-spot"
revocation_mean_secs = 7200.0
remove_revoked_type = true
server_ckpt_every = 20
client_checkpoint = false
seed = 7
trials = 3
"#,
        )
        .unwrap();
        assert_eq!(spec.config.app.name, "til");
        assert_eq!(spec.config.n_rounds, 80);
        assert_eq!(spec.config.alpha, 0.4);
        assert_eq!(spec.config.scenario, Scenario::AllSpot);
        assert_eq!(spec.config.revocation_mean_secs, Some(7200.0));
        assert!(spec.config.dynsched_policy.remove_revoked);
        assert_eq!(spec.config.ft.server_every_rounds, 20);
        assert!(!spec.config.ft.client_checkpoint);
        assert_eq!(spec.trials, 3);
    }

    #[test]
    fn job_spec_defaults() {
        let spec = JobSpec::from_toml("app = \"femnist\"\n").unwrap();
        assert_eq!(spec.config.n_rounds, 100); // app default
        assert_eq!(spec.config.scenario, Scenario::AllOnDemand);
        assert_eq!(spec.trials, 1);
        assert!(spec.config.revocation_mean_secs.is_none());
    }

    #[test]
    fn job_spec_rejects_unknown_app_and_scenario() {
        assert!(JobSpec::from_toml("app = \"nope\"\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\nscenario = \"weird\"\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\nalpha = 2.0\n").is_err());
    }

    #[test]
    fn trials_average_and_bound_costs() {
        let mut cfg = SimConfig::new(crate::apps::til(), Scenario::AllSpot, 0);
        cfg.n_rounds = 20;
        cfg.revocation_mean_secs = Some(7200.0);
        let stats = run_trials(&cfg, 3, 100).unwrap();
        assert_eq!(stats.trials, 3);
        assert!(stats.min_cost <= stats.avg_cost && stats.avg_cost <= stats.max_cost);
        assert!(stats.avg_total_secs > 0.0);
    }
}
