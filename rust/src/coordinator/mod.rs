//! The Multi-FedLS coordinator: configuration (TOML job specs), the
//! simulated-time experiment driver ([`sim`]), the real-compute driver
//! ([`real`]) and multi-trial aggregation (the paper averages 3 executions
//! per table row). Trial fan-out is delegated to the [`crate::sweep`]
//! campaign engine, so repeated executions run across a worker pool.

pub mod multijob;
pub mod real;
pub mod sim;

pub use sim::{simulate, Scenario, SimConfig, SimOutcome};

use crate::simul::SimTime;
use crate::sweep::{self, MetricAgg, TrialOutcome};

/// Aggregates over repeated executions of one configuration. The paper's
/// tables report 3-run averages; each metric additionally carries sample
/// stddev, min/max, and a 95% confidence interval (see [`MetricAgg`]).
#[derive(Debug, Clone)]
pub struct TrialStats {
    pub trials: usize,
    pub revocations: MetricAgg,
    /// FL execution time only (first round start → last round end).
    pub exec_secs: MetricAgg,
    /// Whole framework time (provisioning → teardown).
    pub total_secs: MetricAgg,
    pub cost: MetricAgg,
}

impl TrialStats {
    pub fn from_outcomes(outs: &[TrialOutcome]) -> TrialStats {
        let col = |f: fn(&TrialOutcome) -> f64| -> MetricAgg {
            MetricAgg::from_samples(&outs.iter().map(f).collect::<Vec<_>>())
        };
        TrialStats {
            trials: outs.len(),
            revocations: col(|o| o.revocations),
            exec_secs: col(|o| o.fl_exec_secs),
            total_secs: col(|o| o.total_secs),
            cost: col(|o| o.cost),
        }
    }

    /// Mean whole-framework time as `H:MM:SS` (the tables' "exec. time").
    pub fn exec_hms(&self) -> String {
        SimTime::from_secs(self.total_secs.mean).hms()
    }

    /// Mean FL execution time as `H:MM:SS`.
    pub fn fl_hms(&self) -> String {
        SimTime::from_secs(self.exec_secs.mean).hms()
    }
}

/// Run `trials` executions with seeds `base_seed..base_seed+trials`, fanned
/// out over the sweep worker pool (one worker per core). Every seed is fixed
/// before the pool starts, so results are identical to the historical serial
/// loop regardless of worker count.
pub fn run_trials(cfg: &SimConfig, trials: usize, base_seed: u64) -> anyhow::Result<TrialStats> {
    run_trials_with_jobs(cfg, trials, base_seed, 0)
}

/// [`run_trials`] with an explicit worker count (0 = one per core, 1 = serial).
pub fn run_trials_with_jobs(
    cfg: &SimConfig,
    trials: usize,
    base_seed: u64,
    jobs: usize,
) -> anyhow::Result<TrialStats> {
    anyhow::ensure!(trials > 0);
    let point = sweep::PointSpec {
        tags: Vec::new(),
        cfg: cfg.clone(),
        seeds: (0..trials as u64).map(|t| base_seed + t).collect(),
    };
    let mut stats = sweep::run_campaign(std::slice::from_ref(&point), jobs)?;
    Ok(stats.pop().expect("one point"))
}

/// A TOML job specification (the framework's user-facing config):
///
/// ```toml
/// app = "til"
/// rounds = 80
/// alpha = 0.5
/// scenario = "all-spot"        # all-spot | on-demand-server | all-on-demand
/// mapper = "exact"              # exact | milp | cheapest | fastest | random | single-cloud
/// revocation_mean_secs = 7200.0 # omit for no failures
/// remove_revoked_type = true    # Algorithm 3 policy
/// server_ckpt_every = 10        # 0 = server checkpointing off
/// client_checkpoint = true
/// checkpoints = true
/// max_revocations_per_task = 1  # §5.6.1 observed regime; omit for unbounded
/// budget_round = 2.5            # B_round, $ per round (omit = unconstrained)
/// deadline_round = 900.0        # T_round, seconds per round (omit = unconstrained)
/// seed = 42
/// trials = 3
///
/// [market]                      # optional spot-market model (omit = the
/// revocation = "seasonal"       # paper's exponential k_r at constant price;
/// mean_secs = 7200.0            # see crate::market::spec for every key)
/// period_secs = 86400.0
///
/// [outlook]                     # optional market-aware planning (omit =
/// horizon = 14400.0             # the flat expected-factor path; see
/// bid_risk = 0.1                # crate::outlook::spec for every key)
/// defer = true
///
/// [telemetry]                   # optional structured telemetry (omit = off;
/// spans = true                  # presence enables — see
/// metrics = true                # crate::telemetry::spec for every key)
/// ```
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: SimConfig,
    pub trials: usize,
}

impl JobSpec {
    pub fn from_toml(text: &str) -> anyhow::Result<JobSpec> {
        let root = crate::util::tomlmini::parse(text)?;
        Self::from_table_with_base(&root, None)
    }

    /// Parse a job spec out of an already-parsed TOML table. Workload specs
    /// reuse this for each `[[job]]` entry, so the single-job and multi-job
    /// configuration surfaces share one set of keys and semantics.
    pub fn from_table(
        root: &std::collections::BTreeMap<String, crate::util::tomlmini::Value>,
    ) -> anyhow::Result<JobSpec> {
        Self::from_table_with_base(root, None)
    }

    /// [`Self::from_table`] with the spec file's directory for resolving
    /// relative `[market]` trace-file references.
    pub fn from_table_with_base(
        root: &std::collections::BTreeMap<String, crate::util::tomlmini::Value>,
        base: Option<&std::path::Path>,
    ) -> anyhow::Result<JobSpec> {
        use crate::dynsched::DynSchedPolicy;
        crate::util::tomlmini::reject_unknown_keys(
            root,
            &[
                "app",
                "rounds",
                "alpha",
                "scenario",
                "mapper",
                "revocation_mean_secs",
                "remove_revoked_type",
                "checkpoints",
                "client_checkpoint",
                "server_ckpt_every",
                "max_revocations_per_task",
                "budget_round",
                "deadline_round",
                "seed",
                "trials",
                "market",
                "outlook",
                "telemetry",
            ],
            "job spec",
        )?;
        let app_name = root
            .get("app")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("job spec missing `app`"))?;
        let app = crate::apps::by_name(app_name)
            .ok_or_else(|| anyhow::anyhow!("unknown app {app_name}"))?;
        let scenario_key = root.get("scenario").and_then(|v| v.as_str()).unwrap_or("all-on-demand");
        let scenario = Scenario::from_key(scenario_key)
            .ok_or_else(|| anyhow::anyhow!("unknown scenario {scenario_key}"))?;
        // Negative integers must error, not wrap through the `as` casts.
        let get_nonneg = |key: &str| -> anyhow::Result<Option<i64>> {
            match root.get(key).and_then(|v| v.as_int()) {
                Some(x) if x < 0 => anyhow::bail!("{key} must be non-negative, got {x}"),
                other => Ok(other),
            }
        };
        let seed = get_nonneg("seed")?.unwrap_or(42) as u64;
        let mut config = SimConfig::new(app, scenario, seed);
        if let Some(r) = get_nonneg("rounds")? {
            config.n_rounds = r as u32;
        }
        if let Some(a) = root.get("alpha").and_then(|v| v.as_float()) {
            anyhow::ensure!((0.0..=1.0).contains(&a), "alpha must be in [0,1]");
            config.alpha = a;
        }
        config.revocation_mean_secs = root.get("revocation_mean_secs").and_then(|v| v.as_float());
        if let Some(m) = root.get("mapper").and_then(|v| v.as_str()) {
            config.mapper = crate::mapping::MapperKind::from_key(m)
                .ok_or_else(|| anyhow::anyhow!("unknown mapper {m}"))?;
        }
        if let Some(b) = root.get("remove_revoked_type").and_then(|v| v.as_bool()) {
            config.dynsched_policy = if b {
                DynSchedPolicy::different_vm()
            } else {
                DynSchedPolicy::same_vm_allowed()
            };
        }
        if let Some(b) = root.get("checkpoints").and_then(|v| v.as_bool()) {
            config.checkpoints_enabled = b;
        }
        if let Some(b) = root.get("client_checkpoint").and_then(|v| v.as_bool()) {
            config.ft.client_checkpoint = b;
        }
        if let Some(x) = get_nonneg("server_ckpt_every")? {
            anyhow::ensure!(x <= u32::MAX as i64, "server_ckpt_every {x} out of range");
            config.set_server_ckpt_every(x as u32);
        }
        if let Some(m) = get_nonneg("max_revocations_per_task")? {
            config.max_revocations_per_task = Some(m as u32);
        }
        if let Some(b) = root.get("budget_round").and_then(|v| v.as_float()) {
            anyhow::ensure!(b > 0.0, "budget_round must be positive, got {b}");
            config.budget_round = b;
        }
        if let Some(d) = root.get("deadline_round").and_then(|v| v.as_float()) {
            anyhow::ensure!(d > 0.0, "deadline_round must be positive, got {d}");
            config.deadline_round = d;
        }
        // Spot-market model: a `[market]` table (job specs) — a bare string
        // is a named-market reference, which only workload specs can resolve.
        match root.get("market") {
            None => {}
            Some(crate::util::tomlmini::Value::Table(tbl)) => {
                config.market = crate::market::MarketSpec::from_table(tbl, base)?;
            }
            Some(crate::util::tomlmini::Value::Str(name)) => anyhow::bail!(
                "market = \"{name}\" by name is only valid inside workload [[job]] tables \
                 (use a [market] table here)"
            ),
            Some(_) => anyhow::bail!("[market] must be a table"),
        }
        // Market outlook: an `[outlook]` table (job specs) — a bare string
        // is a named-outlook reference, which only workload specs can
        // resolve.
        match root.get("outlook") {
            None => {}
            Some(crate::util::tomlmini::Value::Table(tbl)) => {
                config.outlook = crate::outlook::OutlookSpec::from_table(tbl)?;
            }
            Some(crate::util::tomlmini::Value::Str(name)) => anyhow::bail!(
                "outlook = \"{name}\" by name is only valid inside workload [[job]] tables \
                 (use an [outlook] table here)"
            ),
            Some(_) => anyhow::bail!("[outlook] must be a table"),
        }
        // Telemetry: a `[telemetry]` table (presence enables unless
        // `enabled = false` inside it).
        match root.get("telemetry") {
            None => {}
            Some(crate::util::tomlmini::Value::Table(tbl)) => {
                config.telemetry = crate::telemetry::TelemetrySpec::from_table(tbl)?;
            }
            Some(_) => anyhow::bail!("[telemetry] must be a table"),
        }
        let trials = get_nonneg("trials")?.unwrap_or(1) as usize;
        Ok(JobSpec { config, trials })
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<JobSpec> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let root = crate::util::tomlmini::parse(&text)?;
        Self::from_table_with_base(&root, path.parent())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_parses_full_config() {
        let spec = JobSpec::from_toml(
            r#"
app = "til"
rounds = 80
alpha = 0.4
scenario = "all-spot"
revocation_mean_secs = 7200.0
remove_revoked_type = true
server_ckpt_every = 20
client_checkpoint = false
max_revocations_per_task = 1
seed = 7
trials = 3
"#,
        )
        .unwrap();
        assert_eq!(spec.config.app.name, "til");
        assert_eq!(spec.config.n_rounds, 80);
        assert_eq!(spec.config.alpha, 0.4);
        assert_eq!(spec.config.scenario, Scenario::AllSpot);
        assert_eq!(spec.config.revocation_mean_secs, Some(7200.0));
        assert!(spec.config.dynsched_policy.remove_revoked);
        assert_eq!(spec.config.ft.server_every_rounds, 20);
        assert!(!spec.config.ft.client_checkpoint);
        assert_eq!(spec.config.max_revocations_per_task, Some(1));
        assert_eq!(spec.trials, 3);
    }

    #[test]
    fn job_spec_defaults() {
        let spec = JobSpec::from_toml("app = \"femnist\"\n").unwrap();
        assert_eq!(spec.config.n_rounds, 100); // app default
        assert_eq!(spec.config.scenario, Scenario::AllOnDemand);
        assert_eq!(spec.trials, 1);
        assert!(spec.config.revocation_mean_secs.is_none());
    }

    #[test]
    fn job_spec_parses_mapper_selection() {
        let spec = JobSpec::from_toml("app = \"til\"\nmapper = \"cheapest\"\n").unwrap();
        assert_eq!(spec.config.mapper, crate::mapping::MapperKind::Cheapest);
        // Default is the exact solver.
        let spec = JobSpec::from_toml("app = \"til\"\n").unwrap();
        assert_eq!(spec.config.mapper, crate::mapping::MapperKind::Exact);
        assert!(JobSpec::from_toml("app = \"til\"\nmapper = \"nope\"\n").is_err());
        // server_ckpt_every = 0 disables the periodic save instead of
        // crashing the round-cadence modulo; client checkpointing (default
        // on) keeps the checkpoint machinery armed.
        let spec = JobSpec::from_toml("app = \"til\"\nserver_ckpt_every = 0\n").unwrap();
        assert_eq!(spec.config.ft.server_every_rounds, u32::MAX);
        assert!(spec.config.checkpoints_enabled);
        // With the client side also off, nothing is checkpointed at all —
        // the same semantics as the sweep grid's server_ckpt_every axis.
        let spec = JobSpec::from_toml(
            "app = \"til\"\nserver_ckpt_every = 0\nclient_checkpoint = false\n",
        )
        .unwrap();
        assert!(!spec.config.checkpoints_enabled);
    }

    #[test]
    fn job_spec_parses_budget_and_deadline() {
        let spec = JobSpec::from_toml(
            "app = \"til\"\nbudget_round = 2.5\ndeadline_round = 900.0\n",
        )
        .unwrap();
        assert_eq!(spec.config.budget_round, 2.5);
        assert_eq!(spec.config.deadline_round, 900.0);
        // Defaults are unconstrained (the historical behaviour).
        let spec = JobSpec::from_toml("app = \"til\"\n").unwrap();
        assert!(spec.config.budget_round.is_infinite());
        assert!(spec.config.deadline_round.is_infinite());
        // Non-positive constraints are configuration errors.
        assert!(JobSpec::from_toml("app = \"til\"\nbudget_round = 0.0\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\ndeadline_round = -1.0\n").is_err());
    }

    #[test]
    fn job_spec_parses_telemetry_table() {
        // Presence enables; the default is off; non-table forms are errors.
        let spec = JobSpec::from_toml("app = \"til\"\n\n[telemetry]\n").unwrap();
        assert!(spec.config.telemetry.enabled && spec.config.telemetry.spans);
        let spec = JobSpec::from_toml("app = \"til\"\n\n[telemetry]\nspans = false\n").unwrap();
        assert!(spec.config.telemetry.enabled && !spec.config.telemetry.spans);
        let spec = JobSpec::from_toml("app = \"til\"\n").unwrap();
        assert!(!spec.config.telemetry.enabled);
        assert!(JobSpec::from_toml("app = \"til\"\ntelemetry = true\n").is_err());
    }

    #[test]
    fn job_spec_rejects_unknown_app_and_scenario() {
        assert!(JobSpec::from_toml("app = \"nope\"\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\nscenario = \"weird\"\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\nalpha = 2.0\n").is_err());
        // Negative ints must error, not wrap through the u32/u64 casts.
        assert!(JobSpec::from_toml("app = \"til\"\nrounds = -5\n").is_err());
        assert!(JobSpec::from_toml("app = \"til\"\nmax_revocations_per_task = -1\n").is_err());
    }

    #[test]
    fn trials_average_and_bound_costs() {
        let mut cfg = SimConfig::new(crate::apps::til(), Scenario::AllSpot, 0);
        cfg.n_rounds = 20;
        cfg.revocation_mean_secs = Some(7200.0);
        let stats = run_trials(&cfg, 3, 100).unwrap();
        assert_eq!(stats.trials, 3);
        assert!(stats.cost.min <= stats.cost.mean && stats.cost.mean <= stats.cost.max);
        assert!(stats.total_secs.mean > 0.0);
        assert!(stats.cost.stddev >= 0.0 && stats.cost.ci95 >= 0.0);
    }

    #[test]
    fn trial_stats_hand_computed_three_trial_case() {
        // Regression for the aggregate formulas on a hand-computed case:
        // costs 10/20/30 → mean 20, sample stddev 10, CI half-width
        // 1.96·10/√3 ≈ 11.31609.
        let outs: Vec<TrialOutcome> = [10.0f64, 20.0, 30.0]
            .iter()
            .map(|&c| TrialOutcome {
                revocations: 1.0,
                fl_exec_secs: 2.0 * c,
                total_secs: 3.0 * c,
                cost: c,
                rounds_completed: 5,
            })
            .collect();
        let s = TrialStats::from_outcomes(&outs);
        assert_eq!(s.trials, 3);
        assert!((s.cost.mean - 20.0).abs() < 1e-12);
        assert!((s.cost.stddev - 10.0).abs() < 1e-12);
        assert!((s.cost.min - 10.0).abs() < 1e-12);
        assert!((s.cost.max - 30.0).abs() < 1e-12);
        assert!((s.cost.ci95 - 11.316090442).abs() < 1e-6);
        // Linearity: total_secs = 3×cost, so its aggregates scale by 3.
        assert!((s.total_secs.mean - 60.0).abs() < 1e-12);
        assert!((s.total_secs.stddev - 30.0).abs() < 1e-12);
        assert!((s.revocations.stddev - 0.0).abs() < 1e-12);
    }

    #[test]
    fn run_trials_identical_across_worker_counts() {
        let mut cfg = SimConfig::new(crate::apps::til(), Scenario::AllSpot, 0);
        cfg.n_rounds = 20;
        cfg.revocation_mean_secs = Some(7200.0);
        let serial = run_trials_with_jobs(&cfg, 3, 100, 1).unwrap();
        let parallel = run_trials_with_jobs(&cfg, 3, 100, 8).unwrap();
        assert_eq!(serial.cost.mean.to_bits(), parallel.cost.mean.to_bits());
        assert_eq!(serial.cost.stddev.to_bits(), parallel.cost.stddev.to_bits());
        assert_eq!(serial.total_secs.mean.to_bits(), parallel.total_secs.mean.to_bits());
        assert_eq!(serial.revocations.mean.to_bits(), parallel.revocations.mean.to_bits());
    }
}
