//! Simulation substrate: deterministic RNG, virtual time, and a
//! discrete-event engine. These are the foundations of the multi-cloud
//! simulator in [`crate::cloudsim`].

pub mod des;
pub mod rng;
pub mod time;

pub use des::{EventId, Simulator};
pub use rng::Rng;
pub use time::SimTime;
