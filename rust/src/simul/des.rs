//! A minimal discrete-event simulation (DES) engine.
//!
//! The failure-simulation experiments of the paper (§5.6, Tables 5–8) run FL
//! jobs lasting simulated *hours* under Poisson revocation processes. We
//! advance a virtual clock through a priority queue of events instead of
//! sleeping in wall-clock time. Ties are broken by insertion order (FIFO) so
//! simulations are fully deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;

/// Handle used to cancel a scheduled event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(u64);

struct Scheduled<E> {
    time: SimTime,
    seq: u64,
    id: EventId,
    payload: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock.
pub struct Simulator<E> {
    queue: BinaryHeap<Scheduled<E>>,
    cancelled: std::collections::HashSet<EventId>,
    now: SimTime,
    seq: u64,
    next_id: u64,
}

impl<E> Default for Simulator<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Simulator<E> {
    pub fn new() -> Self {
        Self {
            queue: BinaryHeap::new(),
            cancelled: std::collections::HashSet::new(),
            now: SimTime::ZERO,
            seq: 0,
            next_id: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` to fire at absolute time `at`.
    ///
    /// Panics if `at` is in the (virtual) past: the engine never rewinds.
    pub fn schedule_at(&mut self, at: SimTime, payload: E) -> EventId {
        assert!(
            at >= self.now,
            "cannot schedule into the past: at={} now={}",
            at.secs(),
            self.now.secs()
        );
        let id = EventId(self.next_id);
        self.next_id += 1;
        self.queue.push(Scheduled {
            time: at,
            seq: self.seq,
            id,
            payload,
        });
        self.seq += 1;
        id
    }

    /// Schedule `payload` to fire `delay` seconds from now.
    pub fn schedule_in(&mut self, delay: f64, payload: E) -> EventId {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.schedule_at(self.now + delay, payload)
    }

    /// Cancel a previously scheduled event. Returns true if the event had not
    /// yet fired (cancellation is lazy: the entry is dropped at pop time).
    pub fn cancel(&mut self, id: EventId) -> bool {
        self.cancelled.insert(id)
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn next_event(&mut self) -> Option<(SimTime, E)> {
        while let Some(ev) = self.queue.pop() {
            if self.cancelled.remove(&ev.id) {
                continue;
            }
            debug_assert!(ev.time >= self.now);
            self.now = ev.time;
            return Some((ev.time, ev.payload));
        }
        None
    }

    /// Peek at the timestamp of the next (non-cancelled) event.
    pub fn peek_time(&mut self) -> Option<SimTime> {
        while let Some(ev) = self.queue.peek() {
            if self.cancelled.contains(&ev.id) {
                let ev = self.queue.pop().unwrap();
                self.cancelled.remove(&ev.id);
                continue;
            }
            return Some(ev.time);
        }
        None
    }

    /// Number of pending (possibly cancelled) events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&mut self) -> bool {
        self.peek_time().is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Simulator::new();
        sim.schedule_in(5.0, "c");
        sim.schedule_in(1.0, "a");
        sim.schedule_in(3.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| sim.next_event()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(sim.now().secs(), 5.0);
    }

    #[test]
    fn ties_break_fifo() {
        let mut sim = Simulator::new();
        sim.schedule_at(SimTime::from_secs(2.0), 1);
        sim.schedule_at(SimTime::from_secs(2.0), 2);
        sim.schedule_at(SimTime::from_secs(2.0), 3);
        let order: Vec<_> = std::iter::from_fn(|| sim.next_event()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn cancel_suppresses_event() {
        let mut sim = Simulator::new();
        let a = sim.schedule_in(1.0, "a");
        sim.schedule_in(2.0, "b");
        sim.cancel(a);
        assert_eq!(sim.next_event().map(|(_, e)| e), Some("b"));
        assert!(sim.next_event().is_none());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut sim = Simulator::new();
        sim.schedule_in(10.0, ());
        sim.schedule_in(20.0, ());
        let t1 = sim.next_event().unwrap().0;
        // Scheduling relative to the advanced clock.
        sim.schedule_in(1.0, ());
        let t2 = sim.next_event().unwrap().0;
        assert_eq!(t1.secs(), 10.0);
        assert_eq!(t2.secs(), 11.0);
    }

    #[test]
    #[should_panic]
    fn scheduling_into_past_panics() {
        let mut sim = Simulator::new();
        sim.schedule_in(5.0, ());
        sim.next_event();
        sim.schedule_at(SimTime::from_secs(1.0), ());
    }

    #[test]
    fn peek_skips_cancelled() {
        let mut sim = Simulator::new();
        let a = sim.schedule_in(1.0, "a");
        sim.schedule_in(2.0, "b");
        sim.cancel(a);
        assert_eq!(sim.peek_time().unwrap().secs(), 2.0);
    }
}
