//! Simulation time: a totally-ordered wrapper over `f64` seconds.
//!
//! Virtual time is measured in seconds since experiment start. We keep it as
//! `f64` (sub-second billing granularity matters: providers bill per second)
//! but wrap it so it can live inside `BinaryHeap` keys.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in virtual time, in seconds since the simulation epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimTime(pub f64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0.0);

    pub fn secs(self) -> f64 {
        self.0
    }

    pub fn from_secs(s: f64) -> Self {
        debug_assert!(s.is_finite(), "non-finite SimTime");
        SimTime(s)
    }

    pub fn from_hms(h: u64, m: u64, s: u64) -> Self {
        SimTime((h * 3600 + m * 60 + s) as f64)
    }

    /// Render as `H:MM:SS` the way the paper's tables report execution times.
    pub fn hms(self) -> String {
        let total = self.0.round().max(0.0) as u64;
        format!("{}:{:02}:{:02}", total / 3600, (total / 60) % 60, total % 60)
    }

    pub fn max(self, other: SimTime) -> SimTime {
        if self.0 >= other.0 {
            self
        } else {
            other
        }
    }
}

impl Eq for SimTime {}

impl PartialOrd for SimTime {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SimTime {
    fn cmp(&self, other: &Self) -> Ordering {
        // Times are always finite (enforced at construction).
        self.0.partial_cmp(&other.0).expect("non-finite SimTime")
    }
}

impl Add<f64> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: f64) -> SimTime {
        SimTime(self.0 + rhs)
    }
}

impl AddAssign<f64> for SimTime {
    fn add_assign(&mut self, rhs: f64) {
        self.0 += rhs;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = f64;
    fn sub(self, rhs: SimTime) -> f64 {
        self.0 - rhs.0
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.hms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hms_formatting() {
        assert_eq!(SimTime::from_secs(0.0).hms(), "0:00:00");
        assert_eq!(SimTime::from_hms(3, 4, 37).hms(), "3:04:37");
        assert_eq!(SimTime::from_secs(11077.0).hms(), "3:04:37");
        assert_eq!(SimTime::from_hms(10, 1, 46).hms(), "10:01:46");
    }

    #[test]
    fn ordering_and_arithmetic() {
        let a = SimTime::from_secs(1.0);
        let b = a + 2.5;
        assert!(b > a);
        assert_eq!(b - a, 2.5);
        assert_eq!(a.max(b), b);
    }
}
