//! Deterministic pseudo-random number generation for the simulator.
//!
//! All stochastic elements of Multi-FedLS experiments (spot revocations,
//! synthetic data, randomized baselines) draw from seeded streams so every
//! table in EXPERIMENTS.md regenerates bit-identically. We implement
//! SplitMix64 (for seeding / stream splitting) and xoshiro256** (the work
//! generator) from the public-domain reference algorithms rather than pulling
//! in an external RNG crate.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state and to
/// derive independent child streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream; deterministic in (self state, tag).
    pub fn split(&mut self, tag: u64) -> Rng {
        let mix = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::seeded(mix)
    }

    /// Derive a child seed *without* advancing this generator: a pure
    /// function of (current state, tag). The sweep engine uses this to give
    /// every trial in a campaign its own stream — because the parent is
    /// never mutated, expansion order, worker count, and completion order
    /// cannot change any derived seed.
    pub fn split_seed(&self, tag: u64) -> u64 {
        let mut sm = SplitMix64::new(
            self.s[0]
                ^ self.s[1].rotate_left(16)
                ^ self.s[2].rotate_left(32)
                ^ self.s[3].rotate_left(48)
                ^ tag.wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        // Two rounds so that consecutive tags map to well-separated seeds.
        sm.next_u64();
        sm.next_u64()
    }

    /// Like [`Rng::split`], but pure: see [`Rng::split_seed`].
    pub fn split_at(&self, tag: u64) -> Rng {
        Rng::seeded(self.split_seed(tag))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1). 53-bit mantissa construction.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1), guaranteed nonzero (for log transforms).
    pub fn next_f64_open(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // Lemire's rejection method to avoid modulo bias.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let low = m as u64;
            if low >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone check.
            let t = n.wrapping_neg() % n;
            if low >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponentially-distributed sample with the given rate λ (mean 1/λ).
    ///
    /// This is the inter-arrival time of the Poisson revocation process used
    /// by the paper's failure simulation (§5.6): a revocation rate λ = 1/k_r
    /// where k_r is the mean time between failures in seconds.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0, "exponential rate must be positive");
        -self.next_f64_open().ln() / rate
    }

    /// Poisson-distributed count with the given mean (Knuth's method for
    /// small means; used in tests/validation, not the hot path).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        assert!(mean >= 0.0);
        if mean == 0.0 {
            return 0;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64_open();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Standard normal via Box-Muller (used for synthetic data generation).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::seeded(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn split_seed_is_pure_and_tag_sensitive() {
        let root = Rng::seeded(7);
        assert_eq!(root.split_seed(3), root.split_seed(3), "no state advance");
        assert_ne!(root.split_seed(3), root.split_seed(4));
        // Pure split streams are independent across tags.
        let mut c1 = root.split_at(1);
        let mut c2 = root.split_at(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
        // And differ from the parent's own output stream.
        let mut parent = Rng::seeded(7);
        let mut child = root.split_at(0);
        let same = (0..64).filter(|_| parent.next_u64() == child.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_unbiased_range() {
        let mut r = Rng::seeded(4);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            // Expect 10_000 each; loose 10% tolerance.
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::seeded(5);
        let rate = 1.0 / 7200.0; // paper's k_r = 2h revocation rate
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 7200.0).abs() < 7200.0 * 0.03, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::seeded(6);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(8);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
