//! Synthetic federated datasets standing in for the paper's silos (§5.1).
//!
//! The paper's scheduling results depend only on per-round compute and
//! communication *times*, not on pixel/text content, so the real-compute
//! examples use synthetic datasets with planted learnable structure:
//!
//! * [`femnist_like`] — 28×28 "handwritten character" images, 62 classes,
//!   one writer-style per client (non-IID: per-client prototype jitter);
//! * [`shakespeare_like`] — next-character prediction over Markov text, one
//!   "role" (transition matrix temperature) per client;
//! * [`til_like`] — 32×32 RGB "tissue patches", binary
//!   lymphocyte-present/absent with planted blob structure (scaled down
//!   from the paper's 100K×100K WSIs to CPU size).
//!
//! Every generator is deterministic in (seed, client id) and returns the
//! [`crate::runtime::trainer::Shard`] layout the PJRT trainers consume.

use crate::runtime::trainer::Shard;
use crate::simul::Rng;

/// FEMNIST-like: `n_classes` prototypes in pixel space; a sample is its
/// class prototype + writer-specific offset + noise.
pub fn femnist_like(
    seed: u64,
    client: usize,
    n_train: usize,
    n_test: usize,
    n_classes: usize,
) -> Shard {
    let d = 28 * 28;
    let mut proto_rng = Rng::seeded(seed); // shared across clients
    let prototypes: Vec<Vec<f32>> = (0..n_classes)
        .map(|_| (0..d).map(|_| proto_rng.normal() as f32).collect())
        .collect();
    let mut rng = Rng::seeded(seed ^ 0x5EED).split(client as u64 + 1);
    // Writer style: a per-client bias pattern (non-IID shift).
    let style: Vec<f32> = (0..d).map(|_| 0.3 * rng.normal() as f32).collect();
    let gen = |n: usize, rng: &mut Rng| {
        let mut xs = Vec::with_capacity(n * d);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.next_below(n_classes as u64) as usize;
            for j in 0..d {
                xs.push(prototypes[label][j] + style[j] + 0.5 * rng.normal() as f32);
            }
            ys.push(label as f32);
        }
        (xs, ys)
    };
    let (x_train, y_train) = gen(n_train, &mut rng);
    let (x_test, y_test) = gen(n_test, &mut rng);
    Shard { x_train, y_train, x_test, y_test, feature_dim: d }
}

/// Shakespeare-like: order-1 Markov chains over a small alphabet; the task
/// is next-character prediction from a context window.
pub fn shakespeare_like(
    seed: u64,
    client: usize,
    n_train: usize,
    n_test: usize,
    vocab: usize,
    context: usize,
) -> Shard {
    let mut rng = Rng::seeded(seed ^ 0x5BAE).split(client as u64 + 1);
    // Per-client transition matrix ("each character of each play is a
    // different client"): sparse-ish rows with client-specific structure.
    let mut trans: Vec<Vec<f64>> = Vec::with_capacity(vocab);
    for _ in 0..vocab {
        let mut row: Vec<f64> = (0..vocab).map(|_| rng.next_f64_open().powf(3.0)).collect();
        let s: f64 = row.iter().sum();
        for v in row.iter_mut() {
            *v /= s;
        }
        trans.push(row);
    }
    let sample_next = |state: usize, rng: &mut Rng| -> usize {
        let mut u = rng.next_f64();
        for (i, &p) in trans[state].iter().enumerate() {
            if u < p {
                return i;
            }
            u -= p;
        }
        vocab - 1
    };
    // One long stream, sliced into (context → next) samples.
    let total = n_train + n_test;
    let mut stream = Vec::with_capacity(total + context + 1);
    let mut s = rng.next_below(vocab as u64) as usize;
    for _ in 0..total + context + 1 {
        stream.push(s);
        s = sample_next(s, &mut rng);
    }
    let mut xs = Vec::with_capacity(total * context);
    let mut ys = Vec::with_capacity(total);
    for i in 0..total {
        for j in 0..context {
            // Normalized char ids; the model embeds them.
            xs.push(stream[i + j] as f32 / vocab as f32);
        }
        ys.push(stream[i + context] as f32);
    }
    let split = n_train * context;
    Shard {
        x_train: xs[..split].to_vec(),
        y_train: ys[..n_train].to_vec(),
        x_test: xs[split..].to_vec(),
        y_test: ys[n_train..].to_vec(),
        feature_dim: context,
    }
}

/// TIL-like: 32×32 RGB patches; positives contain a dark circular "cell
/// cluster" blob, negatives are smooth tissue texture.
pub fn til_like(seed: u64, client: usize, n_train: usize, n_test: usize) -> Shard {
    let (h, w) = (32usize, 32usize);
    let d = h * w * 3;
    let mut rng = Rng::seeded(seed ^ 0x71f).split(client as u64 + 1);
    let gen = |n: usize, rng: &mut Rng| {
        let mut xs = Vec::with_capacity(n * d);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.next_below(2) as usize;
            // Base tissue texture (pinkish, smooth).
            let base: f32 = 0.7 + 0.04 * rng.normal() as f32;
            let (cx, cy, r) = (
                rng.uniform(10.0, 22.0),
                rng.uniform(10.0, 22.0),
                rng.uniform(5.0, 8.0),
            );
            for y in 0..h {
                for x in 0..w {
                    let noise = 0.05 * rng.normal() as f32;
                    let mut px = [base + noise, base * 0.6 + noise, base * 0.7 + noise];
                    if label == 1 {
                        let dist = ((x as f64 - cx).powi(2) + (y as f64 - cy).powi(2)).sqrt();
                        if dist < r {
                            // Lymphocyte cluster: dark blue-purple blob.
                            px = [0.25 + noise, 0.2 + noise, 0.5 + noise];
                        }
                    }
                    xs.extend_from_slice(&px);
                }
            }
            ys.push(label as f32);
        }
        (xs, ys)
    };
    let (x_train, y_train) = gen(n_train, &mut rng);
    let (x_test, y_test) = gen(n_test, &mut rng);
    Shard { x_train, y_train, x_test, y_test, feature_dim: d }
}

/// Build the per-client shards for a named application (sample counts can be
/// scaled down for fast examples).
pub fn shards_for_app(app: &crate::apps::AppSpec, seed: u64, scale: f64) -> Vec<Shard> {
    // Keep at least two batches of the largest model batch size (32) so the
    // AOT fixed-shape train/eval steps always have a full batch.
    let scaled = |n: u32| ((n as f64 * scale).round() as usize).max(64);
    (0..app.n_clients())
        .map(|i| {
            let n_train = scaled(app.train_samples[i]);
            let n_test = scaled(app.test_samples[i]);
            match app.name {
                "femnist" => femnist_like(seed, i, n_train, n_test, 62),
                "shakespeare" => shakespeare_like(seed, i, n_train, n_test, 64, 32),
                _ => til_like(seed, i, n_train, n_test),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn femnist_shapes_and_determinism() {
        let a = femnist_like(1, 0, 20, 5, 62);
        assert_eq!(a.x_train.len(), 20 * 784);
        assert_eq!(a.y_train.len(), 20);
        assert_eq!(a.x_test.len(), 5 * 784);
        assert_eq!(a.feature_dim, 784);
        let b = femnist_like(1, 0, 20, 5, 62);
        assert_eq!(a.x_train, b.x_train);
        // Different clients differ (writer styles).
        let c = femnist_like(1, 1, 20, 5, 62);
        assert_ne!(a.x_train, c.x_train);
    }

    #[test]
    fn femnist_labels_in_range() {
        let s = femnist_like(2, 0, 200, 10, 62);
        for &y in &s.y_train {
            assert!((0.0..62.0).contains(&y) && y.fract() == 0.0);
        }
    }

    #[test]
    fn femnist_classes_are_separable() {
        // Same-class samples must be closer than cross-class on average —
        // the planted structure a CNN can learn.
        let s = femnist_like(3, 0, 100, 0, 5);
        let d = 784;
        let idx = |c: usize| {
            s.y_train
                .iter()
                .enumerate()
                .filter(move |&(_, &y)| y as usize == c)
                .map(|(i, _)| i)
                .collect::<Vec<_>>()
        };
        let dist = |i: usize, j: usize| -> f32 {
            (0..d)
                .map(|k| (s.x_train[i * d + k] - s.x_train[j * d + k]).powi(2))
                .sum::<f32>()
        };
        let c0 = idx(0);
        let c1 = idx(1);
        if c0.len() >= 2 && !c1.is_empty() {
            let same = dist(c0[0], c0[1]);
            let cross = dist(c0[0], c1[0]);
            assert!(same < cross, "same={same} cross={cross}");
        }
    }

    #[test]
    fn shakespeare_next_char_is_predictable() {
        // With peaked transition rows, the most frequent successor of a char
        // in train also dominates in test (the structure an LSTM learns).
        let s = shakespeare_like(4, 0, 400, 100, 16, 8);
        assert_eq!(s.feature_dim, 8);
        assert_eq!(s.x_train.len(), 400 * 8);
        for &y in &s.y_train {
            assert!((0.0..16.0).contains(&y));
        }
        // Deterministic per (seed, client).
        let t = shakespeare_like(4, 0, 400, 100, 16, 8);
        assert_eq!(s.x_train, t.x_train);
    }

    #[test]
    fn til_blob_statistics_differ_by_class() {
        let s = til_like(5, 0, 60, 0);
        let d = s.feature_dim;
        // Positives (label 1) have lower mean intensity (dark blob).
        let mut pos = (0.0f64, 0u32);
        let mut neg = (0.0f64, 0u32);
        for (i, &y) in s.y_train.iter().enumerate() {
            let mean: f32 = s.x_train[i * d..(i + 1) * d].iter().sum::<f32>() / d as f32;
            if y > 0.5 {
                pos = (pos.0 + mean as f64, pos.1 + 1);
            } else {
                neg = (neg.0 + mean as f64, neg.1 + 1);
            }
        }
        assert!(pos.1 > 5 && neg.1 > 5, "both classes present");
        assert!(pos.0 / pos.1 as f64 <= neg.0 / neg.1 as f64 + 1e-12);
    }

    #[test]
    fn shards_for_app_respects_counts_and_scale() {
        let app = crate::apps::femnist();
        let shards = shards_for_app(&app, 9, 0.1);
        assert_eq!(shards.len(), 5);
        assert_eq!(shards[0].n_train(), 80);
        assert_eq!(shards[4].n_train(), 105);
    }
}
