//! A dense two-phase primal simplex LP solver.
//!
//! Built from scratch (no solver crates offline) for the Initial Mapping
//! MILP (§4.2). Problems are small — tens to a few hundred variables — so a
//! dense tableau with Bland's anti-cycling rule is simple and robust.
//!
//! Form: minimize `c·x` subject to `A x {≤,≥,=} b`, `x ≥ 0`.

/// Constraint relation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rel {
    Le,
    Ge,
    Eq,
}

/// A sparse constraint row.
#[derive(Debug, Clone)]
pub struct Constraint {
    /// (variable index, coefficient) pairs; indices may repeat (summed).
    pub coeffs: Vec<(usize, f64)>,
    pub rel: Rel,
    pub rhs: f64,
}

/// A linear program: minimize `objective · x` s.t. constraints, `x ≥ 0`.
#[derive(Debug, Clone)]
pub struct Lp {
    pub num_vars: usize,
    pub objective: Vec<f64>,
    pub constraints: Vec<Constraint>,
}

impl Lp {
    pub fn new(num_vars: usize) -> Self {
        Lp { num_vars, objective: vec![0.0; num_vars], constraints: Vec::new() }
    }

    pub fn set_objective(&mut self, var: usize, coeff: f64) {
        self.objective[var] = coeff;
    }

    pub fn add(&mut self, coeffs: Vec<(usize, f64)>, rel: Rel, rhs: f64) {
        debug_assert!(coeffs.iter().all(|&(i, _)| i < self.num_vars));
        self.constraints.push(Constraint { coeffs, rel, rhs });
    }

    /// Convenience: `x_i ≤ ub`.
    pub fn add_upper_bound(&mut self, var: usize, ub: f64) {
        self.add(vec![(var, 1.0)], Rel::Le, ub);
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Solution {
    Optimal { x: Vec<f64>, objective: f64 },
    Infeasible,
    Unbounded,
}

const EPS: f64 = 1e-9;

/// Dense simplex tableau.
struct Tableau {
    /// rows × cols, last column is the RHS.
    a: Vec<Vec<f64>>,
    /// Cost row (length cols), last entry is -objective value.
    cost: Vec<f64>,
    /// Basic variable of each row.
    basis: Vec<usize>,
    rows: usize,
    cols: usize, // including rhs
}

impl Tableau {
    /// One simplex phase: pivot until optimal or unbounded.
    /// Returns false on unboundedness.
    fn run(&mut self) -> bool {
        loop {
            // Bland's rule: entering variable = lowest index with negative
            // reduced cost.
            let mut entering = None;
            for j in 0..self.cols - 1 {
                if self.cost[j] < -EPS {
                    entering = Some(j);
                    break;
                }
            }
            let Some(j) = entering else { return true }; // optimal
            // Ratio test (ties: lowest basis index — Bland).
            let mut leaving: Option<(usize, f64)> = None;
            for i in 0..self.rows {
                let aij = self.a[i][j];
                if aij > EPS {
                    let ratio = self.a[i][self.cols - 1] / aij;
                    match leaving {
                        None => leaving = Some((i, ratio)),
                        Some((bi, br)) => {
                            if ratio < br - EPS
                                || (ratio < br + EPS && self.basis[i] < self.basis[bi])
                            {
                                leaving = Some((i, ratio));
                            }
                        }
                    }
                }
            }
            let Some((i, _)) = leaving else { return false }; // unbounded
            self.pivot(i, j);
        }
    }

    fn pivot(&mut self, row: usize, col: usize) {
        let p = self.a[row][col];
        debug_assert!(p.abs() > EPS);
        for v in self.a[row].iter_mut() {
            *v /= p;
        }
        for i in 0..self.rows {
            if i != row {
                let f = self.a[i][col];
                if f.abs() > EPS {
                    for jj in 0..self.cols {
                        self.a[i][jj] -= f * self.a[row][jj];
                    }
                }
            }
        }
        let f = self.cost[col];
        if f.abs() > EPS {
            for jj in 0..self.cols {
                self.cost[jj] -= f * self.a[row][jj];
            }
        }
        self.basis[row] = col;
    }

    /// Re-derive the cost row for objective `c` (pricing out basics).
    fn set_objective(&mut self, c: &[f64]) {
        self.cost = vec![0.0; self.cols];
        self.cost[..c.len()].copy_from_slice(c);
        for i in 0..self.rows {
            let b = self.basis[i];
            let f = self.cost[b];
            if f.abs() > EPS {
                for jj in 0..self.cols {
                    self.cost[jj] -= f * self.a[i][jj];
                }
            }
        }
    }
}

/// Solve the LP with the two-phase simplex method.
pub fn solve(lp: &Lp) -> Solution {
    let m = lp.constraints.len();
    let n = lp.num_vars;

    // Column layout: [structural | slack/surplus | artificial | rhs].
    let mut n_slack = 0usize;
    let mut n_art = 0usize;
    for c in &lp.constraints {
        // Normalize rhs ≥ 0 first to decide what the row needs.
        let rhs_neg = c.rhs < 0.0;
        let rel = effective_rel(c.rel, rhs_neg);
        match rel {
            Rel::Le => n_slack += 1,
            Rel::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Rel::Eq => n_art += 1,
        }
    }
    let cols = n + n_slack + n_art + 1;
    let mut a = vec![vec![0.0; cols]; m];
    let mut basis = vec![0usize; m];
    let mut next_slack = n;
    let mut next_art = n + n_slack;
    let mut art_cols = Vec::new();

    for (i, c) in lp.constraints.iter().enumerate() {
        let sign = if c.rhs < 0.0 { -1.0 } else { 1.0 };
        for &(j, v) in &c.coeffs {
            a[i][j] += sign * v;
        }
        a[i][cols - 1] = sign * c.rhs;
        let rel = effective_rel(c.rel, c.rhs < 0.0);
        match rel {
            Rel::Le => {
                a[i][next_slack] = 1.0;
                basis[i] = next_slack;
                next_slack += 1;
            }
            Rel::Ge => {
                a[i][next_slack] = -1.0;
                next_slack += 1;
                a[i][next_art] = 1.0;
                basis[i] = next_art;
                art_cols.push(next_art);
                next_art += 1;
            }
            Rel::Eq => {
                a[i][next_art] = 1.0;
                basis[i] = next_art;
                art_cols.push(next_art);
                next_art += 1;
            }
        }
    }

    let mut t = Tableau { a, cost: vec![0.0; cols], basis, rows: m, cols };

    // Phase 1: minimize the sum of artificials.
    if !art_cols.is_empty() {
        let mut phase1 = vec![0.0; cols - 1];
        for &j in &art_cols {
            phase1[j] = 1.0;
        }
        t.set_objective(&phase1);
        if !t.run() {
            // Phase-1 objective is bounded below by 0; unbounded here means
            // numerical trouble — treat as infeasible.
            return Solution::Infeasible;
        }
        let p1_obj = -t.cost[cols - 1];
        if p1_obj > 1e-6 {
            return Solution::Infeasible;
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for i in 0..t.rows {
            if art_cols.contains(&t.basis[i]) {
                let mut pivoted = false;
                for j in 0..n + n_slack {
                    if t.a[i][j].abs() > EPS {
                        t.pivot(i, j);
                        pivoted = true;
                        break;
                    }
                }
                // A redundant all-zero row stays basic on its artificial at
                // value 0; harmless for phase 2 as long as the artificial
                // columns are costed at +∞-like 0 and never re-enter. We
                // zero the row's artificial coefficient usage by leaving it.
                let _ = pivoted;
            }
        }
    }

    // Phase 2: original objective (artificial columns excluded from entry by
    // giving them +large cost — simpler: forbid them by setting cost high).
    let mut phase2 = vec![0.0; cols - 1];
    phase2[..n].copy_from_slice(&lp.objective);
    for &j in &art_cols {
        phase2[j] = 1e18; // never profitable to re-enter
    }
    t.set_objective(&phase2);
    if !t.run() {
        return Solution::Unbounded;
    }

    let mut x = vec![0.0; n];
    for i in 0..t.rows {
        if t.basis[i] < n {
            x[t.basis[i]] = t.a[i][cols - 1];
        }
    }
    let objective = lp
        .objective
        .iter()
        .zip(&x)
        .map(|(c, v)| c * v)
        .sum();
    Solution::Optimal { x, objective }
}

fn effective_rel(rel: Rel, rhs_negated: bool) -> Rel {
    if !rhs_negated {
        rel
    } else {
        match rel {
            Rel::Le => Rel::Ge,
            Rel::Ge => Rel::Le,
            Rel::Eq => Rel::Eq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(sol: &Solution, want_obj: f64, tol: f64) -> Vec<f64> {
        match sol {
            Solution::Optimal { x, objective } => {
                assert!(
                    (objective - want_obj).abs() < tol,
                    "objective {objective} != {want_obj}"
                );
                x.clone()
            }
            other => panic!("expected optimal, got {other:?}"),
        }
    }

    #[test]
    fn maximize_profit_classic() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18 → (2, 6), obj 36.
        let mut lp = Lp::new(2);
        lp.set_objective(0, -3.0);
        lp.set_objective(1, -5.0);
        lp.add(vec![(0, 1.0)], Rel::Le, 4.0);
        lp.add(vec![(1, 2.0)], Rel::Le, 12.0);
        lp.add(vec![(0, 3.0), (1, 2.0)], Rel::Le, 18.0);
        let x = assert_opt(&solve(&lp), -36.0, 1e-6);
        assert!((x[0] - 2.0).abs() < 1e-6 && (x[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_ge_constraints() {
        // min x + 2y s.t. x + y = 10, x ≥ 3 → (10? no y≥0) x=10,y=0 obj 10
        // but x ≥ 3 already satisfied; optimum x=10.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 2.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Rel::Eq, 10.0);
        lp.add(vec![(0, 1.0)], Rel::Ge, 3.0);
        let x = assert_opt(&solve(&lp), 10.0, 1e-6);
        assert!((x[0] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ 1 and x ≥ 2.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0)], Rel::Le, 1.0);
        lp.add(vec![(0, 1.0)], Rel::Ge, 2.0);
        assert_eq!(solve(&lp), Solution::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        // min -x, x ≥ 0 unconstrained above.
        let mut lp = Lp::new(1);
        lp.set_objective(0, -1.0);
        lp.add(vec![(0, 1.0)], Rel::Ge, 0.0);
        assert_eq!(solve(&lp), Solution::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // min x s.t. -x ≤ -5  (i.e. x ≥ 5).
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, -1.0)], Rel::Le, -5.0);
        let x = assert_opt(&solve(&lp), 5.0, 1e-6);
        assert!((x[0] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Klee-Minty-ish degenerate corner; must terminate via Bland's rule.
        let mut lp = Lp::new(3);
        lp.set_objective(0, -10.0);
        lp.set_objective(1, -12.0);
        lp.set_objective(2, -12.0);
        lp.add(vec![(0, 1.0), (1, 2.0), (2, 2.0)], Rel::Le, 20.0);
        lp.add(vec![(0, 2.0), (1, 1.0), (2, 2.0)], Rel::Le, 20.0);
        lp.add(vec![(0, 2.0), (1, 2.0), (2, 1.0)], Rel::Le, 20.0);
        assert_opt(&solve(&lp), -136.0, 1e-6);
    }

    #[test]
    fn transportation_problem() {
        // 2 supplies (10, 20), 2 demands (15, 15), costs [[1,2],[3,1]].
        // x00+x01 ≤ 10, x10+x11 ≤ 20, x00+x10 = 15, x01+x11 = 15.
        // Optimal: x00=10, x10=5, x11=15 → 10 + 15 + 15 = 40.
        let mut lp = Lp::new(4);
        for (i, c) in [1.0, 2.0, 3.0, 1.0].iter().enumerate() {
            lp.set_objective(i, *c);
        }
        lp.add(vec![(0, 1.0), (1, 1.0)], Rel::Le, 10.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Rel::Le, 20.0);
        lp.add(vec![(0, 1.0), (2, 1.0)], Rel::Eq, 15.0);
        lp.add(vec![(1, 1.0), (3, 1.0)], Rel::Eq, 15.0);
        assert_opt(&solve(&lp), 40.0, 1e-6);
    }

    #[test]
    fn duplicate_coeffs_are_summed() {
        // min x s.t. x + x ≥ 8 → x = 4.
        let mut lp = Lp::new(1);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0), (0, 1.0)], Rel::Ge, 8.0);
        let x = assert_opt(&solve(&lp), 4.0, 1e-6);
        assert!((x[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn random_lps_match_brute_force_vertices() {
        // Property: for random bounded 2-var LPs with ≤ constraints, simplex
        // equals the best feasible vertex found by enumerating constraint
        // intersections.
        crate::util::testkit::forall(
            "simplex vs vertex enumeration",
            0xC0FFEE,
            60,
            |rng| {
                let mut lp = Lp::new(2);
                lp.set_objective(0, rng.uniform(-5.0, 5.0));
                lp.set_objective(1, rng.uniform(-5.0, 5.0));
                // Box + a few random cuts keeps it bounded and feasible at 0.
                lp.add(vec![(0, 1.0)], Rel::Le, rng.uniform(1.0, 10.0));
                lp.add(vec![(1, 1.0)], Rel::Le, rng.uniform(1.0, 10.0));
                for _ in 0..3 {
                    lp.add(
                        vec![(0, rng.uniform(0.1, 2.0)), (1, rng.uniform(0.1, 2.0))],
                        Rel::Le,
                        rng.uniform(2.0, 15.0),
                    );
                }
                lp
            },
            |lp| {
                let sol = solve(lp);
                let Solution::Optimal { objective, .. } = sol else {
                    return Err(format!("expected optimal, got {sol:?}"));
                };
                // Enumerate vertices: intersections of constraint boundaries
                // (plus axes), keep feasible, take best.
                let mut lines: Vec<(f64, f64, f64)> = vec![(1.0, 0.0, 0.0), (0.0, 1.0, 0.0)];
                for c in &lp.constraints {
                    let mut a = 0.0;
                    let mut b = 0.0;
                    for &(j, v) in &c.coeffs {
                        if j == 0 {
                            a += v;
                        } else {
                            b += v;
                        }
                    }
                    lines.push((a, b, c.rhs));
                }
                let feasible = |x: f64, y: f64| -> bool {
                    if x < -1e-7 || y < -1e-7 {
                        return false;
                    }
                    lp.constraints.iter().all(|c| {
                        let mut lhs = 0.0;
                        for &(j, v) in &c.coeffs {
                            lhs += v * if j == 0 { x } else { y };
                        }
                        lhs <= c.rhs + 1e-7
                    })
                };
                let mut best = f64::INFINITY;
                for i in 0..lines.len() {
                    for k in i + 1..lines.len() {
                        let (a1, b1, c1) = lines[i];
                        let (a2, b2, c2) = lines[k];
                        let det = a1 * b2 - a2 * b1;
                        if det.abs() < 1e-9 {
                            continue;
                        }
                        let x = (c1 * b2 - c2 * b1) / det;
                        let y = (a1 * c2 - a2 * c1) / det;
                        if feasible(x, y) {
                            best = best.min(lp.objective[0] * x + lp.objective[1] * y);
                        }
                    }
                }
                if (objective - best).abs() < 1e-5 {
                    Ok(())
                } else {
                    Err(format!("simplex {objective} vs enumeration {best}"))
                }
            },
        );
    }
}
