//! Optimization substrate: a dense two-phase simplex LP solver ([`lp`]) and
//! a 0/1 branch-and-bound MILP solver over it ([`bb`]). Built from scratch;
//! used by the Initial Mapping module (§4.2).

pub mod bb;
pub mod lp;

pub use bb::{solve as solve_milp, Milp, MilpSolution};
pub use lp::{solve as solve_lp, Constraint, Lp, Rel, Solution};
