//! 0/1 branch-and-bound MILP solver over the simplex LP relaxation.
//!
//! Used by the generic route of the Initial Mapping formulation (the
//! specialized enumerative solver in [`crate::mapping::exact`] is the
//! production path; this one cross-checks it and exists as a reusable
//! substrate).

use super::lp::{Lp, Rel, Solution};

/// A mixed 0/1 integer program: the LP plus a set of variables restricted to
/// {0, 1}. Callers should also add `x ≤ 1` rows for binaries (done by
/// [`Milp::new`]).
#[derive(Debug, Clone)]
pub struct Milp {
    pub lp: Lp,
    pub binaries: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct MilpSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Search statistics (nodes explored) for benchmarking.
    pub nodes: usize,
}

impl Milp {
    pub fn new(mut lp: Lp, binaries: Vec<usize>) -> Self {
        for &b in &binaries {
            lp.add_upper_bound(b, 1.0);
        }
        Milp { lp, binaries }
    }
}

const INT_TOL: f64 = 1e-6;

/// Solve by DFS branch-and-bound, branching on the most fractional binary,
/// exploring the nearer-integer branch first. Returns None when infeasible.
pub fn solve(milp: &Milp) -> Option<MilpSolution> {
    let mut best: Option<(Vec<f64>, f64)> = None;
    let mut nodes = 0usize;
    // Stack of (fixed assignments) — each entry is (var, value) list delta.
    let mut stack: Vec<Vec<(usize, f64)>> = vec![Vec::new()];

    while let Some(fixed) = stack.pop() {
        nodes += 1;
        // Build the node LP: base + equality fixings.
        let mut lp = milp.lp.clone();
        for &(v, val) in &fixed {
            lp.add(vec![(v, 1.0)], Rel::Eq, val);
        }
        let sol = super::lp::solve(&lp);
        let Solution::Optimal { x, objective } = sol else {
            continue; // infeasible / unbounded node
        };
        // Bound: prune if not better than incumbent.
        if let Some((_, inc)) = &best {
            if objective >= *inc - 1e-9 {
                continue;
            }
        }
        // Find most fractional binary.
        let mut branch_var = None;
        let mut worst_frac = INT_TOL;
        for &b in &milp.binaries {
            let f = (x[b] - x[b].round()).abs();
            if f > worst_frac {
                worst_frac = f;
                branch_var = Some(b);
            }
        }
        match branch_var {
            None => {
                // Integral → new incumbent.
                best = Some((x, objective));
            }
            Some(b) => {
                let frac = milp.lp.objective.len(); // silence unused in release
                let _ = frac;
                let near = x[b].round().clamp(0.0, 1.0);
                let far = 1.0 - near;
                // Push far first so near is explored first (LIFO).
                let mut fixed_far = fixed.clone();
                fixed_far.push((b, far));
                stack.push(fixed_far);
                let mut fixed_near = fixed;
                fixed_near.push((b, near));
                stack.push(fixed_near);
            }
        }
    }
    best.map(|(x, objective)| MilpSolution { x, objective, nodes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simul::Rng;

    #[test]
    fn knapsack_small() {
        // max 10a + 13b + 7c s.t. 3a + 4b + 2c ≤ 6 → b + c = 20.
        let mut lp = Lp::new(3);
        lp.set_objective(0, -10.0);
        lp.set_objective(1, -13.0);
        lp.set_objective(2, -7.0);
        lp.add(vec![(0, 3.0), (1, 4.0), (2, 2.0)], Rel::Le, 6.0);
        let milp = Milp::new(lp, vec![0, 1, 2]);
        let sol = solve(&milp).unwrap();
        assert!((sol.objective + 20.0).abs() < 1e-6, "obj={}", sol.objective);
        assert!(sol.x[1] > 0.5 && sol.x[2] > 0.5 && sol.x[0] < 0.5);
    }

    #[test]
    fn infeasible_binary_program() {
        // a + b = 3 with binaries is impossible.
        let mut lp = Lp::new(2);
        lp.set_objective(0, 1.0);
        lp.add(vec![(0, 1.0), (1, 1.0)], Rel::Eq, 3.0);
        let milp = Milp::new(lp, vec![0, 1]);
        assert!(solve(&milp).is_none());
    }

    #[test]
    fn assignment_each_task_one_machine() {
        // 2 tasks × 2 machines, cost [[1, 10], [10, 1]]; each task exactly
        // one machine → diagonal, cost 2.
        let mut lp = Lp::new(4); // x(t,m) = t*2+m
        for (i, c) in [1.0, 10.0, 10.0, 1.0].iter().enumerate() {
            lp.set_objective(i, *c);
        }
        lp.add(vec![(0, 1.0), (1, 1.0)], Rel::Eq, 1.0);
        lp.add(vec![(2, 1.0), (3, 1.0)], Rel::Eq, 1.0);
        let milp = Milp::new(lp, vec![0, 1, 2, 3]);
        let sol = solve(&milp).unwrap();
        assert!((sol.objective - 2.0).abs() < 1e-6);
    }

    #[test]
    fn mixed_continuous_and_binary() {
        // min y + 0.1t : t ≥ 5y, t ≥ 3(1-y), t continuous.
        // y=0 → t=3 cost 0.3; y=1 → t=5 cost 1.5. Expect y=0.
        let mut lp = Lp::new(2); // y=0, t=1
        lp.set_objective(0, 1.0);
        lp.set_objective(1, 0.1);
        lp.add(vec![(1, 1.0), (0, -5.0)], Rel::Ge, 0.0);
        lp.add(vec![(1, 1.0), (0, 3.0)], Rel::Ge, 3.0);
        let milp = Milp::new(lp, vec![0]);
        let sol = solve(&milp).unwrap();
        assert!(sol.x[0] < 0.5);
        assert!((sol.objective - 0.3).abs() < 1e-6);
    }

    /// Brute-force 0/1 reference.
    fn brute_force(milp: &Milp) -> Option<f64> {
        let nb = milp.binaries.len();
        let mut best: Option<f64> = None;
        for mask in 0u32..(1 << nb) {
            let mut lp = milp.lp.clone();
            for (bit, &v) in milp.binaries.iter().enumerate() {
                let val = if mask >> bit & 1 == 1 { 1.0 } else { 0.0 };
                lp.add(vec![(v, 1.0)], Rel::Eq, val);
            }
            if let Solution::Optimal { objective, .. } = crate::solver::lp::solve(&lp) {
                best = Some(best.map_or(objective, |b: f64| b.min(objective)));
            }
        }
        best
    }

    #[test]
    fn random_knapsacks_match_brute_force() {
        crate::util::testkit::forall(
            "bb vs brute force knapsack",
            0xBEEF,
            30,
            |rng: &mut Rng| {
                let n = 3 + rng.next_below(4) as usize; // 3..6 items
                let mut lp = Lp::new(n);
                let mut weights = Vec::new();
                for i in 0..n {
                    lp.set_objective(i, -rng.uniform(1.0, 20.0)); // maximize value
                    weights.push((i, rng.uniform(1.0, 10.0)));
                }
                let cap = rng.uniform(5.0, 25.0);
                lp.add(weights, Rel::Le, cap);
                Milp::new(lp, (0..n).collect())
            },
            |milp| {
                let got = solve(milp).map(|s| s.objective);
                let want = brute_force(milp);
                match (got, want) {
                    (Some(g), Some(w)) if (g - w).abs() < 1e-5 => Ok(()),
                    (None, None) => Ok(()),
                    other => Err(format!("bb {:?} vs brute {:?}", other.0, other.1)),
                }
            },
        );
    }

    #[test]
    fn random_assignment_with_capacity_matches_brute_force() {
        crate::util::testkit::forall(
            "bb vs brute force capacitated assignment",
            0xFEED,
            20,
            |rng: &mut Rng| {
                // 2 tasks × 3 machines with machine capacity 1 on a random
                // machine, random costs.
                let nt = 2;
                let nm = 3;
                let mut lp = Lp::new(nt * nm);
                for i in 0..nt * nm {
                    lp.set_objective(i, rng.uniform(1.0, 10.0));
                }
                for t in 0..nt {
                    let row = (0..nm).map(|m| (t * nm + m, 1.0)).collect();
                    lp.add(row, Rel::Eq, 1.0);
                }
                let tight = rng.next_below(nm as u64) as usize;
                lp.add((0..nt).map(|t| (t * nm + tight, 1.0)).collect(), Rel::Le, 1.0);
                Milp::new(lp, (0..nt * nm).collect())
            },
            |milp| {
                let got = solve(milp).map(|s| s.objective);
                let want = brute_force(milp);
                match (got, want) {
                    (Some(g), Some(w)) if (g - w).abs() < 1e-5 => Ok(()),
                    (None, None) => Ok(()),
                    other => Err(format!("bb {:?} vs brute {:?}", other.0, other.1)),
                }
            },
        );
    }
}
