//! The slimmed event-loop core of the simulated-time executor.
//!
//! Drives one Multi-FedLS job over the simulated multi-cloud through the
//! pluggable module stack of a [`Framework`]: Pre-Scheduling → Initial
//! Mapping → provisioning → synchronous FL rounds → spot revocations →
//! Dynamic Scheduler replacement → checkpoint-based recovery → teardown,
//! with per-second billing throughout.
//!
//! This is the former monolithic `coordinator::sim::simulate` body with
//! every module decision routed through the stack's trait objects. With the
//! default stack the arithmetic (including floating-point operation order)
//! is unchanged, so outputs are bit-identical to the pre-refactor
//! simulator; `tests/framework_parity.rs` enforces that.

use crate::cloud::{Market, VmTypeId};
use crate::cloudsim::{MultiCloud, VmId};
use crate::coordinator::sim::{environment_for, SimConfig, SimEvent, SimOutcome};
use crate::dynsched::{CurrentMap, FaultyTask, RevocationCtx};
use crate::mapping::problem::{JobProfile, Mapping, MappingProblem};
use crate::market::MarketView;
use crate::presched::SlowdownReport;
use crate::simul::SimTime;
use crate::telemetry::{Candidate, DecisionKind, DecisionRecord, Elimination, EventKind};

use super::modules::FaultTolerance;
use super::Framework;

struct TaskState {
    vm_type: VmTypeId,
    instance: VmId,
    /// Rounds completed on this instance (warm-up applies on its first).
    rounds_on_instance: u32,
}

/// Telemetry-only `Provision` event for a freshly requested instance
/// (provider/region/market resolved from the catalog snapshot), carrying
/// the decision that caused the request.
fn provision_kind(
    mc: &MultiCloud,
    task: &str,
    vm_type: VmTypeId,
    inst: VmId,
    market: Market,
    decision: Option<u64>,
) -> EventKind {
    let cat = &mc.catalog;
    EventKind::Provision {
        task: task.to_string(),
        vm: cat.vm(vm_type).id.clone(),
        provider: cat.provider(cat.provider_of(vm_type)).name.clone(),
        region: cat.region(cat.region_of(vm_type)).name.clone(),
        spot: matches!(market, Market::Spot),
        boot_done: mc.instance(inst).ready_at,
        decision,
    }
}

/// Run one simulated Multi-FedLS execution through `fw`'s module stack.
pub(super) fn run(fw: &Framework, cfg: &SimConfig) -> anyhow::Result<SimOutcome> {
    run_stop(fw, cfg, None).map(|(out, _)| out)
}

/// [`run`] with an optional preemption instant: with `stop_secs = Some(s)`
/// the execution halts at simulated instant `s` if the job is still running
/// then (the workload engine's checkpoint-preempt hook). The Fault Tolerance
/// module plans the surviving round from the freshest checkpoint — exactly
/// the server-loss restore path (§4.3) — so the returned outcome's
/// `rounds_completed` is the checkpointed progress a later resume starts
/// from. Every live VM is terminated (and billed) at the preemption instant.
/// Returns the outcome plus the rounds of progress the preemption discarded
/// (completed work past the last surviving checkpoint).
///
/// With `stop_secs = None` the stop checks never fire and the arithmetic is
/// bit-identical to the unstoppable path.
pub(super) fn run_stop(
    fw: &Framework,
    cfg: &SimConfig,
    stop_secs: Option<f64>,
) -> anyhow::Result<(SimOutcome, u32)> {
    let (catalog, ground_truth) = environment_for(&cfg.app);
    // Assemble the spot-market model (the default: exponential k_r
    // revocations at constant price, bit-identical to the historical inline
    // draws) and the expected spot-price multiplier over the planning
    // horizon, which the mapping/dynsched cost models charge per spot
    // VM-second. Exactly 1.0 for the default market.
    let spot_price_factor = cfg.market.planning_price_factor(cfg.planning_horizon_secs());
    // Market outlook (opt-in via `[outlook]`): closed-form price/revocation
    // forecasts consumed by the mapper (windowed costs, deferred starts) and
    // the Dynamic Scheduler (remaining-horizon pricing). `None` keeps every
    // consumer on the flat expected-factor path above, bit for bit.
    let outlook = cfg.outlook.enabled.then(|| {
        crate::outlook::MarketOutlook::new(
            &cfg.market,
            cfg.revocation_mean_secs,
            cfg.outlook.clone(),
            cfg.planning_horizon_secs(),
        )
    });
    let mut mc = MultiCloud::with_market(
        catalog,
        ground_truth,
        cfg.market.build(cfg.revocation_mean_secs),
        cfg.seed,
    );
    let mut events = Vec::new();
    let mut now = SimTime::ZERO;

    // --- Pre-Scheduling (§4.1; cached per environment by `EnvCache`) ---
    let slowdowns = fw.pre_sched().slowdowns(&mc);
    let slowdowns: &SlowdownReport = slowdowns.as_ref();
    let job = cfg.app.profile();

    // --- Initial Mapping (§4.2) ---
    // (The problem borrows a snapshot of the catalog so the simulator can be
    // mutated while the dynamic scheduler keeps consulting prices/slowdowns.)
    let catalog = mc.catalog.clone();
    let problem = MappingProblem {
        catalog: &catalog,
        slowdowns,
        job: &job,
        alpha: cfg.alpha,
        market: cfg.scenario.client_market(),
        spot_price_factor,
        budget_round: cfg.budget_round,
        deadline_round: cfg.deadline_round,
        outlook: outlook.as_ref(),
    };
    let mapper = fw.mapper_for(cfg);
    let sol = mapper
        .map(&problem)
        .ok_or_else(|| anyhow::anyhow!("initial mapping infeasible ({})", mapper.name()))?;
    let initial: Mapping = sol.mapping.clone();

    // Decision provenance (gated on `[telemetry]` with `decisions = true`):
    // one DecisionRecord per decision point, IDs job-local and dense from 0.
    // The off path allocates nothing and stamps every event `decision: None`,
    // keeping it bit-identical to the pre-provenance executor.
    let record_decisions = cfg.telemetry.record_decisions();
    let mut decisions: Vec<DecisionRecord> = Vec::new();
    let vm_label = |vm: VmTypeId| {
        format!(
            "{}/{} {}",
            catalog.provider(catalog.provider_of(vm)).name,
            catalog.region(catalog.region_of(vm)).name,
            catalog.vm(vm).id
        )
    };
    let map_decision = if record_decisions {
        let id = decisions.len() as u64;
        decisions.push(DecisionRecord {
            id,
            at: now.secs(),
            kind: DecisionKind::InitialMapping,
            job: None,
            tenant: None,
            chosen: Some(vm_label(initial.server)),
            reason: format!(
                "{} mapper: objective {:.5} (α = {}) within budget ${:.4}/round and \
                 deadline {:.0}s",
                mapper.name(),
                sol.eval.objective,
                cfg.alpha,
                cfg.budget_round,
                cfg.deadline_round
            ),
            candidates: crate::mapping::explain_candidates(&problem, Some(&initial)),
            instances: Vec::new(),
            attributed_cost: None,
        });
        Some(id)
    } else {
        None
    };
    events.push(SimEvent {
        at: now,
        kind: EventKind::InitialMapping {
            server: mc.catalog.vm(initial.server).id.clone(),
            clients: initial.clients.iter().map(|&v| mc.catalog.vm(v).id.clone()).collect(),
            predicted_makespan: sol.eval.makespan,
            predicted_cost: sol.eval.total_cost,
            decision: map_decision,
        },
    });

    // Deferred start (outlook `defer = true`): the mapper judged a later
    // provisioning instant cheaper in expectation and the deadline slack
    // allows it, so the job idles (unbilled — nothing is provisioned yet)
    // until the chosen start offset.
    if sol.defer_secs > 0.0 {
        let defer_decision = if record_decisions {
            let id = decisions.len() as u64;
            let window = cfg.n_rounds as f64 * sol.eval.makespan;
            // Both start instants the outlook weighed, priced over the
            // job's expected execution window; bid advice is advisory-only.
            let (now_factor, deferred_factor) = outlook
                .as_ref()
                .map(|o| {
                    (o.expected_price_factor(0.0, window),
                     o.expected_price_factor(sol.defer_secs, window))
                })
                .unwrap_or((spot_price_factor, spot_price_factor));
            let bid = outlook.as_ref().and_then(|o| o.advise_bid(sol.defer_secs, window));
            decisions.push(DecisionRecord {
                id,
                at: now.secs(),
                kind: DecisionKind::Deferral,
                job: None,
                tenant: None,
                chosen: Some(format!("start at t={:.0}s", sol.defer_secs)),
                reason: match bid {
                    Some(b) => format!(
                        "outlook priced the deferred window cheaper; advised bid factor {b:.3}"
                    ),
                    None => "outlook priced the deferred window cheaper".to_string(),
                },
                candidates: vec![
                    Candidate {
                        label: format!("start at t={:.0}s", sol.defer_secs),
                        objective: deferred_factor,
                        price_factor: deferred_factor,
                        eliminated: None,
                    },
                    Candidate {
                        label: "start at t=0s".to_string(),
                        objective: now_factor,
                        price_factor: now_factor,
                        eliminated: Some(Elimination::Dominated),
                    },
                ],
                instances: Vec::new(),
                attributed_cost: None,
            });
            Some(id)
        } else {
            None
        };
        now = SimTime::from_secs(sol.defer_secs);
        events.push(SimEvent {
            at: now,
            kind: EventKind::Deferral { defer_secs: sol.defer_secs, decision: defer_decision },
        });
    }

    // --- provision all tasks (boot in parallel) ---
    let server_market = cfg.scenario.server_market();
    let client_market = cfg.scenario.client_market();
    let mut server = TaskState {
        vm_type: initial.server,
        instance: mc.provision(now, initial.server, server_market)?,
        rounds_on_instance: 0,
    };
    let mut clients: Vec<TaskState> = Vec::new();
    for &vm in &initial.clients {
        clients.push(TaskState {
            vm_type: vm,
            instance: mc.provision(now, vm, client_market)?,
            rounds_on_instance: 0,
        });
    }
    // The whole initial fleet is downstream of the mapping decision: its
    // billed cost attributes there.
    if let Some(id) = map_decision {
        let rec = &mut decisions[id as usize];
        rec.instances.push(server.instance.0);
        rec.instances.extend(clients.iter().map(|c| c.instance.0));
    }
    if cfg.telemetry.enabled {
        let k = provision_kind(
            &mc,
            "server",
            server.vm_type,
            server.instance,
            server_market,
            map_decision,
        );
        events.push(SimEvent { at: now, kind: k });
        for (i, c) in clients.iter().enumerate() {
            let k = provision_kind(
                &mc,
                &format!("client-{i}"),
                c.vm_type,
                c.instance,
                client_market,
                map_decision,
            );
            events.push(SimEvent { at: now, kind: k });
        }
    }
    let mut ready_at = mc.instance(server.instance).ready_at;
    for c in &clients {
        ready_at = ready_at.max(mc.instance(c.instance).ready_at);
    }
    now = ready_at;
    mc.mark_running(server.instance);
    for c in &clients {
        mc.mark_running(c.instance);
    }
    events.push(SimEvent { at: now, kind: EventKind::FlStart });
    let fl_start = now;

    // Dynamic Scheduler candidate sets (I_t), per task (§4.4).
    let all_vms: Vec<VmTypeId> = mc.catalog.vm_ids().collect();
    let mut server_set = all_vms.clone();
    let mut client_sets: Vec<Vec<VmTypeId>> = vec![all_vms.clone(); clients.len()];

    let mut n_revocations = 0u32;
    let mut revocations_per_task: Vec<u32> = vec![0; clients.len() + 1]; // [server, clients...]
    let mut completed = 0u32; // fully completed rounds
    // Freshest server-side checkpoint round (replicated → survives loss).
    let mut server_ckpt_round = 0u32;
    let mut safety = 0usize;
    let stop = stop_secs.map(SimTime::from_secs);
    let mut preempted = false;

    while completed < cfg.n_rounds {
        // Preemption instant reached (including mid-boot: `now` may already
        // sit past the stop after the initial provisioning or a replacement
        // boot): halt before starting another round.
        if let Some(s) = stop {
            if now >= s {
                now = s;
                preempted = true;
                break;
            }
        }
        safety += 1;
        anyhow::ensure!(safety < 200_000, "simulation did not converge (runaway revocations)");
        let round = completed + 1;

        // Round duration with the current placement.
        let duration = round_duration(cfg, &mc, slowdowns, &job, fw.ft(), &server, &clients);
        let end = now + duration;
        if cfg.telemetry.enabled {
            events.push(SimEvent {
                at: now,
                kind: EventKind::RoundStart { round, predicted_secs: duration },
            });
        }

        // Earliest spot revocation strictly before the round completes —
        // collecting *every* task hit at that instant, so co-timed evictions
        // (one trace instant or bid crossing hitting several VMs at once)
        // are processed as a single batched event instead of all but the
        // first silently absorbing into the replacement's boot wait.
        let mut hit: Option<(SimTime, Vec<FaultyTask>)> = None;
        let consider = |at: Option<SimTime>,
                        task: FaultyTask,
                        hit: &mut Option<(SimTime, Vec<FaultyTask>)>| {
            if let Some(t) = at {
                if t > now && t <= end {
                    match hit.as_mut() {
                        Some((bt, tasks)) if t < *bt => {
                            *bt = t;
                            tasks.clear();
                            tasks.push(task);
                        }
                        Some((bt, tasks)) if t == *bt => tasks.push(task),
                        Some(_) => {}
                        None => *hit = Some((t, vec![task])),
                    }
                }
            }
        };
        consider(mc.instance(server.instance).revocation_at, FaultyTask::Server, &mut hit);
        for (i, c) in clients.iter().enumerate() {
            consider(mc.instance(c.instance).revocation_at, FaultyTask::Client(i), &mut hit);
        }

        // Preemption cuts the round short: if nothing (round end or
        // revocation) happens at or before the stop instant, the in-flight
        // work is abandoned there and the FT restore plans the surviving
        // round.
        if let Some(s) = stop {
            let next = hit.as_ref().map_or(end, |(t, _)| *t);
            if next > s {
                now = s;
                preempted = true;
                break;
            }
        }

        match hit {
            None => {
                // Round completes.
                now = end;
                server.rounds_on_instance += 1;
                for c in clients.iter_mut() {
                    c.rounds_on_instance += 1;
                }
                completed = round;
                let saved = fw.ft().checkpoint_after_round(cfg, round);
                if saved {
                    server_ckpt_round = round;
                }
                // Message-exchange costs (Eq. 6) for this round.
                for c in &clients {
                    let m = &job.msg;
                    mc.charge_egress(now, server.vm_type, m.s_train_gb + m.s_aggreg_gb, "server msgs");
                    mc.charge_egress(now, c.vm_type, m.c_train_gb + m.c_test_gb, "client msgs");
                }
                if cfg.telemetry.enabled {
                    let m = &job.msg;
                    let egress_gb = clients.len() as f64
                        * (m.s_train_gb + m.s_aggreg_gb + m.c_train_gb + m.c_test_gb);
                    events.push(SimEvent { at: now, kind: EventKind::RoundEnd { round, egress_gb } });
                    if saved {
                        events.push(SimEvent { at: now, kind: EventKind::CheckpointSave { round } });
                    }
                }
            }
            Some((t_rev, faulty_tasks)) => {
                // Revocations interrupt the round; the round's work is lost.
                // Every task hit at `t_rev` is revoked and rescheduled in
                // consider-order (server first, then clients by index), so
                // later replacement choices see earlier ones in the current
                // map; the round resumes after the slowest replacement
                // boots (boots overlap).
                now = t_rev;
                if faulty_tasks.len() > 1 {
                    events.push(SimEvent {
                        at: now,
                        kind: EventKind::BatchedRevocation { count: faulty_tasks.len() },
                    });
                }
                let mut boot_max = now;
                for faulty in faulty_tasks {
                    n_revocations += 1;
                    let current_map = CurrentMap {
                        server: server.vm_type,
                        clients: clients.iter().map(|c| c.vm_type).collect(),
                    };
                    let (task_name, old_type, set): (String, VmTypeId, &mut Vec<VmTypeId>) =
                        match faulty {
                            FaultyTask::Server => {
                                ("server".into(), server.vm_type, &mut server_set)
                            }
                            FaultyTask::Client(i) => {
                                (format!("client-{i}"), clients[i].vm_type, &mut client_sets[i])
                            }
                        };
                    // Revoke in the platform (blocks the type per policy).
                    let inst = match faulty {
                        FaultyTask::Server => server.instance,
                        FaultyTask::Client(i) => clients[i].instance,
                    };
                    mc.revoke(now, inst, cfg.dynsched_policy.remove_revoked);
                    events.push(SimEvent {
                        at: now,
                        kind: EventKind::Revocation {
                            task: task_name.clone(),
                            vm: mc.catalog.vm(old_type).id.clone(),
                            round,
                            provider: mc
                                .catalog
                                .provider(mc.catalog.provider_of(old_type))
                                .name
                                .clone(),
                            region: mc.catalog.region(mc.catalog.region_of(old_type)).name.clone(),
                        },
                    });

                    // Dynamic Scheduler picks the replacement. With an
                    // outlook, candidates are priced over the actual
                    // remaining-rounds window rather than the planning-wide
                    // expected factor.
                    let remaining_secs =
                        (cfg.n_rounds - completed) as f64 * sol.eval.makespan;
                    let ctx = RevocationCtx {
                        problem: &problem,
                        map: &current_map,
                        faulty,
                        candidates: set,
                        revoked: old_type,
                        policy: cfg.dynsched_policy,
                        at: now,
                        remaining_secs,
                        market: MarketView::with_outlook(&cfg.market, outlook.as_ref()),
                    };
                    let (selection, new_set) = fw.dynsched().select(&ctx);
                    // Provenance must replay the selection over the *incoming*
                    // candidate set, before the revoked type is removed.
                    let replace_decision = if record_decisions {
                        let id = decisions.len() as u64;
                        let chosen_vm = selection.as_ref().map(|s| s.vm);
                        decisions.push(DecisionRecord {
                            id,
                            at: now.secs(),
                            kind: DecisionKind::Replacement,
                            job: None,
                            tenant: None,
                            chosen: chosen_vm.map(vm_label),
                            reason: match &selection {
                                Some(s) => format!(
                                    "{} replaced {} after revocation: best weighted objective \
                                     {:.5} among {} candidate(s)",
                                    fw.dynsched().name(),
                                    task_name,
                                    s.value,
                                    s.candidates_considered
                                ),
                                None => format!(
                                    "candidate set for {task_name} exhausted after repeated \
                                     revocations"
                                ),
                            },
                            candidates: fw.dynsched().explain(&ctx, chosen_vm),
                            instances: Vec::new(),
                            attributed_cost: None,
                        });
                        Some(id)
                    } else {
                        None
                    };
                    *set = new_set;
                    let sel = selection
                        .ok_or_else(|| anyhow::anyhow!("dynamic scheduler exhausted candidates"))?;

                    // Provision the replacement; everyone waits for its boot
                    // (the server requires all clients each round, §4.3).
                    // When the per-task revocation cap is reached the
                    // replacement is not re-exposed to the Poisson process
                    // (§5.6.1's observed "at most one revocation per task"
                    // regime).
                    let task_idx = match faulty {
                        FaultyTask::Server => 0,
                        FaultyTask::Client(i) => i + 1,
                    };
                    revocations_per_task[task_idx] += 1;
                    let allow_more = cfg
                        .max_revocations_per_task
                        .map_or(true, |cap| revocations_per_task[task_idx] < cap);
                    let new_inst = mc.provision_with(
                        now,
                        sel.vm,
                        match faulty {
                            FaultyTask::Server => server_market,
                            FaultyTask::Client(_) => client_market,
                        },
                        allow_more,
                    )?;
                    if let Some(id) = replace_decision {
                        decisions[id as usize].instances.push(new_inst.0);
                    }
                    let boot_done = mc.instance(new_inst).ready_at;
                    boot_max = boot_max.max(boot_done);
                    events.push(SimEvent {
                        at: now,
                        kind: EventKind::Replacement {
                            task: task_name.clone(),
                            vm: mc.catalog.vm(sel.vm).id.clone(),
                            value: sel.value,
                            boot_done,
                            decision: replace_decision,
                        },
                    });
                    if cfg.telemetry.enabled {
                        let market = match faulty {
                            FaultyTask::Server => server_market,
                            FaultyTask::Client(_) => client_market,
                        };
                        let k =
                            provision_kind(&mc, &task_name, sel.vm, new_inst, market, replace_decision);
                        events.push(SimEvent { at: now, kind: k });
                    }
                    match faulty {
                        FaultyTask::Server => {
                            server = TaskState {
                                vm_type: sel.vm,
                                instance: new_inst,
                                rounds_on_instance: 0,
                            };
                            // Recovery (§4.3): the FT module plans the
                            // restore round from the freshest checkpoint
                            // available.
                            let restore = fw.ft().restore_round(cfg, completed, server_ckpt_round);
                            if restore < completed {
                                events.push(SimEvent {
                                    at: now,
                                    kind: EventKind::CheckpointRestore {
                                        restore_round: restore,
                                        lost: completed - restore,
                                    },
                                });
                                completed = restore;
                            }
                        }
                        FaultyTask::Client(i) => {
                            clients[i] = TaskState {
                                vm_type: sel.vm,
                                instance: new_inst,
                                rounds_on_instance: 0,
                            };
                        }
                    }
                    mc.mark_running(new_inst);
                }
                // Other tasks idle (and bill) until every replacement is up.
                now = boot_max;
            }
        }
    }

    // Checkpoint-preemption epilogue: the FT module plans the surviving
    // round exactly as it would after a server loss — with client
    // checkpoints every round nothing is lost; server-only checkpointing
    // falls back to the last periodic save; no FT restarts from scratch.
    let mut rounds_lost = 0u32;
    if preempted {
        let restore = fw.ft().restore_round(cfg, completed, server_ckpt_round);
        rounds_lost = completed - restore;
        completed = restore;
        events.push(SimEvent {
            at: now,
            // The victim-selection decision lives in the workload engine's
            // ID space; it stamps this event when splicing the trace.
            kind: EventKind::Preemption { round: completed, lost: rounds_lost, decision: None },
        });
    }

    let fl_end = now;
    // Teardown: terminate every live instance.
    let live: Vec<VmId> = mc.live_instances().map(|v| v.id).collect();
    for id in live {
        mc.terminate(now, id);
    }
    events.push(SimEvent { at: now, kind: EventKind::Teardown { preempted } });

    let fl_exec_secs = if preempted { (fl_end - fl_start).max(0.0) } else { fl_end - fl_start };
    // Spans + metrics are reconstructed post-hoc from the event log and the
    // ledger — the hot loop carries no telemetry state.
    let mut telemetry = cfg.telemetry.enabled.then(|| {
        crate::telemetry::build_job_telemetry(
            &cfg.telemetry,
            &mc.catalog,
            &mc.ledger,
            &events,
            now,
            fl_start,
        )
    });
    if let Some(tel) = telemetry.as_mut() {
        if record_decisions {
            // Cost attribution: a decision is charged the billed cost of
            // every VM lifetime it provisioned (needs span reconstruction).
            if cfg.telemetry.spans {
                for r in &mut decisions {
                    if !r.instances.is_empty() {
                        r.attributed_cost = Some(
                            tel.vms
                                .iter()
                                .filter(|v| r.instances.contains(&v.instance))
                                .map(|v| v.billed_cost)
                                .sum(),
                        );
                    }
                }
            }
            tel.decisions = std::mem::take(&mut decisions);
        }
    }
    let outcome = SimOutcome {
        fl_exec_secs,
        total_secs: now.secs(),
        total_cost: mc.total_cost(now),
        vm_cost: mc.ledger.vm_cost(now),
        egress_cost: mc.ledger.egress_cost(),
        n_revocations,
        rounds_completed: completed,
        initial_server: mc.catalog.vm(initial.server).id.clone(),
        initial_clients: initial
            .clients
            .iter()
            .map(|&v| mc.catalog.vm(v).id.clone())
            .collect(),
        events,
        predicted_round_makespan: sol.eval.makespan,
        predicted_round_cost: sol.eval.total_cost,
        telemetry,
    };
    Ok((outcome, rounds_lost))
}

/// Duration of one FL round for the current placement, including first-round
/// warm-up on fresh instances and the FT module's checkpoint overheads
/// (§5.5). Overheads are added in the same order as the historical
/// monolithic simulator (disabled hooks return exactly 0.0, which is a
/// bitwise no-op on the accumulators).
fn round_duration(
    cfg: &SimConfig,
    mc: &MultiCloud,
    slowdowns: &SlowdownReport,
    job: &JobProfile,
    ft: &dyn FaultTolerance,
    server: &TaskState,
    clients: &[TaskState],
) -> f64 {
    let mut makespan: f64 = 0.0;
    for (i, c) in clients.iter().enumerate() {
        let first = c.rounds_on_instance == 0;
        let exec = mc.exec_secs(c.vm_type, job.client_train_bl[i] + job.client_test_bl[i], first);
        let comm = (job.train_comm_bl + job.test_comm_bl)
            * slowdowns.sl_comm(mc.catalog.region_of(c.vm_type), mc.catalog.region_of(server.vm_type));
        let mut t = exec + comm;
        // Client checkpoint: save received weights locally each round.
        t += ft.client_round_overhead_secs(cfg);
        makespan = makespan.max(t);
    }
    let agg = job.agg_bl * slowdowns.sl_inst(server.vm_type);
    let mut total = makespan + agg;
    // Server checkpoint every X rounds (local save is synchronous; the
    // replication overlaps the next round's waiting, §5.5). The round being
    // executed is approximated by the server instance's age + 1.
    let next_round_number = server.rounds_on_instance + 1;
    total += ft.server_armed_overhead_secs(cfg);
    total += ft.server_save_overhead_secs(cfg, next_round_number);
    total
}
