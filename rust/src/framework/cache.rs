//! The shared environment cache: one Pre-Scheduling [`SlowdownReport`] per
//! environment fingerprint, computed at most once and shared (via `Arc`)
//! across every trial of a campaign.
//!
//! The paper makes the point explicitly (§4.1): "it is not necessary to
//! re-execute the dummy application in every framework execution" — the
//! report only depends on the environment (regions, VM types, prices), not
//! on the job, seed, or failure pattern. The sweep engine therefore keys
//! the cache on [`crate::presched::fingerprint`] and the worker pool shares
//! one instance, turning N-trials-per-environment re-measurement into a
//! single measurement per campaign.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cloudsim::MultiCloud;
use crate::presched::{self, PreScheduler, SlowdownReport};

/// Environment fingerprint → shared slowdown report. Thread-safe; the
/// measurement runs under the map lock so each environment is measured
/// exactly once even when many workers miss simultaneously.
pub struct EnvCache {
    reports: Mutex<BTreeMap<String, Arc<SlowdownReport>>>,
    computations: AtomicUsize,
}

impl EnvCache {
    pub fn new() -> EnvCache {
        EnvCache { reports: Mutex::new(BTreeMap::new()), computations: AtomicUsize::new(0) }
    }

    /// The report for `mc`'s environment: served from cache when the
    /// fingerprint matches, measured (and recorded) otherwise.
    pub fn get_or_measure(&self, mc: &MultiCloud) -> Arc<SlowdownReport> {
        let key = presched::fingerprint(&mc.catalog);
        let mut reports = self.reports.lock().expect("env cache lock poisoned");
        if let Some(report) = reports.get(&key) {
            return report.clone();
        }
        let report = Arc::new(PreScheduler::new(mc).measure_defaults());
        self.computations.fetch_add(1, Ordering::Relaxed);
        reports.insert(key, report.clone());
        report
    }

    /// How many reports were actually measured (cache misses). A campaign
    /// over one environment must report exactly 1 whatever its trial count.
    pub fn computations(&self) -> usize {
        self.computations.load(Ordering::Relaxed)
    }

    /// Number of distinct environments currently cached.
    pub fn len(&self) -> usize {
        self.reports.lock().expect("env cache lock poisoned").len()
    }
}

impl Default for EnvCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::tables;
    use crate::cloudsim::RevocationModel;

    fn sim(seed: u64) -> MultiCloud {
        MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::none(),
            seed,
        )
    }

    #[test]
    fn same_environment_measures_once() {
        let cache = EnvCache::new();
        let a = cache.get_or_measure(&sim(1));
        let b = cache.get_or_measure(&sim(2)); // different seed, same catalog
        assert_eq!(cache.computations(), 1);
        assert_eq!(cache.len(), 1);
        assert!(Arc::ptr_eq(&a, &b), "both callers must share one report");
    }

    #[test]
    fn different_environments_measure_separately() {
        let cache = EnvCache::new();
        cache.get_or_measure(&sim(1));
        let aws = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            1,
        );
        cache.get_or_measure(&aws);
        assert_eq!(cache.computations(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn concurrent_misses_still_measure_once() {
        let cache = Arc::new(EnvCache::new());
        std::thread::scope(|s| {
            for seed in 0..8u64 {
                let cache = cache.clone();
                s.spawn(move || {
                    cache.get_or_measure(&sim(seed));
                });
            }
        });
        assert_eq!(cache.computations(), 1);
    }
}
