//! The four pluggable module traits of the Multi-FedLS pipeline and their
//! built-in implementations.
//!
//! Each trait mirrors one module of the paper:
//!
//! * [`PreScheduling`] (§4.1) produces the environment's `SlowdownReport`;
//! * [`InitialMapper`] (§4.2) solves the placement problem;
//! * [`FaultTolerance`] (§4.3) prices checkpoint overheads and plans
//!   recovery;
//! * [`DynScheduler`] (§4.4) picks replacement VMs after revocations.
//!
//! All traits are object-safe and `Send + Sync`, so module stacks can be
//! shared across the sweep worker pool. The default stack
//! (`DummyAppPreSched` + the [`MapperKind`]-selected mapper + `PaperFt` +
//! `PaperDynSched`) reproduces the original monolithic simulator
//! bit-for-bit; every other implementation is a drop-in ablation.

use std::sync::Arc;

use crate::cloud::VmTypeId;
use crate::cloudsim::MultiCloud;
use crate::coordinator::sim::SimConfig;
use crate::dynsched::{self, RevocationCtx, Selection};
use crate::mapping::problem::{Mapping, MappingProblem};
use crate::mapping::{self, MapperKind, MappingSolution};
use crate::presched::{PreScheduler, SlowdownReport};
use crate::telemetry::{Candidate, Elimination};

use super::EnvCache;

// ---------------------------------------------------------------------------
// Pre-Scheduling (§4.1)
// ---------------------------------------------------------------------------

/// Produces the slowdown report the Initial Mapping and Dynamic Scheduler
/// consume.
pub trait PreScheduling: Send + Sync {
    fn name(&self) -> &'static str;
    /// Measure (or fetch) the environment's slowdown report.
    fn slowdowns(&self, mc: &MultiCloud) -> Arc<SlowdownReport>;
}

/// Default: run the dummy application on every framework execution — the
/// paper's measurement protocol, uncached.
pub struct DummyAppPreSched;

impl PreScheduling for DummyAppPreSched {
    fn name(&self) -> &'static str {
        "dummy-app"
    }
    fn slowdowns(&self, mc: &MultiCloud) -> Arc<SlowdownReport> {
        Arc::new(PreScheduler::new(mc).measure_defaults())
    }
}

/// Campaign-scoped caching: one measurement per environment fingerprint,
/// shared across every trial that uses the same [`EnvCache`].
pub struct CachedPreSched {
    cache: Arc<EnvCache>,
}

impl CachedPreSched {
    pub fn new(cache: Arc<EnvCache>) -> CachedPreSched {
        CachedPreSched { cache }
    }
}

impl PreScheduling for CachedPreSched {
    fn name(&self) -> &'static str {
        "cached-dummy-app"
    }
    fn slowdowns(&self, mc: &MultiCloud) -> Arc<SlowdownReport> {
        self.cache.get_or_measure(mc)
    }
}

// ---------------------------------------------------------------------------
// Initial Mapping (§4.2)
// ---------------------------------------------------------------------------

/// Solves the Initial Mapping problem; `None` = no feasible placement.
pub trait InitialMapper: Send + Sync {
    fn name(&self) -> &'static str;
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution>;
}

/// Wrap a bare baseline `Mapping` into a solution, rejecting infeasible
/// placements (the exact solver checks feasibility internally; baselines
/// need the explicit gate).
fn solution_from(p: &MappingProblem, mapping: Mapping) -> Option<MappingSolution> {
    let eval = p.evaluate(&mapping);
    if !eval.feasible {
        return None;
    }
    let defer_secs = p.defer_secs(eval.makespan);
    Some(MappingSolution { mapping, eval, nodes: 0, defer_secs })
}

/// The structured exact MILP solver (the paper's production path).
pub struct ExactMapper;

impl InitialMapper for ExactMapper {
    fn name(&self) -> &'static str {
        "exact"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::exact::solve(p)
    }
}

/// The linearized-MILP transcription over the generic simplex + B&B solver
/// (slow; cross-check and ablation only).
pub struct MilpMapper;

impl InitialMapper for MilpMapper {
    fn name(&self) -> &'static str {
        "milp"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::milp::solve(p).and_then(|m| solution_from(p, m))
    }
}

/// Everyone on the cheapest-rate VM type that fits quota.
pub struct CheapestMapper;

impl InitialMapper for CheapestMapper {
    fn name(&self) -> &'static str {
        "cheapest"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::baselines::cheapest(p).and_then(|m| solution_from(p, m))
    }
}

/// Everyone on the lowest-slowdown VM type that fits quota.
pub struct FastestMapper;

impl InitialMapper for FastestMapper {
    fn name(&self) -> &'static str {
        "fastest"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::baselines::fastest(p).and_then(|m| solution_from(p, m))
    }
}

/// Uniform-random feasible placement.
pub struct RandomMapper {
    pub seed: u64,
    pub attempts: usize,
}

impl Default for RandomMapper {
    fn default() -> Self {
        RandomMapper { seed: 2024, attempts: 200 }
    }
}

impl InitialMapper for RandomMapper {
    fn name(&self) -> &'static str {
        "random"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::baselines::random(p, self.seed, self.attempts).and_then(|m| solution_from(p, m))
    }
}

/// Exact solve restricted to the best single provider (the "don't go
/// multi-cloud" ablation).
pub struct SingleCloudMapper;

impl InitialMapper for SingleCloudMapper {
    fn name(&self) -> &'static str {
        "single-cloud"
    }
    fn map(&self, p: &MappingProblem) -> Option<MappingSolution> {
        mapping::baselines::single_cloud(p, None).and_then(|m| solution_from(p, m))
    }
}

/// A pinned, precomputed Initial Mapping. The workload engine solves each
/// job's placement against the *residual* shared quota at admission time and
/// pins the result here, so the per-job event loop provisions exactly the
/// admitted placement instead of re-solving against the full catalog.
pub struct FixedMapper {
    solution: MappingSolution,
}

impl FixedMapper {
    pub fn new(solution: MappingSolution) -> FixedMapper {
        FixedMapper { solution }
    }
}

impl InitialMapper for FixedMapper {
    fn name(&self) -> &'static str {
        "fixed"
    }
    fn map(&self, _p: &MappingProblem) -> Option<MappingSolution> {
        Some(self.solution.clone())
    }
}

/// The built-in mapper for a [`MapperKind`] (job-spec / sweep selection).
pub fn mapper_for(kind: MapperKind) -> Arc<dyn InitialMapper> {
    match kind {
        MapperKind::Exact => Arc::new(ExactMapper),
        MapperKind::Milp => Arc::new(MilpMapper),
        MapperKind::Cheapest => Arc::new(CheapestMapper),
        MapperKind::Fastest => Arc::new(FastestMapper),
        MapperKind::Random => Arc::new(RandomMapper::default()),
        MapperKind::SingleCloud => Arc::new(SingleCloudMapper),
    }
}

// ---------------------------------------------------------------------------
// Fault Tolerance (§4.3)
// ---------------------------------------------------------------------------

/// Checkpoint-overhead and recovery hooks consulted by the event loop.
/// Implementations must be pure functions of `cfg` and their arguments —
/// the loop owns all mutable state (current checkpoint round, etc.).
pub trait FaultTolerance: Send + Sync {
    fn name(&self) -> &'static str;
    /// Seconds each client adds to its round for checkpointing received
    /// weights (0 when disabled).
    fn client_round_overhead_secs(&self, cfg: &SimConfig) -> f64;
    /// Constant per-round server-side overhead while checkpointing is armed
    /// (0 when disabled).
    fn server_armed_overhead_secs(&self, cfg: &SimConfig) -> f64;
    /// Synchronous save cost added when round `next_round_number` triggers a
    /// periodic server checkpoint (0 otherwise).
    fn server_save_overhead_secs(&self, cfg: &SimConfig, next_round_number: u32) -> f64;
    /// Does completing `round` persist a server checkpoint?
    fn checkpoint_after_round(&self, cfg: &SimConfig, round: u32) -> bool;
    /// Round to restore from after a server loss, given `completed` rounds
    /// and the freshest server checkpoint.
    fn restore_round(&self, cfg: &SimConfig, completed: u32, server_ckpt_round: u32) -> u32;
}

/// The paper's checkpoint model (§4.3), calibrated against Fig. 2: client
/// checkpoints every round, server checkpoints every X rounds plus a
/// constant armed-overhead, recovery from the freshest checkpoint.
pub struct PaperFt;

impl FaultTolerance for PaperFt {
    fn name(&self) -> &'static str {
        "paper-checkpoints"
    }

    fn client_round_overhead_secs(&self, cfg: &SimConfig) -> f64 {
        if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
            cfg.ft.client_save_overhead_secs(cfg.app.checkpoint_gb)
        } else {
            0.0
        }
    }

    fn server_armed_overhead_secs(&self, cfg: &SimConfig) -> f64 {
        if cfg.checkpoints_enabled {
            cfg.ft.server_round_overhead_secs
        } else {
            0.0
        }
    }

    fn server_save_overhead_secs(&self, cfg: &SimConfig, next_round_number: u32) -> f64 {
        if cfg.checkpoints_enabled && next_round_number % cfg.ft.server_every_rounds == 0 {
            cfg.ft.save_overhead_secs(cfg.app.checkpoint_gb)
        } else {
            0.0
        }
    }

    fn checkpoint_after_round(&self, cfg: &SimConfig, round: u32) -> bool {
        cfg.checkpoints_enabled && round % cfg.ft.server_every_rounds == 0
    }

    fn restore_round(&self, cfg: &SimConfig, completed: u32, server_ckpt_round: u32) -> u32 {
        if cfg.checkpoints_enabled && cfg.ft.client_checkpoint {
            // Clients checkpoint every round → freshest state is `completed`.
            completed
        } else if cfg.checkpoints_enabled {
            server_ckpt_round
        } else {
            0
        }
    }
}

/// Fault tolerance fully disabled regardless of `cfg` (the "no FT module"
/// ablation: zero overheads, server losses restart from round 0).
pub struct NoFt;

impl FaultTolerance for NoFt {
    fn name(&self) -> &'static str {
        "no-ft"
    }
    fn client_round_overhead_secs(&self, _cfg: &SimConfig) -> f64 {
        0.0
    }
    fn server_armed_overhead_secs(&self, _cfg: &SimConfig) -> f64 {
        0.0
    }
    fn server_save_overhead_secs(&self, _cfg: &SimConfig, _next_round_number: u32) -> f64 {
        0.0
    }
    fn checkpoint_after_round(&self, _cfg: &SimConfig, _round: u32) -> bool {
        false
    }
    fn restore_round(&self, _cfg: &SimConfig, _completed: u32, _server_ckpt_round: u32) -> u32 {
        0
    }
}

// ---------------------------------------------------------------------------
// Dynamic Scheduler (§4.4)
// ---------------------------------------------------------------------------

/// Picks the replacement VM for a revoked task, returning the selection and
/// the task's updated candidate set.
///
/// The single [`RevocationCtx`] argument carries the whole decision state —
/// problem, current placement, faulty task, candidate set, revoked type,
/// policy, the revocation instant, and a read-only
/// [`crate::market::MarketView`] of the job's price series — so
/// implementations can be time- and market-aware
/// without the trait growing a new positional parameter for every addition.
/// Wrappers narrow the context instead of re-plumbing arguments (the
/// workload engine's quota filter re-issues the ctx with a filtered
/// candidate set). `InitialMapper` and `FaultTolerance` keep their short
/// positional signatures (≤ 3 arguments each); they get the same treatment
/// the day they grow past that.
pub trait DynScheduler: Send + Sync {
    fn name(&self) -> &'static str;
    fn select(&self, ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>);

    /// Decision provenance for a selection this scheduler made over `ctx`:
    /// the ranked candidate table with a typed elimination reason per
    /// loser. Called post-hoc by the executor only when `[telemetry]`
    /// records decisions, so it must not mutate scheduler state. The
    /// default replays Algorithm 3's scoring; implementations whose
    /// selection logic differs override it so the table reflects their
    /// real reasons.
    fn explain(&self, ctx: &RevocationCtx<'_>, chosen: Option<VmTypeId>) -> Vec<Candidate> {
        dynsched::explain_candidates(ctx, chosen)
    }
}

/// Algorithms 1–3 (the paper's Dynamic Scheduler): re-compute makespan and
/// cost for every candidate and minimize the weighted objective.
pub struct PaperDynSched;

impl DynScheduler for PaperDynSched {
    fn name(&self) -> &'static str {
        "algorithms-1-3"
    }
    fn select(&self, ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
        dynsched::select_instance(ctx)
    }
}

/// Trivial baseline: always restart the task on the same VM type that was
/// revoked, ignoring the candidate set and the removal policy. Isolates the
/// benefit of Algorithm 3's re-optimization in ablations.
pub struct RestartSameType;

impl DynScheduler for RestartSameType {
    fn name(&self) -> &'static str {
        "restart-same-type"
    }
    fn select(&self, ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
        let (p, map, faulty, revoked) = (ctx.problem, ctx.map, ctx.faulty, ctx.revoked);
        let expected_makespan = dynsched::recompute_makespan(p, map, faulty, revoked);
        let expected_cost = dynsched::recompute_cost(p, map, faulty, revoked, expected_makespan);
        let selection = Selection {
            vm: revoked,
            expected_makespan,
            expected_cost,
            value: p.objective_value(expected_cost, expected_makespan),
            candidates_considered: 1,
        };
        (Some(selection), ctx.candidates.to_vec())
    }

    fn explain(&self, ctx: &RevocationCtx<'_>, chosen: Option<VmTypeId>) -> Vec<Candidate> {
        // This baseline never ranks the candidate set: the one candidate it
        // considers is the revoked type itself, so that's the whole table.
        let (p, cat) = (ctx.problem, ctx.problem.catalog);
        let makespan = dynsched::recompute_makespan(p, ctx.map, ctx.faulty, ctx.revoked);
        let cost = dynsched::recompute_cost(p, ctx.map, ctx.faulty, ctx.revoked, makespan);
        vec![Candidate {
            label: format!(
                "{}/{} {}",
                cat.provider(cat.provider_of(ctx.revoked)).name,
                cat.region(cat.region_of(ctx.revoked)).name,
                cat.vm(ctx.revoked).id
            ),
            objective: p.objective_value(cost, makespan),
            price_factor: p.spot_price_factor,
            eliminated: if chosen == Some(ctx.revoked) {
                None
            } else {
                Some(Elimination::Dominated)
            },
        }]
    }
}
