//! The composable Multi-FedLS execution pipeline.
//!
//! The paper defines the framework as four cooperating modules; here each
//! is an object-safe trait ([`PreScheduling`], [`InitialMapper`],
//! [`FaultTolerance`], [`DynScheduler`] in [`modules`]) plugged into a
//! slimmed event-loop core (`exec.rs`, carved out of the former monolithic
//! `coordinator::sim::simulate`). A [`Framework`] value is one assembled
//! stack:
//!
//! ```
//! use multi_fedls::apps;
//! use multi_fedls::coordinator::{Scenario, SimConfig};
//! use multi_fedls::framework::{CheapestMapper, Framework};
//!
//! let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
//! cfg.checkpoints_enabled = false;
//! cfg.n_rounds = 2;
//!
//! // The default stack reproduces the paper's pipeline exactly...
//! let outcome = Framework::default_stack().run(&cfg).unwrap();
//! assert_eq!(outcome.rounds_completed, 2);
//!
//! // ...and any module can be swapped for an ablation.
//! let greedy = Framework::builder().mapper(CheapestMapper).build();
//! let ablated = greedy.run(&cfg).unwrap();
//! assert_ne!(outcome.initial_server, ablated.initial_server);
//! ```
//!
//! The Initial Mapping module is special-cased for configuration-driven
//! selection: unless the builder pins a mapper, each run resolves
//! `cfg.mapper` (a `MapperKind`) through `modules::mapper_for`, so job
//! specs and sweep grids can choose the solver per configuration.
//!
//! [`EnvCache`] is the shared environment cache: campaign drivers build
//! their stack with [`Framework::with_env_cache`] so the Pre-Scheduling
//! slowdown report is measured once per environment instead of once per
//! trial (see `crate::sweep`).

pub mod cache;
mod exec;
pub mod modules;

pub use cache::EnvCache;
pub use modules::{
    CachedPreSched, CheapestMapper, DummyAppPreSched, DynScheduler, ExactMapper, FastestMapper,
    FaultTolerance, FixedMapper, InitialMapper, MilpMapper, NoFt, PaperDynSched, PaperFt,
    PreScheduling, RandomMapper, RestartSameType, SingleCloudMapper,
};

use std::sync::Arc;

use crate::coordinator::sim::{SimConfig, SimOutcome};
use crate::mapping::MapperKind;

/// One assembled module stack. Cheap to clone (modules are shared behind
/// `Arc`) and `Sync`, so a single stack can drive a whole worker pool.
#[derive(Clone)]
pub struct Framework {
    pre_sched: Arc<dyn PreScheduling>,
    /// `None` = resolve from `cfg.mapper` at run time.
    mapper: Option<Arc<dyn InitialMapper>>,
    ft: Arc<dyn FaultTolerance>,
    dynsched: Arc<dyn DynScheduler>,
}

impl Framework {
    pub fn builder() -> FrameworkBuilder {
        FrameworkBuilder {
            pre_sched: Arc::new(DummyAppPreSched),
            mapper: None,
            ft: Arc::new(PaperFt),
            dynsched: Arc::new(PaperDynSched),
        }
    }

    /// The paper's stack: dummy-app Pre-Scheduling, config-selected mapper
    /// (exact by default), checkpoint FT, Algorithms 1–3 Dynamic Scheduler.
    pub fn default_stack() -> Framework {
        Self::builder().build()
    }

    /// The default stack with Pre-Scheduling served from a shared
    /// environment cache (one slowdown measurement per environment).
    pub fn with_env_cache(cache: Arc<EnvCache>) -> Framework {
        Self::builder().pre_sched(CachedPreSched::new(cache)).build()
    }

    /// Execute one configuration through this stack.
    pub fn run(&self, cfg: &SimConfig) -> anyhow::Result<SimOutcome> {
        exec::run(self, cfg)
    }

    /// Execute one configuration, halting at simulated instant `stop_secs`
    /// if the job is still running then — the checkpoint-preempt hook the
    /// workload engine's preemptive scheduling policies use. The Fault
    /// Tolerance module plans the surviving round from the freshest
    /// checkpoint (the §4.3 restore path), every live VM is terminated and
    /// billed at the stop instant, and the outcome's `rounds_completed` is
    /// the checkpointed progress a resume continues from. Returns the
    /// outcome plus the completed rounds the preemption discarded (0 with
    /// client checkpoints on — a resumed job re-executes nothing).
    pub fn run_until(&self, cfg: &SimConfig, stop_secs: f64) -> anyhow::Result<(SimOutcome, u32)> {
        exec::run_stop(self, cfg, Some(stop_secs))
    }

    pub(crate) fn pre_sched(&self) -> &dyn PreScheduling {
        self.pre_sched.as_ref()
    }

    pub(crate) fn ft(&self) -> &dyn FaultTolerance {
        self.ft.as_ref()
    }

    pub(crate) fn dynsched(&self) -> &dyn DynScheduler {
        self.dynsched.as_ref()
    }

    /// The mapper for `cfg`: the builder-pinned module if any, otherwise
    /// the built-in implementation selected by `cfg.mapper`.
    pub fn mapper_for(&self, cfg: &SimConfig) -> Arc<dyn InitialMapper> {
        match &self.mapper {
            Some(m) => m.clone(),
            None => modules::mapper_for(cfg.mapper),
        }
    }
}

/// Assembles a [`Framework`], defaulting every slot to the paper's module.
pub struct FrameworkBuilder {
    pre_sched: Arc<dyn PreScheduling>,
    mapper: Option<Arc<dyn InitialMapper>>,
    ft: Arc<dyn FaultTolerance>,
    dynsched: Arc<dyn DynScheduler>,
}

impl FrameworkBuilder {
    pub fn pre_sched(mut self, module: impl PreScheduling + 'static) -> Self {
        self.pre_sched = Arc::new(module);
        self
    }

    /// Pin the Initial Mapping module (overrides `cfg.mapper` selection).
    pub fn mapper(mut self, module: impl InitialMapper + 'static) -> Self {
        self.mapper = Some(Arc::new(module));
        self
    }

    /// Select the built-in mapper for a [`MapperKind`] (equivalent to
    /// setting `cfg.mapper`, but pinned at build time).
    pub fn mapper_kind(mut self, kind: MapperKind) -> Self {
        self.mapper = Some(modules::mapper_for(kind));
        self
    }

    pub fn ft(mut self, module: impl FaultTolerance + 'static) -> Self {
        self.ft = Arc::new(module);
        self
    }

    pub fn dynsched(mut self, module: impl DynScheduler + 'static) -> Self {
        self.dynsched = Arc::new(module);
        self
    }

    pub fn build(self) -> Framework {
        Framework {
            pre_sched: self.pre_sched,
            mapper: self.mapper,
            ft: self.ft,
            dynsched: self.dynsched,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::Scenario;

    #[test]
    fn default_stack_runs_til() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
        cfg.checkpoints_enabled = false;
        cfg.n_rounds = 3;
        let out = Framework::default_stack().run(&cfg).unwrap();
        assert_eq!(out.rounds_completed, 3);
        assert_eq!(out.initial_clients, vec!["vm126"; 4]);
    }

    #[test]
    fn builder_pinned_mapper_overrides_cfg_selection() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
        cfg.checkpoints_enabled = false;
        cfg.n_rounds = 2;
        cfg.mapper = MapperKind::Exact;
        let fw = Framework::builder().mapper(CheapestMapper).build();
        let out = fw.run(&cfg).unwrap();
        // cheapest picks vm212 for everything, never the exact optimum.
        assert_eq!(out.initial_server, "vm212");
    }

    #[test]
    fn cfg_mapper_kind_selects_module() {
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 42);
        cfg.checkpoints_enabled = false;
        cfg.n_rounds = 2;
        cfg.mapper = MapperKind::Fastest;
        let out = Framework::default_stack().run(&cfg).unwrap();
        // fastest puts everyone on the lowest-slowdown type (vm126).
        assert_eq!(out.initial_server, "vm126");
        assert_eq!(out.initial_clients, vec!["vm126"; 4]);
    }

    #[test]
    fn framework_is_cloneable_and_shares_modules() {
        let cache = Arc::new(EnvCache::new());
        let fw = Framework::with_env_cache(cache.clone());
        let fw2 = fw.clone();
        let mut cfg = SimConfig::new(apps::til(), Scenario::AllOnDemand, 1);
        cfg.checkpoints_enabled = false;
        cfg.n_rounds = 1;
        fw.run(&cfg).unwrap();
        fw2.run(&cfg).unwrap();
        assert_eq!(cache.computations(), 1, "clones share one cache");
    }
}
