//! Deterministic VM-ranking helpers shared by the Initial Mapping solvers
//! and baselines and by the Dynamic Scheduler.
//!
//! Both modules rank candidate VMs by a floating-point key (price rate,
//! measured slowdown, or the weighted objective of Algorithm 3) and must do
//! so with identical tie-breaking so that results are reproducible across
//! module implementations: a *stable* sort keeps catalog order for equal
//! keys, and the argmin keeps the *first* minimal element in input order.
//! Before this module each caller hand-rolled its own `partial_cmp` dance;
//! now the comparator lives in one place.

use std::cmp::Ordering;

/// Total order on finite ranking keys. Panics on NaN — a NaN key means the
/// caller computed a slowdown/cost from corrupt inputs, which must never be
/// silently ordered.
pub fn cmp_f64(a: f64, b: f64) -> Ordering {
    a.partial_cmp(&b).expect("NaN ranking key")
}

/// Sort a slice of keys ascending (stable).
pub fn sort_f64(xs: &mut [f64]) {
    xs.sort_by(|a, b| cmp_f64(*a, *b));
}

/// Sort items ascending by an f64 key (stable: ties keep input order).
pub fn sort_by_key_f64<T>(items: &mut [T], mut key: impl FnMut(&T) -> f64) {
    items.sort_by(|a, b| cmp_f64(key(a), key(b)));
}

/// First minimal element in input order (ties keep the earliest), together
/// with its key. This is the selection rule of Algorithm 3: a later
/// candidate replaces the incumbent only when *strictly* better.
pub fn argmin_by_f64<T>(
    items: impl IntoIterator<Item = T>,
    mut key: impl FnMut(&T) -> f64,
) -> Option<(T, f64)> {
    let mut best: Option<(T, f64)> = None;
    for item in items {
        let k = key(&item);
        let better = best.as_ref().map_or(true, |(_, bk)| cmp_f64(k, *bk) == Ordering::Less);
        if better {
            best = Some((item, k));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sort_is_stable_on_ties() {
        let mut items = vec![("a", 2.0), ("b", 1.0), ("c", 1.0), ("d", 0.5)];
        sort_by_key_f64(&mut items, |x| x.1);
        let names: Vec<&str> = items.iter().map(|x| x.0).collect();
        assert_eq!(names, vec!["d", "b", "c", "a"]);
    }

    #[test]
    fn argmin_keeps_first_on_ties() {
        let xs = vec![("a", 3.0), ("b", 1.0), ("c", 1.0)];
        let (item, k) = argmin_by_f64(xs, |x| x.1).unwrap();
        assert_eq!(item.0, "b");
        assert_eq!(k, 1.0);
    }

    #[test]
    fn argmin_empty_is_none() {
        let xs: Vec<f64> = vec![];
        assert!(argmin_by_f64(xs, |&x| x).is_none());
    }

    #[test]
    fn sort_f64_handles_infinity() {
        let mut xs = vec![f64::INFINITY, 1.0, 0.0];
        sort_f64(&mut xs);
        assert_eq!(xs, vec![0.0, 1.0, f64::INFINITY]);
    }

    #[test]
    #[should_panic(expected = "NaN ranking key")]
    fn nan_keys_panic() {
        cmp_f64(f64::NAN, 1.0);
    }
}
