//! Baseline placement policies the paper's exact mapping is compared
//! against in our benches — random, cheapest-rate, fastest, and
//! single-cloud-restricted exact — all usable as drop-in `InitialMapper`
//! implementations via `crate::framework::modules`. VM ranking goes through
//! the shared [`super::rank`] helpers so ties break identically to the
//! Dynamic Scheduler's Algorithm 3.

use crate::cloud::quota::QuotaTracker;
use crate::cloud::{ProviderId, VmTypeId};
use crate::simul::Rng;

use super::problem::{Mapping, MappingProblem};
use super::rank;

/// Uniform-random feasible placement (quota-aware), or None after
/// `attempts` failed tries.
pub fn random(p: &MappingProblem, seed: u64, attempts: usize) -> Option<Mapping> {
    let vms: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    let mut rng = Rng::seeded(seed);
    for _ in 0..attempts {
        let server = vms[rng.next_below(vms.len() as u64) as usize];
        let clients: Vec<VmTypeId> = (0..p.job.n_clients())
            .map(|_| vms[rng.next_below(vms.len() as u64) as usize])
            .collect();
        let mapping = Mapping { server, clients, market: p.market };
        let ev = p.evaluate(&mapping);
        if ev.feasible {
            return Some(mapping);
        }
    }
    None
}

/// Everyone on the cheapest-rate VM type that still fits quota (a classic
/// cost-greedy baseline, oblivious to slowdowns).
pub fn cheapest(p: &MappingProblem) -> Option<Mapping> {
    let mut by_rate: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    rank::sort_by_key_f64(&mut by_rate, |&v| p.rate_per_sec(v));
    greedy_fill(p, &by_rate)
}

/// Everyone on the lowest-slowdown VM type (time-greedy, oblivious to cost).
pub fn fastest(p: &MappingProblem) -> Option<Mapping> {
    let mut by_speed: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    rank::sort_by_key_f64(&mut by_speed, |&v| p.slowdowns.sl_inst(v));
    greedy_fill(p, &by_speed)
}

fn greedy_fill(p: &MappingProblem, pref: &[VmTypeId]) -> Option<Mapping> {
    let mut quota = QuotaTracker::new();
    let server = *pref
        .iter()
        .find(|&&v| quota.allocate(p.catalog, v).is_ok())?;
    let mut clients = Vec::with_capacity(p.job.n_clients());
    for _ in 0..p.job.n_clients() {
        let vm = *pref
            .iter()
            .find(|&&v| quota.allocate(p.catalog, v).is_ok())?;
        clients.push(vm);
    }
    Some(Mapping { server, clients, market: p.market })
}

/// Exact solve restricted to one provider (the "don't go multi-cloud"
/// ablation). Returns the best single-provider mapping over all providers,
/// or the given provider's optimum when `provider` is Some.
pub fn single_cloud(p: &MappingProblem, provider: Option<ProviderId>) -> Option<Mapping> {
    let providers: Vec<ProviderId> = match provider {
        Some(pr) => vec![pr],
        None => p.catalog.provider_ids().collect(),
    };
    let mut best: Option<(Mapping, f64)> = None;
    for pr in providers {
        // Build a filtered catalog view by restricting the VM set via a
        // "forbidden" mask in an exact solve over the same problem: simplest
        // correct approach is to re-run the exact solver on a shrunk catalog.
        let mut cat = p.catalog.clone();
        cat.vm_types.retain(|v| cat.regions[v.region.0].provider == pr);
        if cat.vm_types.is_empty() {
            continue;
        }
        // Slowdown report indices refer to the original catalog, so remap by
        // building a sub-problem via VM id strings.
        let sub_sl = remap_slowdowns(p, &cat);
        let sub = MappingProblem {
            catalog: &cat,
            slowdowns: &sub_sl,
            job: p.job,
            alpha: p.alpha,
            market: p.market,
            spot_price_factor: p.spot_price_factor,
            budget_round: p.budget_round,
            deadline_round: p.deadline_round,
            outlook: p.outlook,
        };
        if let Some(sol) = super::exact::solve(&sub) {
            // Translate back to original ids.
            let server = p.catalog.vm_by_id(&cat.vm(sol.mapping.server).id).unwrap();
            let clients = sol
                .mapping
                .clients
                .iter()
                .map(|&v| p.catalog.vm_by_id(&cat.vm(v).id).unwrap())
                .collect();
            let mapping = Mapping { server, clients, market: p.market };
            let ev = p.evaluate(&mapping);
            if ev.feasible {
                let better = best.as_ref().map_or(true, |(_, o)| ev.objective < *o);
                if better {
                    best = Some((mapping, ev.objective));
                }
            }
        }
    }
    best.map(|(m, _)| m)
}

fn remap_slowdowns(p: &MappingProblem, sub: &crate::cloud::Catalog) -> crate::presched::SlowdownReport {
    use std::collections::BTreeMap;
    let mut exec_slowdown = BTreeMap::new();
    let mut dummy_runs = BTreeMap::new();
    for v in sub.vm_ids() {
        let orig = p.catalog.vm_by_id(&sub.vm(v).id).unwrap();
        exec_slowdown.insert(v, p.slowdowns.sl_inst(orig));
        dummy_runs.insert(v, p.slowdowns.dummy_runs[&orig]);
    }
    let mut comm_slowdown = BTreeMap::new();
    let mut comm_runs = BTreeMap::new();
    for a in sub.region_ids() {
        for b in sub.region_ids() {
            let oa = p.catalog.region_by_name(&sub.region(a).name).unwrap();
            let ob = p.catalog.region_by_name(&sub.region(b).name).unwrap();
            let key = if a <= b { (a, b) } else { (b, a) };
            comm_slowdown.insert(key, p.slowdowns.sl_comm(oa, ob));
            let okey = if oa <= ob { (oa, ob) } else { (ob, oa) };
            comm_runs.insert(key, p.slowdowns.comm_runs[&okey]);
        }
    }
    // Baseline anchors may live outside the sub-catalog; keep ratios as-is
    // (they are already normalized) and anchor to the first VM / pair.
    crate::presched::SlowdownReport {
        dummy_runs,
        comm_runs,
        exec_slowdown,
        comm_slowdown,
        baseline_vm: crate::cloud::VmTypeId(0),
        baseline_pair: (crate::cloud::RegionId(0), crate::cloud::RegionId(0)),
        fingerprint: crate::presched::fingerprint(sub),
    }
}

/// All baselines by name, for bench sweeps.
pub fn all(p: &MappingProblem) -> Vec<(&'static str, Option<Mapping>)> {
    vec![
        ("random", random(p, 2024, 200)),
        ("cheapest", cheapest(p)),
        ("fastest", fastest(p)),
        ("single-cloud", single_cloud(p, None)),
    ]
}

#[cfg(test)]
mod tests {
    use super::super::problem::testutil::*;
    use super::super::problem::MappingProblem;
    use super::*;
    use crate::cloud::Market;

    fn problem<'a>(
        mc: &'a crate::cloudsim::MultiCloud,
        sl: &'a crate::presched::SlowdownReport,
        job: &'a crate::mapping::problem::JobProfile,
    ) -> MappingProblem<'a> {
        MappingProblem {
            catalog: &mc.catalog,
            slowdowns: sl,
            job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        }
    }

    #[test]
    fn cheapest_picks_minimum_rate_vm() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = problem(&mc, &sl, &job);
        let m = cheapest(&p).unwrap();
        // vm212 (r320, $0.574/h) is the cheapest CloudLab VM.
        assert_eq!(mc.catalog.vm(m.server).id, "vm212");
    }

    #[test]
    fn fastest_picks_minimum_slowdown_vm() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = problem(&mc, &sl, &job);
        let m = fastest(&p).unwrap();
        for &c in &m.clients {
            assert_eq!(mc.catalog.vm(c).id, "vm126");
        }
    }

    #[test]
    fn random_is_feasible_and_deterministic() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = problem(&mc, &sl, &job);
        let a = random(&p, 99, 100).unwrap();
        let b = random(&p, 99, 100).unwrap();
        assert_eq!(a, b);
        assert!(p.evaluate(&a).feasible);
    }

    #[test]
    fn single_cloud_stays_in_one_provider() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = problem(&mc, &sl, &job);
        for pr in mc.catalog.provider_ids() {
            if let Some(m) = single_cloud(&p, Some(pr)) {
                let mut vms = m.clients.clone();
                vms.push(m.server);
                for v in vms {
                    assert_eq!(mc.catalog.provider_of(v), pr);
                }
            }
        }
    }

    #[test]
    fn single_cloud_never_beats_multi_cloud_exact() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = problem(&mc, &sl, &job);
        let multi = crate::mapping::exact::solve(&p).unwrap();
        let single = single_cloud(&p, None).unwrap();
        assert!(multi.eval.objective <= p.evaluate(&single).objective + 1e-9);
    }
}
