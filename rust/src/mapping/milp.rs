//! Generic MILP route for the Initial Mapping: builds the linearized
//! formulation of Eqs. 3–18 and solves it with the simplex + branch-and-bound
//! substrate in [`crate::solver`].
//!
//! Linearization of the two products in the paper's formulation:
//! * `x_iv · y_w` (Eq. 5 comm costs, Constraint 16) → `z_ivw ∈ [0,1]` with
//!   `z_ivw ≥ x_iv + y_w − 1` (the minimization objective and the big-M form
//!   of Constraint 16 keep `z` at its bound);
//! * `x_iv · t_m` (Eq. 4 VM costs) → `u_iv ≥ t_m − T_max (1 − x_iv)`,
//!   `u_iv ≥ 0` (and `w_v` likewise for the server term).
//! * Constraint 16 → `t_m ≥ time_ivw − M (2 − x_iv − y_w)` with
//!   `M = max time`.
//!
//! This is exponentially slower than [`super::exact`] (it exists as the
//! faithful transcription of the paper's formulation and as a cross-check);
//! use it on small catalogs.

use crate::cloud::VmTypeId;
use crate::solver::{Lp, Milp, Rel};

use super::problem::{Mapping, MappingProblem};

/// Variable layout for the linearized MILP.
struct Layout {
    n_clients: usize,
    n_vms: usize,
}

impl Layout {
    fn x(&self, i: usize, v: usize) -> usize {
        i * self.n_vms + v
    }
    fn y(&self, v: usize) -> usize {
        self.n_clients * self.n_vms + v
    }
    fn z(&self, i: usize, v: usize, w: usize) -> usize {
        self.n_clients * self.n_vms
            + self.n_vms
            + (i * self.n_vms + v) * self.n_vms
            + w
    }
    fn u(&self, i: usize, v: usize) -> usize {
        self.z(self.n_clients - 1, self.n_vms - 1, self.n_vms - 1) + 1 + i * self.n_vms + v
    }
    fn w(&self, v: usize) -> usize {
        self.u(self.n_clients - 1, self.n_vms - 1) + 1 + v
    }
    fn t_m(&self) -> usize {
        self.w(self.n_vms - 1) + 1
    }
    fn total(&self) -> usize {
        self.t_m() + 1
    }
}

/// Build and solve the linearized MILP; returns the mapping or None when
/// infeasible.
pub fn solve(p: &MappingProblem) -> Option<Mapping> {
    let vms: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    let lay = Layout { n_clients: p.job.n_clients(), n_vms: vms.len() };
    let t_max = p.t_max();
    let cost_max = p.cost_max();
    let mut lp = Lp::new(lay.total());

    // --- objective: α (Σ rate·u + Σ rate·w + Σ comm·z)/cost_max
    //              + (1-α) t_m / T_max ---
    for i in 0..lay.n_clients {
        for v in 0..lay.n_vms {
            let rate = p.rate_per_sec(vms[v]);
            lp.set_objective(lay.u(i, v), p.alpha * rate / cost_max);
            for w in 0..lay.n_vms {
                let comm = p.comm_cost(vms[v], vms[w]);
                lp.set_objective(lay.z(i, v, w), p.alpha * comm / cost_max);
            }
        }
    }
    for v in 0..lay.n_vms {
        let rate = p.rate_per_sec(vms[v]);
        lp.set_objective(lay.w(v), p.alpha * rate / cost_max);
    }
    lp.set_objective(lay.t_m(), (1.0 - p.alpha) / t_max);

    // --- Constraints 10, 11: one VM per task ---
    for i in 0..lay.n_clients {
        lp.add((0..lay.n_vms).map(|v| (lay.x(i, v), 1.0)).collect(), Rel::Eq, 1.0);
    }
    lp.add((0..lay.n_vms).map(|v| (lay.y(v), 1.0)).collect(), Rel::Eq, 1.0);

    // --- Constraints 12–15: GPU/vCPU quotas ---
    for prov in p.catalog.provider_ids() {
        let members: Vec<usize> = (0..lay.n_vms)
            .filter(|&v| p.catalog.provider_of(vms[v]) == prov)
            .collect();
        let spec = p.catalog.provider(prov);
        if let Some(max) = spec.max_gpus {
            let mut row = Vec::new();
            for &v in &members {
                let g = p.catalog.vm(vms[v]).gpus as f64;
                if g > 0.0 {
                    for i in 0..lay.n_clients {
                        row.push((lay.x(i, v), g));
                    }
                    row.push((lay.y(v), g));
                }
            }
            if !row.is_empty() {
                lp.add(row, Rel::Le, max as f64);
            }
        }
        if let Some(max) = spec.max_vcpus {
            let mut row = Vec::new();
            for &v in &members {
                let c = p.catalog.vm(vms[v]).vcpus as f64;
                for i in 0..lay.n_clients {
                    row.push((lay.x(i, v), c));
                }
                row.push((lay.y(v), c));
            }
            lp.add(row, Rel::Le, max as f64);
        }
    }
    for region in p.catalog.region_ids() {
        let members: Vec<usize> = (0..lay.n_vms)
            .filter(|&v| p.catalog.region_of(vms[v]) == region)
            .collect();
        let spec = p.catalog.region(region);
        if let Some(max) = spec.max_gpus {
            let mut row = Vec::new();
            for &v in &members {
                let g = p.catalog.vm(vms[v]).gpus as f64;
                if g > 0.0 {
                    for i in 0..lay.n_clients {
                        row.push((lay.x(i, v), g));
                    }
                    row.push((lay.y(v), g));
                }
            }
            if !row.is_empty() {
                lp.add(row, Rel::Le, max as f64);
            }
        }
        if let Some(max) = spec.max_vcpus {
            let mut row = Vec::new();
            for &v in &members {
                let c = p.catalog.vm(vms[v]).vcpus as f64;
                for i in 0..lay.n_clients {
                    row.push((lay.x(i, v), c));
                }
                row.push((lay.y(v), c));
            }
            lp.add(row, Rel::Le, max as f64);
        }
    }

    // --- linking: z ≥ x + y − 1 ---
    for i in 0..lay.n_clients {
        for v in 0..lay.n_vms {
            for w in 0..lay.n_vms {
                lp.add(
                    vec![(lay.z(i, v, w), 1.0), (lay.x(i, v), -1.0), (lay.y(w), -1.0)],
                    Rel::Ge,
                    -1.0,
                );
                lp.add_upper_bound(lay.z(i, v, w), 1.0);
            }
        }
    }

    // --- cost linearization: u_iv ≥ t_m − T_max(1 − x_iv) ---
    for i in 0..lay.n_clients {
        for v in 0..lay.n_vms {
            lp.add(
                vec![(lay.u(i, v), 1.0), (lay.t_m(), -1.0), (lay.x(i, v), -t_max)],
                Rel::Ge,
                -t_max,
            );
        }
    }
    for v in 0..lay.n_vms {
        lp.add(
            vec![(lay.w(v), 1.0), (lay.t_m(), -1.0), (lay.y(v), -t_max)],
            Rel::Ge,
            -t_max,
        );
    }

    // --- Constraint 16 (big-M): t_m ≥ time − M(2 − x − y) ---
    let big_m = t_max * 1.01;
    for i in 0..lay.n_clients {
        for v in 0..lay.n_vms {
            for w in 0..lay.n_vms {
                let time = p.client_round_time(i, vms[v], vms[w]);
                lp.add(
                    vec![
                        (lay.t_m(), 1.0),
                        (lay.x(i, v), -big_m),
                        (lay.y(w), -big_m),
                    ],
                    Rel::Ge,
                    time - 2.0 * big_m,
                );
            }
        }
    }

    // --- Constraints 8, 9: budget + deadline ---
    lp.add(vec![(lay.t_m(), 1.0)], Rel::Le, p.deadline_round);
    {
        // total_costs = Σ rate·u + Σ rate·w + Σ comm·z ≤ B_round
        let mut row = Vec::new();
        for i in 0..lay.n_clients {
            for v in 0..lay.n_vms {
                let rate = p.rate_per_sec(vms[v]);
                row.push((lay.u(i, v), rate));
                for w in 0..lay.n_vms {
                    row.push((lay.z(i, v, w), p.comm_cost(vms[v], vms[w])));
                }
            }
        }
        for v in 0..lay.n_vms {
            row.push((lay.w(v), p.rate_per_sec(vms[v])));
        }
        lp.add(row, Rel::Le, p.budget_round);
    }

    // Binaries: x and y (z/u/w/t_m are continuous, forced by constraints).
    let mut binaries = Vec::new();
    for i in 0..lay.n_clients {
        for v in 0..lay.n_vms {
            binaries.push(lay.x(i, v));
        }
    }
    for v in 0..lay.n_vms {
        binaries.push(lay.y(v));
    }

    let milp = Milp::new(lp, binaries);
    let sol = crate::solver::solve_milp(&milp)?;

    let server = (0..lay.n_vms).find(|&v| sol.x[lay.y(v)] > 0.5)?;
    let mut clients = Vec::new();
    for i in 0..lay.n_clients {
        let v = (0..lay.n_vms).find(|&v| sol.x[lay.x(i, v)] > 0.5)?;
        clients.push(vms[v]);
    }
    Some(Mapping { server: vms[server], clients, market: p.market })
}

#[cfg(test)]
mod tests {
    use super::super::problem::{JobProfile, MappingProblem, MessageSizes};
    use super::*;
    use crate::cloud::{tables, Catalog, Market};
    use crate::cloudsim::{MultiCloud, RevocationModel};
    use crate::presched::PreScheduler;

    /// A small catalog (4 VM types, 2 clients) keeps the generic MILP fast.
    fn small_env() -> (Catalog, crate::presched::SlowdownReport) {
        let mut cat = tables::cloudlab();
        let keep = ["vm121", "vm126", "vm211", "vm212"];
        cat.vm_types.retain(|v| keep.contains(&v.id.as_str()));
        let gt = tables::cloudlab_ground_truth();
        let mc = MultiCloud::new(cat.clone(), gt, RevocationModel::none(), 5);
        let sl = PreScheduler::new(&mc).measure_defaults();
        (cat, sl)
    }

    fn small_job(n_clients: usize) -> JobProfile {
        JobProfile {
            name: "mini".into(),
            client_train_bl: vec![1000.0; n_clients],
            client_test_bl: vec![50.0; n_clients],
            train_comm_bl: 5.61,
            test_comm_bl: 3.05,
            agg_bl: 2.0,
            msg: MessageSizes {
                s_train_gb: 0.5,
                s_aggreg_gb: 0.5,
                c_train_gb: 0.5,
                c_test_gb: 0.001,
            },
            n_rounds: 10,
        }
    }

    #[test]
    fn milp_matches_exact_solver_objective() {
        let (cat, sl) = small_env();
        let job = small_job(2);
        for alpha in [0.0, 0.5, 1.0] {
            let p = MappingProblem {
                catalog: &cat,
                slowdowns: &sl,
                job: &job,
                alpha,
                market: Market::OnDemand,
                spot_price_factor: 1.0,
                budget_round: 1e9,
                deadline_round: 1e9,
                outlook: None,
            };
            let exact = crate::mapping::exact::solve(&p).expect("exact feasible");
            let milp = solve(&p).expect("milp feasible");
            let em = p.evaluate(&milp);
            assert!(
                (exact.eval.objective - em.objective).abs() < 1e-6,
                "alpha={alpha}: exact obj {} vs milp obj {}",
                exact.eval.objective,
                em.objective
            );
        }
    }

    #[test]
    fn milp_respects_deadline() {
        let (cat, sl) = small_env();
        let job = small_job(2);
        let p = MappingProblem {
            catalog: &cat,
            slowdowns: &sl,
            job: &job,
            alpha: 1.0,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 100.0, // forces GPU VM despite pure-cost α
            outlook: None,
        };
        let got = solve(&p);
        match (got, crate::mapping::exact::solve(&p)) {
            (Some(m), Some(e)) => {
                let em = p.evaluate(&m);
                assert!(em.makespan <= 100.0 + 1e-6);
                assert!((em.objective - e.eval.objective).abs() < 1e-6);
            }
            (None, None) => {}
            (a, b) => panic!("feasibility disagreement: milp {:?} exact {:?}", a.is_some(), b.is_some()),
        }
    }

    #[test]
    fn milp_budget_deadline_boundary_matches_exact() {
        // Exact-equality boundary (the 1e-9 epsilon in problem.rs): a budget
        // or deadline equal to the attainable minimum stays feasible for
        // BOTH solver routes; just below it, both return None rather than a
        // constraint-violating mapping.
        let (cat, sl) = small_env();
        let job = small_job(2);
        let base = |alpha: f64| MappingProblem {
            catalog: &cat,
            slowdowns: &sl,
            job: &job,
            alpha,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let min_cost = crate::mapping::exact::solve(&base(1.0)).unwrap().eval.total_cost;
        let min_makespan = crate::mapping::exact::solve(&base(0.0)).unwrap().eval.makespan;

        let mut p = base(0.5);
        p.budget_round = min_cost;
        let m = solve(&p).expect("milp feasible at budget equality");
        assert!(p.evaluate(&m).total_cost <= min_cost + 1e-9);
        p.budget_round = min_cost - 1e-3;
        assert!(solve(&p).is_none(), "milp sub-minimum budget must be infeasible");
        assert!(crate::mapping::exact::solve(&p).is_none());

        let mut p = base(0.5);
        p.deadline_round = min_makespan;
        let m = solve(&p).expect("milp feasible at deadline equality");
        assert!(p.evaluate(&m).makespan <= min_makespan + 1e-9);
        p.deadline_round = min_makespan - 1e-3;
        assert!(solve(&p).is_none(), "milp sub-minimum deadline must be infeasible");
        assert!(crate::mapping::exact::solve(&p).is_none());
    }

    #[test]
    fn milp_infeasible_when_budget_zero() {
        let (cat, sl) = small_env();
        let job = small_job(2);
        let p = MappingProblem {
            catalog: &cat,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e-9,
            deadline_round: 1e9,
            outlook: None,
        };
        assert!(solve(&p).is_none());
        assert!(crate::mapping::exact::solve(&p).is_none());
    }

    #[test]
    fn milp_random_instances_match_exact() {
        // Property test: random small instances, generic MILP == exact.
        crate::util::testkit::forall(
            "milp vs exact on random instances",
            0xAB1E,
            8,
            |rng| {
                let (cat, sl) = small_env();
                let n_clients = 1 + rng.next_below(2) as usize;
                let mut job = small_job(n_clients);
                for i in 0..n_clients {
                    job.client_train_bl[i] = rng.uniform(100.0, 3000.0);
                    job.client_test_bl[i] = rng.uniform(5.0, 100.0);
                }
                let alpha = rng.uniform(0.0, 1.0);
                (cat, sl, job, alpha)
            },
            |(cat, sl, job, alpha)| {
                let p = MappingProblem {
                    catalog: cat,
                    slowdowns: sl,
                    job,
                    alpha: *alpha,
                    market: Market::OnDemand,
                    spot_price_factor: 1.0,
                    budget_round: 1e9,
                    deadline_round: 1e9,
                    outlook: None,
                };
                let exact = crate::mapping::exact::solve(&p);
                let milp = solve(&p);
                match (exact, milp) {
                    (Some(e), Some(m)) => {
                        let em = p.evaluate(&m);
                        if (e.eval.objective - em.objective).abs() < 1e-5 {
                            Ok(())
                        } else {
                            Err(format!(
                                "objective mismatch: exact {} milp {}",
                                e.eval.objective, em.objective
                            ))
                        }
                    }
                    (None, None) => Ok(()),
                    (e, m) => Err(format!(
                        "feasibility mismatch exact={} milp={}",
                        e.is_some(),
                        m.is_some()
                    )),
                }
            },
        );
    }
}
