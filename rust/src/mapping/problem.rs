//! The Initial Mapping problem (§4.2): data model and objective evaluation.
//!
//! Implements the quantities of Eqs. 1–7 of the paper: expected execution /
//! communication / aggregation times for any task placement, the cost model,
//! and the normalized weighted objective
//! `α · total_costs/cost_max + (1-α) · t_m/T_max` (Eq. 3).

use crate::cloud::quota::assignment_fits;
use crate::cloud::{Catalog, Market, ProviderId, VmTypeId};
use crate::outlook::MarketOutlook;
use crate::presched::SlowdownReport;

/// Message sizes of the FL job, in GB (Table 1's `size(...)` entries).
#[derive(Debug, Clone, Copy)]
pub struct MessageSizes {
    /// `s_msg_train`: server → client initial weights.
    pub s_train_gb: f64,
    /// `s_msg_aggreg`: server → client aggregated weights.
    pub s_aggreg_gb: f64,
    /// `c_msg_train`: client → server updated weights.
    pub c_train_gb: f64,
    /// `c_msg_test`: client → server evaluation metrics.
    pub c_test_gb: f64,
}

impl MessageSizes {
    /// Total GB exchanged per client per round.
    pub fn round_total_gb(&self) -> f64 {
        self.s_train_gb + self.s_aggreg_gb + self.c_train_gb + self.c_test_gb
    }
}

/// Job baselines produced by the Pre-Scheduling module for the concrete FL
/// application (§4.1): per-client times on the baseline VM and message times
/// on the baseline region pair.
#[derive(Debug, Clone)]
pub struct JobProfile {
    pub name: String,
    /// `train_bl_i` per client, seconds for one round on the baseline VM.
    pub client_train_bl: Vec<f64>,
    /// `test_bl_i` per client.
    pub client_test_bl: Vec<f64>,
    /// `train_comm_bl`: round-trip training-message time on baseline pair.
    pub train_comm_bl: f64,
    /// `test_comm_bl`.
    pub test_comm_bl: f64,
    /// Server aggregation baseline time per round on the baseline VM.
    pub agg_bl: f64,
    pub msg: MessageSizes,
    pub n_rounds: u32,
}

impl JobProfile {
    pub fn n_clients(&self) -> usize {
        self.client_train_bl.len()
    }
}

/// A placement of the FL job: `y` (server VM type) and `x` (client VM types).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    pub server: VmTypeId,
    pub clients: Vec<VmTypeId>,
    pub market: Market,
}

/// Per-round evaluation of a mapping under the paper's cost/makespan model.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// `t_m`: round makespan, seconds (Constraint 16 binding client).
    pub makespan: f64,
    /// Eq. 4 for one round.
    pub vm_cost: f64,
    /// Eq. 5 for one round.
    pub comm_cost: f64,
    pub total_cost: f64,
    /// Eq. 3 value (normalized).
    pub objective: f64,
    /// Budget/deadline/quota feasibility.
    pub feasible: bool,
}

/// The full problem instance handed to the solvers.
#[derive(Clone, Copy)]
pub struct MappingProblem<'a> {
    pub catalog: &'a Catalog,
    pub slowdowns: &'a SlowdownReport,
    pub job: &'a JobProfile,
    /// User weight α ∈ [0,1] between cost (α) and makespan (1-α).
    pub alpha: f64,
    pub market: Market,
    /// Expected spot-price multiplier over the planning horizon (the
    /// market's [`crate::market::PriceSeries`] time-averaged; 1.0 = the
    /// catalog's fixed rate). Scales every spot VM rate the cost models see;
    /// on-demand planning is unaffected.
    pub spot_price_factor: f64,
    /// `B_round`: budget for a single round, $.
    pub budget_round: f64,
    /// `T_round`: deadline for a single round, seconds.
    pub deadline_round: f64,
    /// Market forecast for outlook-aware planning (`None` = the flat
    /// expected-factor path, bit-identical to the historical planner).
    /// Enables [`Self::windowed`] re-pricing and [`Self::defer_secs`].
    pub outlook: Option<&'a MarketOutlook>,
}

impl<'a> MappingProblem<'a> {
    /// `cost_jkl` in $ per second as the planner sees it: the catalog rate
    /// for `self.market`, scaled by the expected spot-price multiplier when
    /// planning a spot placement. The factor-1.0 branch returns the catalog
    /// rate untouched, keeping the default market bit-identical to the
    /// historical arithmetic.
    pub fn rate_per_sec(&self, vm: VmTypeId) -> f64 {
        self.rate_for(vm, self.market)
    }

    /// [`Self::rate_per_sec`] for an explicit market (placements carry their
    /// own market tag).
    pub fn rate_for(&self, vm: VmTypeId, market: Market) -> f64 {
        let base = self.catalog.vm(vm).cost_per_sec(market);
        // Epsilon comparison (repo-wide 1e-9 convention): exactly-1.0
        // factors take the untouched-rate branch, so the default market
        // stays bit-identical to the historical arithmetic.
        if market == Market::Spot && (self.spot_price_factor - 1.0).abs() > 1e-9 {
            base * self.spot_price_factor
        } else {
            base
        }
    }

    /// The most expensive planner-visible rate (the Eq. 7 normalization
    /// bound under the expected spot price).
    pub fn max_rate_per_sec(&self) -> f64 {
        let base = self.catalog.max_cost_per_sec(self.market);
        if self.market == Market::Spot && (self.spot_price_factor - 1.0).abs() > 1e-9 {
            base * self.spot_price_factor
        } else {
            base
        }
    }

    /// Eq. 2: `t_exec_ijkl` — computation time of client `i` on VM `vm`.
    pub fn t_exec(&self, client: usize, vm: VmTypeId) -> f64 {
        (self.job.client_train_bl[client] + self.job.client_test_bl[client])
            * self.slowdowns.sl_inst(vm)
    }

    /// Eq. 1: `t_comm_jklm` — message time between the regions of two VMs.
    pub fn t_comm(&self, a: VmTypeId, b: VmTypeId) -> f64 {
        let ra = self.catalog.region_of(a);
        let rb = self.catalog.region_of(b);
        (self.job.train_comm_bl + self.job.test_comm_bl) * self.slowdowns.sl_comm(ra, rb)
    }

    /// `t_aggreg_jkl` — server aggregation time on VM `vm`.
    pub fn t_aggreg(&self, vm: VmTypeId) -> f64 {
        self.job.agg_bl * self.slowdowns.sl_inst(vm)
    }

    /// Per-client round completion time against a given server placement
    /// (the inner expression of Constraint 16).
    pub fn client_round_time(&self, client: usize, client_vm: VmTypeId, server_vm: VmTypeId) -> f64 {
        self.t_exec(client, client_vm) + self.t_comm(client_vm, server_vm) + self.t_aggreg(server_vm)
    }

    /// Eq. 6: `comm_jm` — $ cost of one round of messages between a client in
    /// provider `j` and the server in provider `m`.
    pub fn comm_cost_between(&self, client_provider: ProviderId, server_provider: ProviderId) -> f64 {
        let m = &self.job.msg;
        let cost_t_m = self.catalog.provider(server_provider).egress_cost_per_gb;
        let cost_t_j = self.catalog.provider(client_provider).egress_cost_per_gb;
        (m.s_train_gb + m.s_aggreg_gb) * cost_t_m + (m.c_train_gb + m.c_test_gb) * cost_t_j
    }

    /// Eq. 6 applied to VM placements.
    pub fn comm_cost(&self, client_vm: VmTypeId, server_vm: VmTypeId) -> f64 {
        self.comm_cost_between(self.catalog.provider_of(client_vm), self.catalog.provider_of(server_vm))
    }

    /// `T_max`: maximum possible round makespan over all clients and VMs.
    pub fn t_max(&self) -> f64 {
        let worst_exec = (0..self.job.n_clients())
            .map(|i| {
                self.catalog
                    .vm_ids()
                    .map(|v| self.t_exec(i, v))
                    .fold(0.0, f64::max)
            })
            .fold(0.0, f64::max);
        let worst_comm = self
            .catalog
            .vm_ids()
            .flat_map(|a| self.catalog.vm_ids().map(move |b| (a, b)))
            .map(|(a, b)| self.t_comm(a, b))
            .fold(0.0, f64::max);
        let worst_agg = self
            .catalog
            .vm_ids()
            .map(|v| self.t_aggreg(v))
            .fold(0.0, f64::max);
        worst_exec + worst_comm + worst_agg
    }

    /// Eq. 7: `cost_max` — normalization bound for the cost objective.
    pub fn cost_max(&self) -> f64 {
        let n_tasks = self.job.n_clients() as f64 + 1.0;
        let max_rate = self.max_rate_per_sec();
        let max_comm = self
            .catalog
            .provider_ids()
            .flat_map(|j| self.catalog.provider_ids().map(move |m| (j, m)))
            .map(|(j, m)| self.comm_cost_between(j, m))
            .fold(0.0, f64::max);
        max_rate * self.t_max() * n_tasks + max_comm * self.job.n_clients() as f64
    }

    /// Evaluate a complete mapping for one round (Eqs. 3–7 + feasibility).
    pub fn evaluate(&self, mapping: &Mapping) -> Evaluation {
        assert_eq!(mapping.clients.len(), self.job.n_clients());
        let makespan = mapping
            .clients
            .iter()
            .enumerate()
            .map(|(i, &vm)| self.client_round_time(i, vm, mapping.server))
            .fold(0.0, f64::max);
        let rate_sum: f64 = mapping
            .clients
            .iter()
            .map(|&vm| self.rate_for(vm, mapping.market))
            .sum::<f64>()
            + self.rate_for(mapping.server, mapping.market);
        let vm_cost = rate_sum * makespan;
        let comm_cost: f64 = mapping
            .clients
            .iter()
            .map(|&vm| self.comm_cost(vm, mapping.server))
            .sum();
        let total_cost = vm_cost + comm_cost;
        let objective = self.alpha * total_cost / self.cost_max()
            + (1.0 - self.alpha) * makespan / self.t_max();
        let mut vms = mapping.clients.clone();
        vms.push(mapping.server);
        let feasible = total_cost <= self.budget_round + 1e-9
            && makespan <= self.deadline_round + 1e-9
            && assignment_fits(self.catalog, &vms).is_ok();
        Evaluation { makespan, vm_cost, comm_cost, total_cost, objective, feasible }
    }

    /// Objective value for externally computed (cost, makespan), used by the
    /// Dynamic Scheduler's greedy heuristic (Algorithm 3's `value`).
    pub fn objective_value(&self, total_cost: f64, makespan: f64) -> f64 {
        self.alpha * total_cost / self.cost_max() + (1.0 - self.alpha) * makespan / self.t_max()
    }

    /// The same problem re-priced for the concrete window `[t, t+h)`: the
    /// flat horizon-wide `spot_price_factor` is replaced by the outlook's
    /// exact integral over the window, so costs reflect what this window
    /// actually pays. Without an outlook (or on a constant-price market,
    /// where the windowed factor is exactly 1.0 and `rate_for` takes the
    /// untouched-rate branch) the returned problem prices identically to
    /// `self` — the outlook-off parity anchor for the Dynamic Scheduler's
    /// remaining-horizon candidate pricing.
    pub fn windowed(&self, t: f64, h: f64) -> MappingProblem<'a> {
        match self.outlook {
            Some(o) => {
                MappingProblem { spot_price_factor: o.expected_price_factor(t, h), ..*self }
            }
            None => *self,
        }
    }

    /// How long provisioning should be deferred (from the job-local t = 0)
    /// to dodge an upcoming price spike: 0.0 — start now — unless this is a
    /// spot planning problem with a `defer = true` outlook and waiting for a
    /// later price step is strictly cheaper over the whole run. The delay is
    /// capped by the outlook horizon and by the deadline slack
    /// `(T_round − t_m) · n_rounds`, so an admitted deferral can never push
    /// any round past its deadline.
    pub fn defer_secs(&self, round_makespan: f64) -> f64 {
        let Some(o) = self.outlook else { return 0.0 };
        if self.market != Market::Spot || !o.defers() || !(round_makespan > 0.0) {
            return 0.0;
        }
        let n_rounds = self.job.n_rounds as f64;
        let slack = if self.deadline_round.is_finite() {
            ((self.deadline_round - round_makespan) * n_rounds).max(0.0)
        } else {
            f64::INFINITY
        };
        o.best_start_offset(round_makespan * n_rounds, o.horizon_secs().min(slack))
    }
}

#[cfg(test)]
pub mod testutil {
    //! Shared fixtures for mapping tests.
    use super::*;
    use crate::cloud::tables;
    use crate::cloudsim::{MultiCloud, RevocationModel};
    use crate::presched::PreScheduler;

    /// TIL application profile (§5.1, §5.4): 4 clients, baseline round time
    /// 2765.4 s, comm baseline 8.66 s, 504 MB model checkpoint.
    pub fn til_profile() -> JobProfile {
        crate::apps::til().profile()
    }

    pub fn cloudlab_sim() -> MultiCloud {
        MultiCloud::new(
            tables::cloudlab(),
            tables::cloudlab_ground_truth(),
            RevocationModel::none(),
            11,
        )
    }

    pub fn slowdowns(mc: &MultiCloud) -> SlowdownReport {
        PreScheduler::new(mc).measure_defaults()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;
    use crate::cloud::tables;

    #[test]
    fn til_exec_time_on_gpu_vm_matches_section_5_4() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        // 2765.4 × 0.045 ≈ 124 s per round.
        let t = p.t_exec(0, vm126);
        assert!((t - 2765.4 * sl.sl_inst(vm126)).abs() < 1e-9);
        assert!(t > 100.0 && t < 140.0, "t={t}");
    }

    #[test]
    fn client_round_time_includes_all_terms() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let total = p.client_round_time(0, vm126, vm121);
        let parts = p.t_exec(0, vm126) + p.t_comm(vm126, vm121) + p.t_aggreg(vm121);
        assert!((total - parts).abs() < 1e-9);
        assert!(total > p.t_exec(0, vm126));
    }

    #[test]
    fn evaluation_cost_model_eq4_eq5() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let mapping = Mapping {
            server: vm121,
            clients: vec![vm126; 4],
            market: Market::OnDemand,
        };
        let ev = p.evaluate(&mapping);
        // vm_cost = (4×vm126 + vm121 rates) × makespan.
        let rate = (4.0 * 4.693 + 1.670) / 3600.0;
        assert!((ev.vm_cost - rate * ev.makespan).abs() < 1e-9);
        // comm cost: 4 clients × Eq. 6 (same egress price both ways here).
        let per_client = job.msg.round_total_gb() * tables::EGRESS_CLOUDLAB;
        assert!((ev.comm_cost - 4.0 * per_client).abs() < 1e-9);
        assert!(ev.feasible);
    }

    #[test]
    fn objective_normalized_between_zero_and_one() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        // Any mapping's objective is within [0, 1] by the Eq. 7 bounds.
        for server in mc.catalog.vm_ids() {
            let mapping = Mapping {
                server,
                clients: vec![server; 4],
                market: Market::OnDemand,
            };
            let ev = p.evaluate(&mapping);
            assert!(
                ev.objective >= 0.0 && ev.objective <= 1.0 + 1e-9,
                "objective {} out of range for {:?}",
                ev.objective,
                mc.catalog.vm(server).id
            );
        }
    }

    #[test]
    fn budget_and_deadline_infeasibility() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 0.01, // absurdly small
            deadline_round: 1e9,
            outlook: None,
        };
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let mapping = Mapping { server: vm126, clients: vec![vm126; 4], market: Market::OnDemand };
        assert!(!p.evaluate(&mapping).feasible);
    }

    #[test]
    fn feasibility_epsilon_boundary_at_exact_equality() {
        // The 1e-9 slack in `evaluate` exists so a budget/deadline equal to
        // a mapping's own cost/makespan (a natural way to pin "this exact
        // placement") is not rejected by floating-point noise. Exactly-equal
        // bounds are feasible; bounds below by more than the epsilon are not.
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let mapping = Mapping { server: vm126, clients: vec![vm126; 4], market: Market::OnDemand };
        let free = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let ev = free.evaluate(&mapping);
        assert!(ev.feasible);

        let pinned = MappingProblem {
            spot_price_factor: 1.0,
            budget_round: ev.total_cost,   // exact equality
            deadline_round: ev.makespan,   // exact equality
            ..free
        };
        assert!(pinned.evaluate(&mapping).feasible, "equality must stay feasible");

        let below_budget = MappingProblem { budget_round: ev.total_cost - 1e-6, ..pinned };
        assert!(!below_budget.evaluate(&mapping).feasible);
        let below_deadline = MappingProblem {
            spot_price_factor: 1.0,
            budget_round: ev.total_cost,
            deadline_round: ev.makespan - 1e-6,
            ..below_budget
        };
        assert!(!below_deadline.evaluate(&mapping).feasible);
    }

    #[test]
    fn alpha_extremes_reorder_solutions() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap(); // fast, expensive
        let vm114 = mc.catalog.vm_by_id("vm114").unwrap(); // slow, cheap
        let mk = |alpha: f64| MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let fast = Mapping { server: vm126, clients: vec![vm126; 4], market: Market::OnDemand };
        let cheap = Mapping { server: vm114, clients: vec![vm114; 4], market: Market::OnDemand };
        // α=0 reduces to makespan ordering; α=1 reduces to total-cost
        // ordering. (Note: under the paper's Eq. 4 cost model, VM cost is
        // rate × makespan, so a fast-expensive VM can be *cheaper* per round
        // than a slow-cheap one — the orderings are asserted against the
        // model, not assumed.)
        let p0 = mk(0.0);
        let (f0, c0) = (p0.evaluate(&fast), p0.evaluate(&cheap));
        assert_eq!(f0.objective < c0.objective, f0.makespan < c0.makespan);
        assert!(f0.makespan < c0.makespan);
        let p1 = mk(1.0);
        let (f1, c1) = (p1.evaluate(&fast), p1.evaluate(&cheap));
        assert_eq!(f1.objective < c1.objective, f1.total_cost < c1.total_cost);
    }

    #[test]
    fn spot_market_scales_cost_not_time() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p_od = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let p_spot = MappingProblem { market: Market::Spot, ..p_od };
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let m_od = Mapping { server: vm126, clients: vec![vm126; 4], market: Market::OnDemand };
        let m_spot = Mapping { server: vm126, clients: vec![vm126; 4], market: Market::Spot };
        let e_od = p_od.evaluate(&m_od);
        let e_spot = p_spot.evaluate(&m_spot);
        assert!((e_od.makespan - e_spot.makespan).abs() < 1e-9);
        assert!((e_spot.vm_cost / e_od.vm_cost - 0.3).abs() < 0.01);
    }
}
