//! Exact specialized solver for the Initial Mapping MILP.
//!
//! The formulation (Eqs. 3–18) couples the binary placement variables through
//! two products: `x·y` (client/server co-placement in Constraint 16 and the
//! comm costs) and `x·t_m` (VM cost accrues for the whole makespan). Instead
//! of linearizing, we exploit the problem structure:
//!
//! 1. enumerate the server VM type `y` (|V| choices);
//! 2. for a fixed server, each client's round time on each VM is a constant,
//!    so the optimal `t_m` is one of the |C|·|V| distinct candidate times;
//! 3. for a fixed (server, t_m) pair, the objective decomposes per client
//!    into `rate_v · t_m + comm_cost(v, server)` over VMs with round time
//!    ≤ t_m — a min-cost choice per client coupled only by the GPU/vCPU
//!    quota constraints (12–15), solved by a small branch-and-bound with a
//!    per-client-minimum lower bound.
//!
//! This is exact and fast (the paper's instances have ≤ 13 VM types and ≤ 8
//! clients); the generic simplex+B&B route in [`super::milp`] cross-checks
//! it on small instances.

use crate::cloud::quota::QuotaTracker;
use crate::cloud::VmTypeId;

use super::problem::{Evaluation, Mapping, MappingProblem};
use super::rank;

/// Result of the Initial Mapping: the chosen placement and its evaluation.
#[derive(Debug, Clone)]
pub struct MappingSolution {
    pub mapping: Mapping,
    pub eval: Evaluation,
    /// Nodes explored by the inner quota B&B (for benchmarking).
    pub nodes: usize,
    /// Provisioning deferral advised by the market outlook
    /// ([`MappingProblem::defer_secs`]): delay the job start by this many
    /// seconds to dodge an upcoming price spike. 0.0 — the only value
    /// without a `defer = true` outlook — means start immediately;
    /// `framework::exec` honors a positive value as a delayed-start event.
    pub defer_secs: f64,
}

/// Solve the Initial Mapping exactly. Returns None when no placement meets
/// the budget/deadline/quota constraints.
pub fn solve(p: &MappingProblem) -> Option<MappingSolution> {
    let vms: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    let n_clients = p.job.n_clients();
    let t_max = p.t_max();
    let cost_max = p.cost_max();
    let mut best: Option<MappingSolution> = None;
    let mut nodes_total = 0usize;

    for &server in &vms {
        // Server must fit quota alone.
        let mut base_quota = QuotaTracker::new();
        if base_quota.allocate(p.catalog, server).is_err() {
            continue;
        }
        let t_agg = p.t_aggreg(server);
        // Per client per VM: (round time, cost slope, comm cost).
        let mut time = vec![vec![0.0; vms.len()]; n_clients];
        let mut ccost = vec![vec![0.0; vms.len()]; n_clients];
        for i in 0..n_clients {
            for (vi, &v) in vms.iter().enumerate() {
                time[i][vi] = p.t_exec(i, v) + p.t_comm(v, server) + t_agg;
                ccost[i][vi] = p.comm_cost(v, server);
            }
        }
        // Candidate makespans: all distinct client round times ≤ deadline.
        let mut candidates: Vec<f64> = time
            .iter()
            .flatten()
            .copied()
            .filter(|&t| t <= p.deadline_round + 1e-9)
            .collect();
        rank::sort_f64(&mut candidates);
        candidates.dedup_by(|a, b| (*a - *b).abs() < 1e-9);

        let server_rate = p.rate_per_sec(server);
        for &t_m in &candidates {
            // Feasible VM set + per-client cost under this t_m.
            // cost_i(v) = rate_v * t_m + comm_cost(v, server)
            let mut options: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n_clients);
            let mut ok = true;
            for i in 0..n_clients {
                let mut opts: Vec<(usize, f64)> = (0..vms.len())
                    .filter(|&vi| time[i][vi] <= t_m + 1e-9)
                    .map(|vi| {
                        let rate = p.rate_per_sec(vms[vi]);
                        (vi, rate * t_m + ccost[i][vi])
                    })
                    .collect();
                if opts.is_empty() {
                    ok = false;
                    break;
                }
                rank::sort_by_key_f64(&mut opts, |o| o.1);
                options.push(opts);
            }
            if !ok {
                continue;
            }
            // Quick lower bound on total cost: server + per-client minima.
            let lb_clients: f64 = options.iter().map(|o| o[0].1).sum();
            let lb_cost = server_rate * t_m + lb_clients;
            if lb_cost > p.budget_round + 1e-9 {
                // cost only grows with t_m for the same option sets; but
                // option sets also widen — cannot break, just skip.
                continue;
            }
            let lb_objective = p.alpha * lb_cost / cost_max + (1.0 - p.alpha) * t_m / t_max;
            if let Some(b) = &best {
                if lb_objective >= b.eval.objective - 1e-12 {
                    continue;
                }
            }
            // Min-cost client assignment under quotas (B&B).
            let budget_clients = p.budget_round - server_rate * t_m;
            let (assignment, nodes) =
                min_cost_assignment(p, &vms, &options, base_quota.clone(), budget_clients);
            nodes_total += nodes;
            let Some((chosen, _cost)) = assignment else { continue };
            let mapping = Mapping {
                server,
                clients: chosen.iter().map(|&vi| vms[vi]).collect(),
                market: p.market,
            };
            let eval = p.evaluate(&mapping);
            if !eval.feasible {
                continue;
            }
            let better = match &best {
                None => true,
                Some(b) => eval.objective < b.eval.objective - 1e-12,
            };
            if better {
                let defer_secs = p.defer_secs(eval.makespan);
                best = Some(MappingSolution { mapping, eval, nodes: nodes_total, defer_secs });
            }
        }
    }
    if let Some(b) = &mut best {
        b.nodes = nodes_total;
    }
    best
}

/// Branch-and-bound: assign each client one of its (sorted-by-cost) options,
/// respecting quotas, minimizing total cost, under a budget cutoff.
fn min_cost_assignment(
    p: &MappingProblem,
    vms: &[VmTypeId],
    options: &[Vec<(usize, f64)>],
    quota: QuotaTracker,
    budget: f64,
) -> (Option<(Vec<usize>, f64)>, usize) {
    // Suffix minima for the lower bound.
    let n = options.len();
    let mut suffix_min = vec![0.0; n + 1];
    for i in (0..n).rev() {
        suffix_min[i] = suffix_min[i + 1] + options[i][0].1;
    }
    let mut best: Option<(Vec<usize>, f64)> = None;
    let mut nodes = 0usize;
    let mut chosen = vec![usize::MAX; n];

    fn rec(
        p: &MappingProblem,
        vms: &[VmTypeId],
        options: &[Vec<(usize, f64)>],
        suffix_min: &[f64],
        budget: f64,
        i: usize,
        cost_so_far: f64,
        quota: &mut QuotaTracker,
        chosen: &mut Vec<usize>,
        best: &mut Option<(Vec<usize>, f64)>,
        nodes: &mut usize,
    ) {
        *nodes += 1;
        if cost_so_far + suffix_min[i] > budget + 1e-9 {
            return;
        }
        if let Some((_, bc)) = best {
            if cost_so_far + suffix_min[i] >= *bc - 1e-12 {
                return;
            }
        }
        if i == options.len() {
            *best = Some((chosen.clone(), cost_so_far));
            return;
        }
        for &(vi, c) in &options[i] {
            if quota.allocate(p.catalog, vms[vi]).is_err() {
                continue;
            }
            chosen[i] = vi;
            rec(p, vms, options, suffix_min, budget, i + 1, cost_so_far + c, quota, chosen, best, nodes);
            chosen[i] = usize::MAX;
            quota.release(p.catalog, vms[vi]);
        }
    }

    let mut quota = quota;
    rec(
        p,
        vms,
        options,
        &suffix_min,
        budget,
        0,
        0.0,
        &mut quota,
        &mut chosen,
        &mut best,
        &mut nodes,
    );
    (best, nodes)
}

#[cfg(test)]
mod tests {
    use super::super::problem::testutil::*;
    use super::super::problem::MappingProblem;
    use super::*;
    use crate::cloud::{tables, Market};
    use crate::cloudsim::{MultiCloud, RevocationModel};
    use crate::presched::PreScheduler;

    fn til_problem<'a>(
        mc: &'a MultiCloud,
        sl: &'a crate::presched::SlowdownReport,
        job: &'a crate::mapping::problem::JobProfile,
        alpha: f64,
    ) -> MappingProblem<'a> {
        MappingProblem {
            catalog: &mc.catalog,
            slowdowns: sl,
            job,
            alpha,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        }
    }

    #[test]
    fn til_optimal_matches_section_5_4() {
        // §5.4: "the optimized configuration to run the TIL application in
        // CloudLab is composed of a VM vm121 for the server and four VMs
        // vm126 for clients" — under the paper's balanced α.
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = til_problem(&mc, &sl, &job, 0.5);
        let sol = solve(&p).expect("feasible");
        let cat = &mc.catalog;
        // vm124 (c240g1) has the same price as the paper's vm121 (c220g1)
        // and a marginally lower measured slowdown (0.970 vs 1.000), so our
        // exact solver may pick either; both are Wisconsin 32-vCPU $1.670/h.
        let server_id = cat.vm(sol.mapping.server).id.clone();
        assert!(server_id == "vm121" || server_id == "vm124", "server={server_id}");
        for &c in &sol.mapping.clients {
            assert_eq!(cat.vm(c).id, "vm126");
        }
        // Predicted per-round makespan ≈ 135.8 s → ×10 rounds ≈ 22:38.
        let per_round = sol.eval.makespan;
        let ten_rounds = per_round * 10.0;
        assert!(
            (ten_rounds - (22.0 * 60.0 + 38.0)).abs() < 60.0,
            "10-round prediction {ten_rounds:.1}s vs paper 1358s"
        );
    }

    #[test]
    fn pure_makespan_alpha_picks_fastest() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = til_problem(&mc, &sl, &job, 0.0);
        let sol = solve(&p).unwrap();
        // All clients on the fastest VM (vm126, slowdown 0.045).
        for &c in &sol.mapping.clients {
            assert_eq!(mc.catalog.vm(c).id, "vm126");
        }
    }

    #[test]
    fn pure_cost_alpha_picks_cheap() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = til_problem(&mc, &sl, &job, 1.0);
        let sol = solve(&p).unwrap();
        // The cost-only optimum must not be more expensive than the
        // balanced optimum.
        let p_bal = til_problem(&mc, &sl, &job, 0.5);
        let bal = solve(&p_bal).unwrap();
        assert!(sol.eval.total_cost <= bal.eval.total_cost + 1e-9);
    }

    #[test]
    fn deadline_constraint_respected() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let mut p = til_problem(&mc, &sl, &job, 1.0);
        // Tight per-round deadline forces fast VMs despite α=1 (cost-only).
        p.deadline_round = 200.0;
        let sol = solve(&p).unwrap();
        assert!(sol.eval.makespan <= 200.0 + 1e-6);
        // And an impossible deadline yields None.
        p.deadline_round = 1.0;
        assert!(solve(&p).is_none());
    }

    #[test]
    fn budget_constraint_respected() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let mut p = til_problem(&mc, &sl, &job, 0.0);
        p.budget_round = 0.5; // $0.5 per round
        if let Some(sol) = solve(&p) {
            assert!(sol.eval.total_cost <= 0.5 + 1e-9);
        }
        p.budget_round = 1e-6;
        assert!(solve(&p).is_none());
    }

    #[test]
    fn budget_at_exact_equality_is_feasible() {
        // Boundary case for the 1e-9 feasibility epsilon (problem.rs): a
        // budget equal to the cheapest attainable per-round cost must still
        // admit a mapping, while a budget just below it (beyond the epsilon)
        // must yield None — not a constraint-violating mapping.
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        // α=1 minimizes cost, so its optimum is the cheapest possible cost.
        let p_cost = til_problem(&mc, &sl, &job, 1.0);
        let min_cost = solve(&p_cost).expect("unconstrained feasible").eval.total_cost;
        for alpha in [0.0, 0.5, 1.0] {
            let mut p = til_problem(&mc, &sl, &job, alpha);
            p.budget_round = min_cost; // exact equality
            let sol = solve(&p).expect("budget at equality must stay feasible");
            assert!(sol.eval.total_cost <= min_cost + 1e-9);
            p.budget_round = min_cost - 1e-3; // strictly below every mapping
            assert!(solve(&p).is_none(), "alpha={alpha}: sub-minimum budget must be infeasible");
        }
    }

    #[test]
    fn deadline_at_exact_equality_is_feasible() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        // α=0 minimizes makespan, so its optimum is the fastest possible round.
        let p_time = til_problem(&mc, &sl, &job, 0.0);
        let min_makespan = solve(&p_time).expect("unconstrained feasible").eval.makespan;
        for alpha in [0.0, 0.5, 1.0] {
            let mut p = til_problem(&mc, &sl, &job, alpha);
            p.deadline_round = min_makespan; // exact equality
            let sol = solve(&p).expect("deadline at equality must stay feasible");
            assert!(sol.eval.makespan <= min_makespan + 1e-9);
            p.deadline_round = min_makespan - 1e-3;
            assert!(
                solve(&p).is_none(),
                "alpha={alpha}: sub-minimum deadline must be infeasible"
            );
        }
    }

    #[test]
    fn quota_limits_gpu_client_count() {
        // AWS/GCP: 4 GPUs per provider. 5 T4-hungry clients cannot all sit
        // in AWS; the solver must spill or use CPU VMs, never violate quota.
        let mc = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            3,
        );
        let sl = PreScheduler::new(&mc).measure_defaults();
        let mut app = crate::apps::til_aws_gcp();
        app.train_samples = vec![948; 5];
        app.test_samples = vec![522; 5];
        let job = app.profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.0,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let sol = solve(&p).expect("feasible");
        let mut vms = sol.mapping.clients.clone();
        vms.push(sol.mapping.server);
        assert!(crate::cloud::quota::assignment_fits(&mc.catalog, &vms).is_ok());
        // Per provider ≤ 4 GPUs.
        for prov in mc.catalog.provider_ids() {
            let gpus: u32 = vms
                .iter()
                .filter(|&&v| mc.catalog.provider_of(v) == prov)
                .map(|&v| mc.catalog.vm(v).gpus)
                .sum();
            assert!(gpus <= 4, "provider {:?} has {gpus} GPUs", prov);
        }
    }

    #[test]
    fn aws_gcp_poc_selects_all_aws_like_paper() {
        // §5.7: "Our Initial Mapping module computed the optimal setup as all
        // tasks running in AWS, with the server in VM vm313 and the clients
        // in VMs vm311."
        let mc = MultiCloud::new(
            tables::aws_gcp(),
            tables::aws_gcp_ground_truth(),
            RevocationModel::none(),
            3,
        );
        let sl = PreScheduler::new(&mc).measure_defaults();
        let job = crate::apps::til_aws_gcp().profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::OnDemand,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let sol = solve(&p).expect("feasible");
        assert_eq!(mc.catalog.vm(sol.mapping.server).id, "vm313");
        for &c in &sol.mapping.clients {
            assert_eq!(mc.catalog.vm(c).id, "vm311");
        }
    }

    #[test]
    fn exact_beats_or_ties_every_greedy_baseline() {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        for alpha in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let p = til_problem(&mc, &sl, &job, alpha);
            let sol = solve(&p).unwrap();
            for b in crate::mapping::baselines::all(&p) {
                if let Some(bm) = b.1 {
                    let be = p.evaluate(&bm);
                    if be.feasible {
                        assert!(
                            sol.eval.objective <= be.objective + 1e-9,
                            "alpha={alpha}: exact {} worse than {} {}",
                            sol.eval.objective,
                            b.0,
                            be.objective
                        );
                    }
                }
            }
        }
    }
}
