//! Initial Mapping module (§4.2): the MILP formulation of Eqs. 3–18 with an
//! exact structured solver ([`exact`], the production path), a faithful
//! linearized-MILP transcription over the generic solver ([`milp`],
//! cross-check + ablation), and greedy/random baselines ([`baselines`]).

pub mod baselines;
pub mod exact;
pub mod milp;
pub mod problem;

pub use exact::{solve as solve_exact, MappingSolution};
pub use problem::{Evaluation, JobProfile, Mapping, MappingProblem, MessageSizes};
