//! Initial Mapping module (§4.2): the MILP formulation of Eqs. 3–18 with an
//! exact structured solver ([`exact`], the production path), a faithful
//! linearized-MILP transcription over the generic solver ([`milp`],
//! cross-check + ablation), greedy/random baselines ([`baselines`]), and the
//! deterministic ranking helpers they share with the Dynamic Scheduler
//! ([`rank`]).
//!
//! Which implementation a `Framework` run uses is selected by [`MapperKind`]
//! (the `mapper = "..."` key of job specs and the `mappers` sweep-grid
//! axis); `crate::framework::modules::mapper_for` turns a kind into the
//! corresponding `InitialMapper` module.

pub mod baselines;
pub mod exact;
pub mod milp;
pub mod problem;
pub mod rank;

pub use exact::{solve as solve_exact, MappingSolution};
pub use problem::{Evaluation, JobProfile, Mapping, MappingProblem, MessageSizes};

/// Which Initial Mapping implementation to run (module selection for the
/// pluggable `Framework` pipeline). `Exact` is the paper's MILP solved by
/// the structured exact solver; the others are the cross-check solver and
/// the comparison baselines, promoted to drop-in alternatives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MapperKind {
    /// Structured exact MILP solver (the production path).
    #[default]
    Exact,
    /// Linearized MILP over the generic simplex + branch-and-bound.
    Milp,
    /// Everyone on the cheapest-rate VM type that fits quota.
    Cheapest,
    /// Everyone on the lowest-slowdown VM type that fits quota.
    Fastest,
    /// Uniform-random feasible placement (fixed internal seed).
    Random,
    /// Exact solve restricted to the best single provider.
    SingleCloud,
}

impl MapperKind {
    /// Stable config-file key (job specs and sweep grids).
    pub fn key(self) -> &'static str {
        match self {
            MapperKind::Exact => "exact",
            MapperKind::Milp => "milp",
            MapperKind::Cheapest => "cheapest",
            MapperKind::Fastest => "fastest",
            MapperKind::Random => "random",
            MapperKind::SingleCloud => "single-cloud",
        }
    }

    pub fn from_key(key: &str) -> Option<MapperKind> {
        match key {
            "exact" => Some(MapperKind::Exact),
            "milp" => Some(MapperKind::Milp),
            "cheapest" => Some(MapperKind::Cheapest),
            "fastest" => Some(MapperKind::Fastest),
            "random" => Some(MapperKind::Random),
            "single-cloud" => Some(MapperKind::SingleCloud),
            _ => None,
        }
    }

    /// Every selectable kind (CLI help, property tests).
    pub fn all() -> [MapperKind; 6] {
        [
            MapperKind::Exact,
            MapperKind::Milp,
            MapperKind::Cheapest,
            MapperKind::Fastest,
            MapperKind::Random,
            MapperKind::SingleCloud,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapper_kind_keys_round_trip() {
        for kind in MapperKind::all() {
            assert_eq!(MapperKind::from_key(kind.key()), Some(kind));
        }
        assert_eq!(MapperKind::from_key("nope"), None);
        assert_eq!(MapperKind::default(), MapperKind::Exact);
    }
}
