//! Initial Mapping module (§4.2): the MILP formulation of Eqs. 3–18 with an
//! exact structured solver ([`exact`], the production path), a faithful
//! linearized-MILP transcription over the generic solver ([`milp`],
//! cross-check + ablation), greedy/random baselines ([`baselines`]), and the
//! deterministic ranking helpers they share with the Dynamic Scheduler
//! ([`rank`]).
//!
//! Which implementation a `Framework` run uses is selected by [`MapperKind`]
//! (the `mapper = "..."` key of job specs and the `mappers` sweep-grid
//! axis); `crate::framework::modules::mapper_for` turns a kind into the
//! corresponding `InitialMapper` module.

pub mod baselines;
pub mod exact;
pub mod milp;
pub mod problem;
pub mod rank;

pub use exact::{solve as solve_exact, MappingSolution};
pub use problem::{Evaluation, JobProfile, Mapping, MappingProblem, MessageSizes};

use crate::cloud::quota::QuotaTracker;
use crate::cloud::VmTypeId;
use crate::telemetry::{Candidate, Elimination};

/// Decision provenance for one Initial Mapping solve: the ranked
/// server-candidate table with a typed elimination reason per loser.
///
/// Runs *post-hoc* and touches none of the solver state, so recording
/// provenance cannot perturb the solve. Granularity is the server VM type —
/// the outer loop of [`exact::solve`] — with each row's objective the same
/// optimistic lower bound the solver prunes on (server cost at the best
/// feasible makespan plus every client's cheapest deadline-meeting option,
/// quota-unaware). The chosen row instead carries the placement's exact
/// evaluated objective and no elimination reason. Works uniformly for the
/// exact/MILP solvers, the baselines, and pinned mappings, since all of
/// them ultimately commit to one server type.
pub fn explain_candidates(p: &MappingProblem, chosen: Option<&Mapping>) -> Vec<Candidate> {
    let vms: Vec<VmTypeId> = p.catalog.vm_ids().collect();
    let n_clients = p.job.n_clients();
    let t_max = p.t_max();
    let cost_max = p.cost_max();
    let chosen_objective = chosen.map(|m| p.evaluate(m).objective);
    let cat = p.catalog;
    let mut rows = Vec::with_capacity(vms.len());
    for &server in &vms {
        let label = format!(
            "{}/{} {}",
            cat.provider(cat.provider_of(server)).name,
            cat.region(cat.region_of(server)).name,
            cat.vm(server).id
        );
        let mut row = Candidate {
            label,
            objective: f64::INFINITY,
            price_factor: p.spot_price_factor,
            eliminated: Some(Elimination::Dominated),
        };
        let mut quota = QuotaTracker::new();
        if quota.allocate(cat, server).is_err() {
            row.eliminated = Some(Elimination::QuotaExhausted);
            rows.push(row);
            continue;
        }
        // Same per-client round times and candidate-makespan grid as the
        // solver's inner loops (exact::solve).
        let t_agg = p.t_aggreg(server);
        let mut time = vec![vec![0.0; vms.len()]; n_clients];
        let mut ccost = vec![vec![0.0; vms.len()]; n_clients];
        for i in 0..n_clients {
            for (vi, &v) in vms.iter().enumerate() {
                time[i][vi] = p.t_exec(i, v) + p.t_comm(v, server) + t_agg;
                ccost[i][vi] = p.comm_cost(v, server);
            }
        }
        let mut grid: Vec<f64> = time
            .iter()
            .flatten()
            .copied()
            .filter(|&t| t <= p.deadline_round + 1e-9)
            .collect();
        rank::sort_f64(&mut grid);
        grid.dedup_by(|a, b| (*a - *b).abs() < 1e-9);

        let server_rate = p.rate_per_sec(server);
        let mut any_in_time = false;
        let mut any_in_budget = false;
        let mut best_lb = f64::INFINITY;
        for &t_m in &grid {
            let mut lb_clients = 0.0;
            let mut ok = true;
            for i in 0..n_clients {
                let min_cost = rank::argmin_by_f64(
                    (0..vms.len()).filter(|&vi| time[i][vi] <= t_m + 1e-9),
                    |&vi| p.rate_per_sec(vms[vi]) * t_m + ccost[i][vi],
                );
                match min_cost {
                    Some((_, c)) => lb_clients += c,
                    None => {
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                continue;
            }
            any_in_time = true;
            let lb_cost = server_rate * t_m + lb_clients;
            if lb_cost > p.budget_round + 1e-9 {
                continue;
            }
            any_in_budget = true;
            let lb_objective = p.alpha * lb_cost / cost_max + (1.0 - p.alpha) * t_m / t_max;
            best_lb = best_lb.min(lb_objective);
        }
        if !any_in_time {
            row.eliminated = Some(Elimination::PastDeadline);
        } else if !any_in_budget {
            row.eliminated = Some(Elimination::OverBudget);
        } else {
            row.objective = best_lb;
        }
        if chosen.map(|m| m.server) == Some(server) {
            row.eliminated = None;
            if let Some(obj) = chosen_objective {
                row.objective = obj;
            }
        }
        rows.push(row);
    }
    rank::sort_by_key_f64(&mut rows, |c| c.objective);
    rows
}

/// Which Initial Mapping implementation to run (module selection for the
/// pluggable `Framework` pipeline). `Exact` is the paper's MILP solved by
/// the structured exact solver; the others are the cross-check solver and
/// the comparison baselines, promoted to drop-in alternatives.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MapperKind {
    /// Structured exact MILP solver (the production path).
    #[default]
    Exact,
    /// Linearized MILP over the generic simplex + branch-and-bound.
    Milp,
    /// Everyone on the cheapest-rate VM type that fits quota.
    Cheapest,
    /// Everyone on the lowest-slowdown VM type that fits quota.
    Fastest,
    /// Uniform-random feasible placement (fixed internal seed).
    Random,
    /// Exact solve restricted to the best single provider.
    SingleCloud,
}

impl MapperKind {
    /// Stable config-file key (job specs and sweep grids).
    pub fn key(self) -> &'static str {
        match self {
            MapperKind::Exact => "exact",
            MapperKind::Milp => "milp",
            MapperKind::Cheapest => "cheapest",
            MapperKind::Fastest => "fastest",
            MapperKind::Random => "random",
            MapperKind::SingleCloud => "single-cloud",
        }
    }

    pub fn from_key(key: &str) -> Option<MapperKind> {
        match key {
            "exact" => Some(MapperKind::Exact),
            "milp" => Some(MapperKind::Milp),
            "cheapest" => Some(MapperKind::Cheapest),
            "fastest" => Some(MapperKind::Fastest),
            "random" => Some(MapperKind::Random),
            "single-cloud" => Some(MapperKind::SingleCloud),
            _ => None,
        }
    }

    /// Every selectable kind (CLI help, property tests).
    pub fn all() -> [MapperKind; 6] {
        [
            MapperKind::Exact,
            MapperKind::Milp,
            MapperKind::Cheapest,
            MapperKind::Fastest,
            MapperKind::Random,
            MapperKind::SingleCloud,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapper_kind_keys_round_trip() {
        for kind in MapperKind::all() {
            assert_eq!(MapperKind::from_key(kind.key()), Some(kind));
        }
        assert_eq!(MapperKind::from_key("nope"), None);
        assert_eq!(MapperKind::default(), MapperKind::Exact);
    }

    #[test]
    fn explain_ranks_every_server_type_and_marks_the_chosen_row() {
        use crate::cloud::Market;
        use crate::mapping::problem::testutil::*;
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::Spot,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        };
        let sol = solve_exact(&p).expect("unconstrained TIL solves");
        let rows = explain_candidates(&p, Some(&sol.mapping));
        assert_eq!(rows.len(), mc.catalog.vm_ids().count(), "one row per server type");
        let chosen: Vec<_> = rows.iter().filter(|r| r.eliminated.is_none()).collect();
        assert_eq!(chosen.len(), 1, "exactly one chosen row");
        assert!(chosen[0].label.ends_with(&mc.catalog.vm(sol.mapping.server).id));
        assert!((chosen[0].objective - sol.eval.objective).abs() < 1e-12);
        assert!(rows.iter().any(|r| r.eliminated == Some(Elimination::Dominated)));
        for w in rows.windows(2) {
            assert!(w[0].objective <= w[1].objective, "rows are ranked by objective");
        }
    }

    #[test]
    fn explain_reports_deadline_and_budget_eliminations() {
        use crate::cloud::Market;
        use crate::mapping::problem::testutil::*;
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        let mut p = MappingProblem {
            catalog: &mc.catalog,
            slowdowns: &sl,
            job: &job,
            alpha: 0.5,
            market: Market::Spot,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e-6,
            outlook: None,
        };
        // An impossible deadline eliminates every server type on time.
        let rows = explain_candidates(&p, None);
        assert!(rows.iter().all(|r| r.eliminated == Some(Elimination::PastDeadline)));
        assert!(rows.iter().all(|r| r.objective.is_infinite()));
        // An impossible budget (with a sane deadline) eliminates on cost.
        p.deadline_round = 1e9;
        p.budget_round = 1e-9;
        let rows = explain_candidates(&p, None);
        assert!(rows.iter().all(|r| r.eliminated == Some(Elimination::OverBudget)));
    }
}
