//! The PJRT-backed [`crate::fl::Trainer`]: a client's local silo plus the
//! compiled train/eval steps of its application model.
//!
//! One training round = `local_epochs` passes over the shard in fixed-size
//! batches, each batch one invocation of the AOT train-step executable
//! (`(params, x, y) → (params', loss)`); evaluation runs the eval-step
//! executable (`(params, x, y) → (loss, correct)`) over the test split.

use crate::fl::Trainer;

use super::manifest::AppArtifacts;
use super::{Engine, Executable};

/// A client's local dataset shard: flattened features + f32-encoded labels.
#[derive(Debug, Clone)]
pub struct Shard {
    pub x_train: Vec<f32>,
    pub y_train: Vec<f32>,
    pub x_test: Vec<f32>,
    pub y_test: Vec<f32>,
    pub feature_dim: usize,
}

impl Shard {
    pub fn n_train(&self) -> usize {
        self.y_train.len()
    }
    pub fn n_test(&self) -> usize {
        self.y_test.len()
    }
}

pub struct PjrtTrainer {
    train_exe: Executable,
    eval_exe: Executable,
    shard: Shard,
    batch: usize,
    local_epochs: u32,
    param_count: usize,
}

impl PjrtTrainer {
    pub fn new(
        engine: &Engine,
        artifacts: &AppArtifacts,
        shard: Shard,
        local_epochs: u32,
    ) -> anyhow::Result<PjrtTrainer> {
        anyhow::ensure!(shard.feature_dim == artifacts.feature_dim, "feature dim mismatch");
        anyhow::ensure!(shard.n_train() >= artifacts.batch, "shard smaller than a batch");
        Ok(PjrtTrainer {
            train_exe: engine.load_hlo_text(&artifacts.train_hlo)?,
            eval_exe: engine.load_hlo_text(&artifacts.eval_hlo)?,
            shard,
            batch: artifacts.batch,
            local_epochs,
            param_count: artifacts.param_count,
        })
    }

    fn batch_views(&self, x: &[f32], y: &[f32]) -> Vec<(Vec<f32>, Vec<f32>)> {
        // Fixed-shape batches (AOT shapes are static); the tail partial
        // batch is dropped, as in the LEAF reference training loops.
        let n = y.len();
        let d = self.shard.feature_dim;
        (0..n / self.batch)
            .map(|b| {
                let lo = b * self.batch;
                let hi = lo + self.batch;
                (x[lo * d..hi * d].to_vec(), y[lo..hi].to_vec())
            })
            .collect()
    }
}

impl Trainer for PjrtTrainer {
    fn n_train_samples(&self) -> u32 {
        self.shard.n_train() as u32
    }

    fn n_test_samples(&self) -> u32 {
        // Fixed-shape eval drops the tail partial batch; report the number
        // of samples actually evaluated so pooled accuracy is exact.
        ((self.shard.n_test() / self.batch) * self.batch) as u32
    }

    fn train_round(&mut self, weights: &[f32], _round: u32) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(weights.len() == self.param_count, "param count mismatch");
        let mut params = weights.to_vec();
        let b = self.batch as i64;
        let d = self.shard.feature_dim as i64;
        for _epoch in 0..self.local_epochs {
            for (bx, by) in self.batch_views(&self.shard.x_train, &self.shard.y_train) {
                let out = self.train_exe.run_f32(&[
                    (&params, &[self.param_count as i64]),
                    (&bx, &[b, d]),
                    (&by, &[b]),
                ])?;
                anyhow::ensure!(out.len() == 2, "train step must return (params, loss)");
                params = out.into_iter().next().unwrap();
            }
        }
        Ok(params)
    }

    fn evaluate(&mut self, weights: &[f32]) -> anyhow::Result<(f64, u32)> {
        let b = self.batch as i64;
        let d = self.shard.feature_dim as i64;
        let mut total_loss = 0.0f64;
        let mut total_correct = 0u32;
        let mut batches = 0u32;
        for (bx, by) in self.batch_views(&self.shard.x_test, &self.shard.y_test) {
            let out = self.eval_exe.run_f32(&[
                (&weights.to_vec(), &[self.param_count as i64]),
                (&bx, &[b, d]),
                (&by, &[b]),
            ])?;
            anyhow::ensure!(out.len() == 2, "eval step must return (loss, correct)");
            total_loss += out[0][0] as f64;
            total_correct += out[1][0] as u32;
            batches += 1;
        }
        anyhow::ensure!(batches > 0, "test shard smaller than a batch");
        Ok((total_loss / batches as f64, total_correct))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-written HLO implementing a 1-feature linear-regression step so
    /// the trainer logic is testable without python artifacts:
    ///   params p = [w]; pred = x·w; grad = 2/B Σ (pred−y)·x; w' = w − 0.1g
    /// loss = mean (pred−y)².
    const LINREG_TRAIN: &str = r#"HloModule linreg_train, entry_computation_layout={(f32[1]{0}, f32[2,1]{1,0}, f32[2]{0})->(f32[1]{0}, f32[])}

add_reducer {
  ra = f32[] parameter(0)
  rb = f32[] parameter(1)
  ROOT rs = f32[] add(ra, rb)
}

ENTRY main {
  p = f32[1]{0} parameter(0)
  x = f32[2,1]{1,0} parameter(1)
  y = f32[2]{0} parameter(2)
  xf = f32[2]{0} reshape(x)
  w0 = f32[] reshape(p)
  wb = f32[2]{0} broadcast(w0), dimensions={}
  yhat = f32[2]{0} multiply(xf, wb)
  err = f32[2]{0} subtract(yhat, y)
  ex = f32[2]{0} multiply(err, xf)
  zero = f32[] constant(0)
  gsum = f32[] reduce(ex, zero), dimensions={0}, to_apply=add_reducer
  lr = f32[] constant(0.1)
  step = f32[] multiply(gsum, lr)
  wnew = f32[] subtract(w0, step)
  pnew = f32[1]{0} reshape(wnew)
  e2 = f32[2]{0} multiply(err, err)
  lsum = f32[] reduce(e2, zero), dimensions={0}, to_apply=add_reducer
  half = f32[] constant(0.5)
  loss = f32[] multiply(lsum, half)
  ROOT out = (f32[1]{0}, f32[]) tuple(pnew, loss)
}
"#;

const LINREG_EVAL: &str = r#"HloModule linreg_eval, entry_computation_layout={(f32[1]{0}, f32[2,1]{1,0}, f32[2]{0})->(f32[], f32[])}

add_reducer {
  ra = f32[] parameter(0)
  rb = f32[] parameter(1)
  ROOT rs = f32[] add(ra, rb)
}

ENTRY main {
  p = f32[1]{0} parameter(0)
  x = f32[2,1]{1,0} parameter(1)
  y = f32[2]{0} parameter(2)
  xf = f32[2]{0} reshape(x)
  w0 = f32[] reshape(p)
  wb = f32[2]{0} broadcast(w0), dimensions={}
  yhat = f32[2]{0} multiply(xf, wb)
  err = f32[2]{0} subtract(yhat, y)
  e2 = f32[2]{0} multiply(err, err)
  zero = f32[] constant(0)
  lsum = f32[] reduce(e2, zero), dimensions={0}, to_apply=add_reducer
  half = f32[] constant(0.5)
  loss = f32[] multiply(lsum, half)
  correct = f32[] constant(2)
  ROOT out = (f32[], f32[]) tuple(loss, correct)
}
"#;

    fn artifacts_in_tmp() -> (std::path::PathBuf, AppArtifacts) {
        let dir = std::env::temp_dir().join(format!("mfls-trainer-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("linreg_train.hlo.txt"), LINREG_TRAIN).unwrap();
        std::fs::write(dir.join("linreg_eval.hlo.txt"), LINREG_EVAL).unwrap();
        let art = AppArtifacts {
            name: "linreg".into(),
            param_count: 1,
            batch: 2,
            feature_dim: 1,
            n_classes: 1,
            train_hlo: dir.join("linreg_train.hlo.txt"),
            eval_hlo: dir.join("linreg_eval.hlo.txt"),
            init_params: dir.join("linreg_init.bin"),
        };
        (dir, art)
    }

    #[test]
    fn pjrt_trainer_learns_linear_coefficient() {
        let engine = Engine::cpu().unwrap();
        let (dir, art) = artifacts_in_tmp();
        // Data: y = 3x over 8 samples.
        let xs: Vec<f32> = (1..=8).map(|i| i as f32 / 8.0).collect();
        let ys: Vec<f32> = xs.iter().map(|x| 3.0 * x).collect();
        let shard = Shard {
            x_train: xs.clone(),
            y_train: ys.clone(),
            x_test: xs[..4].to_vec(),
            y_test: ys[..4].to_vec(),
            feature_dim: 1,
        };
        let mut t = PjrtTrainer::new(&engine, &art, shard, 5).unwrap();
        let w0 = vec![0.0f32];
        let (l0, _) = t.evaluate(&w0).unwrap();
        let mut w = w0;
        for round in 0..20 {
            w = t.train_round(&w, round).unwrap();
        }
        let (l1, _) = t.evaluate(&w).unwrap();
        assert!(l1 < l0 * 0.05, "loss {l0} → {l1}");
        assert!((w[0] - 3.0).abs() < 0.2, "w={}", w[0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_batch_count_and_fedavg_weighting() {
        let engine = Engine::cpu().unwrap();
        let (dir, art) = artifacts_in_tmp();
        let shard = Shard {
            x_train: vec![0.5; 7], // 7 samples → 3 full batches of 2
            y_train: vec![1.0; 7],
            x_test: vec![0.5; 2],
            y_test: vec![1.0; 2],
            feature_dim: 1,
        };
        let t = PjrtTrainer::new(&engine, &art, shard, 1).unwrap();
        assert_eq!(t.n_train_samples(), 7);
        assert_eq!(t.n_test_samples(), 2); // 2 test samples = 1 full batch
        assert_eq!(t.batch_views(&t.shard.x_train, &t.shard.y_train).len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
