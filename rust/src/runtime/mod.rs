//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`) and
//! executes them from the rust hot path. Python never runs at request time.
//!
//! The interchange format is HLO *text* (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the `xla`
//! crate's XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids
//! and round-trips cleanly.

pub mod manifest;
pub mod trainer;

pub use manifest::{AppArtifacts, Manifest};
pub use trainer::PjrtTrainer;

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A PJRT CPU engine hosting compiled executables.
///
/// The `xla` crate's handles are not `Sync`; the engine serializes access
/// through a mutex so FL client threads can share one process-wide engine
/// (CPU PJRT parallelizes internally per executable).
pub struct Engine {
    inner: Arc<Mutex<EngineInner>>,
    /// Compiled-executable cache: every client shares one compilation per
    /// artifact (PJRT compilation of the interpret-mode Pallas HLO is the
    /// expensive part of startup). A BTreeMap so anything derived from the
    /// cache (debug dumps, future eviction) iterates deterministically.
    cache: Arc<Mutex<std::collections::BTreeMap<std::path::PathBuf, Executable>>>,
    hits: Arc<AtomicUsize>,
    misses: Arc<AtomicUsize>,
}

struct EngineInner {
    client: xla::PjRtClient,
}

// The PJRT CPU client is thread-compatible behind a lock.
unsafe impl Send for EngineInner {}

/// A compiled computation ready to execute.
#[derive(Clone)]
pub struct Executable {
    inner: Arc<Mutex<ExecutableInner>>,
    pub name: String,
}

struct ExecutableInner {
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({})", self.name)
    }
}

unsafe impl Send for ExecutableInner {}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> anyhow::Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Engine {
            inner: Arc::new(Mutex::new(EngineInner { client })),
            cache: Arc::new(Mutex::new(std::collections::BTreeMap::new())),
            hits: Arc::new(AtomicUsize::new(0)),
            misses: Arc::new(AtomicUsize::new(0)),
        })
    }

    /// Cache hits so far (shared across clones of this engine).
    pub fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::SeqCst)
    }

    /// Cache misses (= compilations) so far. Deterministic for any number
    /// of concurrent clients: compilation happens under the cache lock, so
    /// each artifact is a miss exactly once.
    pub fn cache_misses(&self) -> usize {
        self.misses.load(Ordering::SeqCst)
    }

    /// Load an HLO-text artifact and compile it for this engine (cached:
    /// repeated loads of the same path reuse the compiled executable).
    pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<Executable> {
        // Hold the cache lock across the compile (the framework::EnvCache
        // pattern): concurrent loads of the same artifact see exactly one
        // miss and never compile twice, so hit/miss counts are identical
        // for any worker count. Compilation is already serialized by the
        // engine mutex, so this costs no parallelism.
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(path) {
            self.hits.fetch_add(1, Ordering::SeqCst);
            return Ok(exe.clone());
        }
        self.misses.fetch_add(1, Ordering::SeqCst);
        anyhow::ensure!(path.exists(), "artifact {} missing — run `make artifacts`", path.display());
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let guard = self.inner.lock().unwrap();
        let exe = guard
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))?;
        let executable = Executable {
            inner: Arc::new(Mutex::new(ExecutableInner { exe })),
            name: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        };
        cache.insert(path.to_path_buf(), executable.clone());
        Ok(executable)
    }
}

impl Clone for Engine {
    fn clone(&self) -> Self {
        Engine {
            inner: self.inner.clone(),
            cache: self.cache.clone(),
            hits: self.hits.clone(),
            misses: self.misses.clone(),
        }
    }
}

impl Executable {
    /// Execute with `f32` inputs of the given shapes; returns the flattened
    /// `f32` outputs of the (jax `return_tuple=True`) result tuple.
    pub fn run_f32(
        &self,
        inputs: &[(&[f32], &[i64])],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape{dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let guard = self.inner.lock().unwrap();
        let result = guard
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e:?}", self.name))?;
        let mut out = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow::anyhow!("no output buffer"))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = out
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decompose_tuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(parts.len());
        for p in parts {
            vecs.push(p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?);
        }
        Ok(vecs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny HLO module (written by hand in HLO text) computing
    /// `out = (x + y,)` over f32[4] — validates the full load→compile→run
    /// path without python artifacts.
    const ADD_HLO: &str = r#"HloModule add_vec, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;

    fn write_tmp(name: &str, text: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("mfls-{}-{}", std::process::id(), name));
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn load_and_run_handwritten_hlo() {
        let engine = Engine::cpu().unwrap();
        let path = write_tmp("add.hlo.txt", ADD_HLO);
        let exe = engine.load_hlo_text(&path).unwrap();
        let out = exe
            .run_f32(&[(&[1.0, 2.0, 3.0, 4.0], &[4]), (&[10.0, 20.0, 30.0, 40.0], &[4])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let engine = Engine::cpu().unwrap();
        let err = engine
            .load_hlo_text(Path::new("/nonexistent/foo.hlo.txt"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    /// The EnvCache-shaped counter assertion: compilation happens under
    /// the cache lock, so no matter how many workers race on the same
    /// artifact, it is a miss exactly once — counts are identical across
    /// worker counts (the `--jobs 1/4` invariant).
    #[test]
    fn cache_counters_identical_across_worker_counts() {
        let run = |workers: usize| -> (usize, usize) {
            let engine = Engine::cpu().unwrap();
            let path = write_tmp(&format!("add-cache-{workers}.hlo.txt"), ADD_HLO);
            let mut joins = Vec::new();
            for _ in 0..workers {
                let engine = engine.clone();
                let path = path.clone();
                joins.push(std::thread::spawn(move || {
                    engine.load_hlo_text(&path).unwrap();
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
            std::fs::remove_file(path).ok();
            (engine.cache_hits(), engine.cache_misses())
        };
        let (hits1, misses1) = run(1);
        let (hits4, misses4) = run(4);
        assert_eq!(misses1, 1);
        assert_eq!(misses4, 1, "artifact must compile exactly once under contention");
        assert_eq!(hits1 + misses1, 1);
        assert_eq!(hits4 + misses4, 4);
    }

    #[test]
    fn executable_shared_across_threads() {
        let engine = Engine::cpu().unwrap();
        let path = write_tmp("add2.hlo.txt", ADD_HLO);
        let exe = engine.load_hlo_text(&path).unwrap();
        let mut joins = Vec::new();
        for t in 0..4 {
            let exe = exe.clone();
            joins.push(std::thread::spawn(move || {
                let x = vec![t as f32; 4];
                let out = exe.run_f32(&[(&x, &[4]), (&x, &[4])]).unwrap();
                assert_eq!(out[0], vec![2.0 * t as f32; 4]);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        std::fs::remove_file(path).ok();
    }
}
