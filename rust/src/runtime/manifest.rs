//! The artifact manifest: metadata `python/compile/aot.py` writes next to
//! the HLO files (`artifacts/manifest.toml`), describing every compiled app
//! model so the rust side can size buffers without re-deriving shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One application's compiled artifacts.
#[derive(Debug, Clone)]
pub struct AppArtifacts {
    pub name: String,
    /// Flattened parameter-vector length.
    pub param_count: usize,
    /// Fixed training batch size compiled into the step.
    pub batch: usize,
    /// Flattened feature dimension per sample.
    pub feature_dim: usize,
    pub n_classes: usize,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    /// Initial parameters written by the AOT pass.
    pub init_params: PathBuf,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub apps: BTreeMap<String, AppArtifacts>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {} — run `make artifacts` first: {e}", path.display())
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let root = crate::util::tomlmini::parse(text)?;
        let mut apps = BTreeMap::new();
        for entry in root
            .get("app")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| anyhow::anyhow!("manifest missing [[app]]"))?
        {
            let need = |k: &str| -> anyhow::Result<i64> {
                entry
                    .get(k)
                    .and_then(|v| v.as_int())
                    .ok_or_else(|| anyhow::anyhow!("manifest app missing {k}"))
            };
            let name = entry
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest app missing name"))?
                .to_string();
            apps.insert(
                name.clone(),
                AppArtifacts {
                    param_count: need("param_count")? as usize,
                    batch: need("batch")? as usize,
                    feature_dim: need("feature_dim")? as usize,
                    n_classes: need("n_classes")? as usize,
                    train_hlo: dir.join(format!("{name}_train.hlo.txt")),
                    eval_hlo: dir.join(format!("{name}_eval.hlo.txt")),
                    init_params: dir.join(format!("{name}_init.bin")),
                    name,
                },
            );
        }
        Ok(Manifest { apps, dir: dir.to_path_buf() })
    }

    pub fn app(&self, name: &str) -> anyhow::Result<&AppArtifacts> {
        self.apps
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("app {name} not in manifest ({:?})", self.apps.keys()))
    }
}

impl AppArtifacts {
    /// Load the initial flat parameter vector (little-endian f32).
    pub fn load_init_params(&self) -> anyhow::Result<Vec<f32>> {
        let bytes = std::fs::read(&self.init_params)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", self.init_params.display()))?;
        anyhow::ensure!(bytes.len() == self.param_count * 4, "init param size mismatch");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
[[app]]
name = "femnist"
param_count = 1000
batch = 32
feature_dim = 784
n_classes = 62

[[app]]
name = "til"
param_count = 2000
batch = 16
feature_dim = 12288
n_classes = 2
"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.apps.len(), 2);
        let f = m.app("femnist").unwrap();
        assert_eq!(f.param_count, 1000);
        assert_eq!(f.batch, 32);
        assert!(f.train_hlo.ends_with("femnist_train.hlo.txt"));
        assert!(m.app("nope").is_err());
    }

    #[test]
    fn init_params_round_trip() {
        let dir = std::env::temp_dir().join(format!("mfls-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::parse(
            "[[app]]\nname = \"x\"\nparam_count = 3\nbatch = 1\nfeature_dim = 1\nn_classes = 2\n",
            &dir,
        )
        .unwrap();
        let app = m.app("x").unwrap();
        let mut bytes = Vec::new();
        for v in [1.0f32, -2.0, 0.5] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&app.init_params, bytes).unwrap();
        assert_eq!(app.load_init_params().unwrap(), vec![1.0, -2.0, 0.5]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
