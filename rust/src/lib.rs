//! # Multi-FedLS
//!
//! A framework for Cross-Silo Federated Learning applications on multi-cloud
//! environments — reproduction of Brum et al. (cs.DC 2023).
//!
//! Multi-FedLS manages multi-cloud resources to reduce the execution time and
//! financial cost of Cross-Silo FL jobs, exploiting cheap preemptible (spot)
//! VMs while surviving their revocation. It is organized as the paper's four
//! modules plus the substrates they need:
//!
//! * [`cloud`] — the environment model: providers, regions, VM types, prices,
//!   quotas (§3), with the paper's Table 2 / Table 9 catalogs built in.
//! * [`simul`] — deterministic RNG + discrete-event simulation engine.
//! * [`cloudsim`] — the simulated multi-cloud platform (VM lifecycle, spot
//!   revocations, network, billing).
//! * [`presched`] — Pre-Scheduling (§4.1): dummy-app slowdown measurement.
//! * [`solver`] — from-scratch LP simplex + 0/1 branch-and-bound MILP.
//! * [`mapping`] — Initial Mapping (§4.2): the MILP formulation (Eqs. 3–18)
//!   with exact and baseline solvers.
//! * [`fl`] — a Flower-like Cross-Silo FL runtime (rounds, FedAvg, messages).
//! * [`ft`] — Fault Tolerance (§4.3): monitoring + checkpointing.
//! * [`dynsched`] — Dynamic Scheduler (§4.4): Algorithms 1–3.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas artifacts.
//! * [`data`] — synthetic federated datasets (TIL, Shakespeare, FEMNIST).
//! * [`apps`] — the paper's three application descriptors (§5.1).
//! * [`coordinator`] — the end-to-end driver tying everything together.
//! * [`sweep`] — the parallel experiment-campaign engine: declarative config
//!   grids fanned out across an OS-thread worker pool, deterministically.
//! * [`trace`] — experiment recording and table rendering.

pub mod apps;
pub mod cloud;
pub mod coordinator;
pub mod data;
pub mod dynsched;
pub mod fl;
pub mod ft;
pub mod mapping;
pub mod presched;
pub mod solver;
pub mod cloudsim;
pub mod runtime;
pub mod trace;
pub mod simul;
pub mod sweep;
pub mod util;
