//! # Multi-FedLS
//!
//! A framework for Cross-Silo Federated Learning applications on multi-cloud
//! environments — reproduction of Brum et al. (cs.DC 2023).
//!
//! Multi-FedLS manages multi-cloud resources to reduce the execution time and
//! financial cost of Cross-Silo FL jobs, exploiting cheap preemptible (spot)
//! VMs while surviving their revocation.
//!
//! ## The module pipeline
//!
//! The paper's four cooperating modules are object-safe traits assembled
//! into a [`framework::Framework`] stack:
//!
//! ```text
//! Framework::builder()
//!     .pre_sched(..)   // PreScheduling (§4.1): dummy-app slowdown report
//!     .mapper(..)      // InitialMapper (§4.2): exact MILP | baselines
//!     .ft(..)          // FaultTolerance (§4.3): checkpoint/recovery model
//!     .dynsched(..)    // DynScheduler (§4.4): Algorithms 1–3 | ablations
//!     .build()
//!     .run(&cfg)
//! ```
//!
//! The default stack reproduces the paper's pipeline exactly;
//! `coordinator::simulate` and `coordinator::run_trials` are thin wrappers
//! over it. Campaign drivers share a [`framework::EnvCache`] so each
//! environment's Pre-Scheduling report is measured once per campaign.
//!
//! ## Module map
//!
//! * [`cloud`] — the environment model: providers, regions, VM types, prices,
//!   quotas (§3), with the paper's Table 2 / Table 9 catalogs built in.
//! * [`simul`] — deterministic RNG + discrete-event simulation engine.
//! * [`market`] — the spot-market subsystem: pluggable revocation processes
//!   (exponential `k_r` / Weibull / seasonal / trace-replay), dynamic price
//!   series (constant / TOML price traces), and bid-priced VMs — configured
//!   per job via `[market]` tables and swept via the `markets` grid axis.
//! * [`cloudsim`] — the simulated multi-cloud platform (VM lifecycle, spot
//!   revocations sampled from the market model, network, segment-accurate
//!   market billing).
//! * [`presched`] — Pre-Scheduling (§4.1): dummy-app slowdown measurement.
//! * [`solver`] — from-scratch LP simplex + 0/1 branch-and-bound MILP.
//! * [`mapping`] — Initial Mapping (§4.2): the MILP formulation (Eqs. 3–18)
//!   with exact and baseline solvers, module selection
//!   ([`mapping::MapperKind`]) and the shared ranking helpers
//!   ([`mapping::rank`]).
//! * [`outlook`] — market forecasting: a per-job [`outlook::MarketOutlook`]
//!   with exact windowed price integrals, closed-form revocation hazards
//!   (survival / expected revocations), bid advice and deferral — consumed
//!   by the mappers (delayed-start decisions), the Dynamic Scheduler
//!   (remaining-horizon candidate pricing) and the workload engine's
//!   admission retries; configured via `[outlook]` tables and the
//!   `outlooks` grid axis (off by default, bit-identical parity).
//! * [`fl`] — a Flower-like Cross-Silo FL runtime (rounds, FedAvg, messages).
//! * [`ft`] — Fault Tolerance (§4.3): monitoring + checkpointing.
//! * [`dynsched`] — Dynamic Scheduler (§4.4): Algorithms 1–3, built around
//!   the [`dynsched::RevocationCtx`] context struct (placement + market view
//!   at the revocation instant).
//! * [`framework`] — the composable pipeline: the four module traits, their
//!   built-in implementations, the builder, the event-loop core, and the
//!   shared environment cache.
//! * [`runtime`] — PJRT executor for the AOT-compiled JAX/Pallas artifacts.
//! * [`data`] — synthetic federated datasets (TIL, Shakespeare, FEMNIST).
//! * [`apps`] — the paper's three application descriptors (§5.1).
//! * [`coordinator`] — configuration (job specs) and the end-to-end drivers
//!   (simulated, real-compute, multi-job planning) over the framework stack.
//! * [`workload`] — first-class multi-job campaigns: arrival processes
//!   (batch/Poisson/trace), admission policies, per-job budget/deadline
//!   constraints, and a discrete-event engine that drives every admitted job
//!   through the framework pipeline against one shared quota ledger
//!   ([`workload::Workload::single`] is the degenerate one-job case) — plus
//!   workload-level dynamic scheduling ([`workload::sched`]): per-job
//!   priorities and tenants, checkpoint-preemption, and cross-tenant
//!   fair-share, pluggable via [`workload::WorkloadScheduler`].
//! * [`sweep`] — the parallel experiment-campaign engine: declarative config
//!   grids fanned out across an OS-thread worker pool, deterministically,
//!   with persisted, resumable results ([`sweep::persist`]).
//! * [`telemetry`] — structured observability: the typed event vocabulary
//!   ([`telemetry::EventKind`]) behind every `SimEvent`, sim-clock spans
//!   (`RoundSpan`/`VmLifetimeSpan`/`JobSpan`/`SolverSpan`) with exact
//!   ledger-backed cost attribution, a deterministic
//!   [`telemetry::MetricsRegistry`], and the JSONL (`--trace-out`) /
//!   flamegraph / `multi-fedls report` sinks — gated per job by the
//!   `[telemetry]` table, bit-identical to the bare simulator when off.
//! * [`trace`] — experiment recording and table rendering.
//! * [`lint`] — the dependency-free determinism & invariant linter behind
//!   `multi-fedls lint` (hash-iter / wall-clock / float-eq / spec-unwrap /
//!   unknown-key rules plus `lint:allow` annotations), also enforced by
//!   `cargo test` and CI.

pub mod apps;
pub mod cloud;
pub mod coordinator;
pub mod data;
pub mod dynsched;
pub mod fl;
pub mod framework;
pub mod ft;
pub mod lint;
pub mod mapping;
pub mod market;
pub mod outlook;
pub mod presched;
pub mod solver;
pub mod cloudsim;
pub mod runtime;
pub mod trace;
pub mod simul;
pub mod sweep;
pub mod telemetry;
pub mod util;
pub mod workload;
