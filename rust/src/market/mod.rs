//! The spot-market subsystem: pluggable revocation processes + dynamic
//! price traces.
//!
//! The paper's market model is a single fixed-rate Poisson clock (`k_r`)
//! and a constant price per second. Real spot markets have time-varying
//! prices, provider-specific interruption behaviour, and hazard rates that
//! change with instance age. This module makes the market a first-class,
//! pluggable model:
//!
//! * [`RevocationProcess`] (in [`revocation`]) — when spot VMs are
//!   preempted: the paper's exponential clock (default), an age-dependent
//!   Weibull hazard, a time-of-day [`SeasonalProcess`], and a deterministic
//!   [`TraceReplay`] of recorded interruption timestamps.
//! * [`PriceSeries`] (in [`price`]) — what spot capacity costs over time:
//!   constant (today's behaviour, bit-identical) or piecewise steps loaded
//!   from TOML price-trace files (AWS spot-price-history shape). Billing
//!   integrates the series segment-accurately ([`crate::cloudsim::billing`])
//!   and planning uses the expected factor over the horizon
//!   ([`crate::mapping::MappingProblem::rate_per_sec`]).
//! * Bid-priced VMs — with a `bid_factor`, a spot VM is additionally
//!   revoked at the first price step that exceeds its bid (the
//!   price-threshold market mode). Co-timed evictions are processed as one
//!   *batched* revocation event: when a crossing outbids several VMs at the
//!   same instant — or a recorded trace instant hits every co-provisioned
//!   VM at once (see [`TraceReplay`]) — each hit task is revoked and
//!   rescheduled at that instant (server considered first, then clients in
//!   index order, so earlier replacement choices are visible to later
//!   ones), and the round resumes after the *slowest* replacement boots.
//!
//! [`MarketView`] is the read-only handle scheduling modules get through
//! [`crate::dynsched::RevocationCtx`]: price factors and upcoming steps are
//! visible to replacement decisions (market-aware scheduling), but the
//! revocation process and its RNG stream are not.
//!
//! [`MarketSpec`] (in [`spec`]) is the declarative form carried by
//! `SimConfig` and parsed from `[market]` / `[[market]]` TOML tables (job
//! specs, sweep grids, workload specs); [`MarketModel`] is the assembled
//! runtime model handed to [`crate::cloudsim::MultiCloud`].
//!
//! Parity contract: `MarketSpec::default()` (exponential `k_r` revocations,
//! constant price, no bid) reproduces the pre-market simulator bit for bit —
//! enforced by `tests/market_parity.rs` and `tests/framework_parity.rs`.

pub mod price;
pub mod revocation;
pub mod spec;

pub use price::PriceSeries;
pub use revocation::{
    ExponentialProcess, NoRevocations, RevocationProcess, SeasonalProcess, TraceReplay,
    WeibullProcess,
};
pub use spec::{MarketSpec, PriceSpec, RevocationSpec};

use crate::cloudsim::RevocationModel;
use crate::simul::{Rng, SimTime};

/// One assembled spot-market model: a revocation process, a price series,
/// and an optional bid threshold. Owned by the simulated platform.
#[derive(Debug)]
pub struct MarketModel {
    pub revocation: Box<dyn RevocationProcess>,
    pub price: PriceSeries,
    /// Bid as a multiple of the base spot rate: the VM is revoked when the
    /// price factor first exceeds it. `None` = not bid-priced.
    pub bid_factor: Option<f64>,
}

impl MarketModel {
    /// The historical market: `RevocationModel` semantics (exponential
    /// clock or none) at constant price.
    pub fn from_revocation(model: RevocationModel) -> MarketModel {
        let revocation: Box<dyn RevocationProcess> = match model.mean_secs {
            Some(k_r) => Box::new(ExponentialProcess::new(k_r)),
            None => Box::new(NoRevocations),
        };
        MarketModel { revocation, price: PriceSeries::Constant, bid_factor: None }
    }

    /// Pre-sample the revocation instant of a spot VM provisioned at `now`:
    /// the earlier of the process sample and (for bid-priced VMs) the first
    /// price step exceeding the bid.
    pub fn revocation_at(&self, now: SimTime, rng: &mut Rng) -> Option<SimTime> {
        let sampled = self.revocation.sample(now, rng);
        let outbid = self.bid_crossing_at(now);
        match (sampled, outbid) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    /// The price-driven eviction instant alone: the first price step
    /// exceeding the bid after `now`. Unlike the failure process, this is
    /// *not* suppressed by the §5.6.1 revocation cap — a provider evicts an
    /// outbid VM no matter how many failures the task has already absorbed.
    pub fn bid_crossing_at(&self, now: SimTime) -> Option<SimTime> {
        self.bid_factor
            .and_then(|bid| self.price.first_crossing_above(now.secs(), bid))
            .map(SimTime::from_secs)
    }
}

/// Read-only market access for scheduling modules (carried by
/// [`crate::dynsched::RevocationCtx`]): the declarative price side of a
/// job's [`MarketSpec`], on the same clock the caller's `at` instants use.
/// Deliberately excludes the revocation process — a scheduler may price
/// candidates against the series, but never peek at future failures. (The
/// optional [`MarketOutlook`] exposes only closed-form *expectations* of
/// that process, never its sampled instants, so the boundary holds.)
#[derive(Debug, Clone, Copy)]
pub struct MarketView<'a> {
    spec: &'a MarketSpec,
    outlook: Option<&'a crate::outlook::MarketOutlook>,
}

impl<'a> MarketView<'a> {
    pub fn new(spec: &'a MarketSpec) -> MarketView<'a> {
        MarketView { spec, outlook: None }
    }

    /// A view upgraded with the job's [`MarketOutlook`]: replacement
    /// selection can price candidates over their actual remaining horizon
    /// instead of the flat expected factor.
    ///
    /// [`MarketOutlook`]: crate::outlook::MarketOutlook
    pub fn with_outlook(
        spec: &'a MarketSpec,
        outlook: Option<&'a crate::outlook::MarketOutlook>,
    ) -> MarketView<'a> {
        MarketView { spec, outlook }
    }

    /// The underlying declarative spec.
    pub fn spec(&self) -> &'a MarketSpec {
        self.spec
    }

    /// The job's market outlook, when outlook-aware scheduling is on.
    pub fn outlook(&self) -> Option<&'a crate::outlook::MarketOutlook> {
        self.outlook
    }

    /// Spot-price multiplier in effect at `at` (1.0 for a constant market).
    pub fn price_factor_at(&self, at: SimTime) -> f64 {
        self.spec.price_series().factor_at(at.secs())
    }

    /// Expected spot-price multiplier over `[0, horizon_secs)` — the same
    /// factor the Initial Mapping charged at planning time.
    pub fn planning_price_factor(&self, horizon_secs: f64) -> f64 {
        self.spec.planning_price_factor(horizon_secs)
    }

    /// The next instant strictly after `at` at which the price changes.
    pub fn next_price_step_after(&self, at: SimTime) -> Option<SimTime> {
        self.spec.next_price_step_after(at.secs()).map(SimTime::from_secs)
    }

    /// The bid threshold of a price-threshold market, if any.
    pub fn bid_factor(&self) -> Option<f64> {
        self.spec.bid_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn market_view_exposes_price_side_only() {
        let spec = MarketSpec {
            revocation: RevocationSpec::Exponential,
            price: PriceSpec::Steps(vec![(0.0, 1.0), (100.0, 1.5)]),
            bid_factor: Some(2.0),
        };
        let view = MarketView::new(&spec);
        assert_eq!(view.price_factor_at(SimTime::ZERO), 1.0);
        assert_eq!(view.price_factor_at(SimTime::from_secs(150.0)), 1.5);
        assert_eq!(view.next_price_step_after(SimTime::ZERO).unwrap().secs(), 100.0);
        assert_eq!(view.bid_factor(), Some(2.0));
        assert!(view.planning_price_factor(200.0) > 1.0);
        // The default market reads as the constant factor everywhere.
        let dflt = MarketSpec::default();
        let view = MarketView::new(&dflt);
        assert_eq!(view.price_factor_at(SimTime::from_secs(1e9)), 1.0);
        assert!(view.next_price_step_after(SimTime::ZERO).is_none());
    }

    #[test]
    fn from_revocation_preserves_legacy_semantics() {
        let mut rng = Rng::seeded(42);
        let none = MarketModel::from_revocation(RevocationModel::none());
        assert!(none.revocation_at(SimTime::ZERO, &mut rng).is_none());
        // No stream advance happened for the disabled model.
        let mut fresh = Rng::seeded(42);
        assert_eq!(rng.next_u64(), fresh.next_u64());

        let poisson = MarketModel::from_revocation(RevocationModel::poisson(7200.0));
        let mut a = Rng::seeded(9);
        let mut b = Rng::seeded(9);
        let got = poisson.revocation_at(SimTime::ZERO, &mut a).unwrap();
        let want = b.exponential(1.0 / 7200.0);
        assert_eq!(got.secs().to_bits(), want.to_bits());
    }

    #[test]
    fn bid_threshold_caps_the_sampled_instant() {
        let price = PriceSeries::steps(vec![(0.0, 1.0), (500.0, 2.0)]).unwrap();
        let model = MarketModel {
            revocation: Box::new(NoRevocations),
            price,
            bid_factor: Some(1.5),
        };
        let mut rng = Rng::seeded(1);
        // No process sample, but the price outbids the VM at t = 500.
        let at = model.revocation_at(SimTime::ZERO, &mut rng).unwrap();
        assert_eq!(at.secs(), 500.0);
        // A VM provisioned after the crossing is never outbid again.
        assert!(model.revocation_at(SimTime::from_secs(600.0), &mut rng).is_none());
    }

    #[test]
    fn earlier_of_process_and_crossing_wins() {
        let price = PriceSeries::steps(vec![(0.0, 1.0), (10_000.0, 3.0)]).unwrap();
        let model = MarketModel {
            revocation: Box::new(TraceReplay { times: vec![50.0] }),
            price,
            bid_factor: Some(2.0),
        };
        let mut rng = Rng::seeded(1);
        assert_eq!(model.revocation_at(SimTime::ZERO, &mut rng).unwrap().secs(), 50.0);
        // After the trace is exhausted the crossing takes over.
        assert_eq!(
            model.revocation_at(SimTime::from_secs(60.0), &mut rng).unwrap().secs(),
            10_000.0
        );
    }
}
