//! Declarative market configuration: the `[market]` / `[[market]]` TOML
//! tables of job specs, sweep grids, and workload specs.
//!
//! ```toml
//! [market]                       # job spec: one table
//! revocation = "seasonal"        # exponential | weibull | seasonal | trace
//! mean_secs = 7200.0             # seasonal: time-averaged k_r
//! period_secs = 86400.0          # seasonal: modulation period
//! amplitude = 0.6                # seasonal: modulation depth in [0, 1)
//! price = "steps"                # constant | steps
//! price_file = "configs/market-price-trace.toml"  # [[step]] at_secs/factor
//! bid_factor = 1.5               # optional: revoke when the price outbids
//! ```
//!
//! Sweep and workload specs define *named* markets as `[[market]]` tables
//! (same keys plus `name`) and select them per grid point via the `markets`
//! axis. Unknown keys — including parameters that belong to a different
//! revocation/price kind — are rejected with an error naming the offending
//! key, in the same spirit as the rest of the spec validation.
//!
//! Trace data can be inline (`revocation_times`, `price_times` +
//! `price_factors`) or loaded from sibling TOML trace files
//! (`revocation_file` with `[[revocation]] at_secs`, `price_file` with
//! `[[step]] at_secs`/`factor` — the AWS spot-price-history shape). Relative
//! paths resolve against the spec file's directory first, then the working
//! directory, so shipped configs work from the crate root.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::price::PriceSeries;
use super::revocation::{
    ExponentialProcess, NoRevocations, SeasonalProcess, TraceReplay, WeibullProcess,
};
use super::MarketModel;
use crate::util::tomlmini::{self, Value};

type Tbl = BTreeMap<String, Value>;

/// Which revocation process drives spot preemptions.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum RevocationSpec {
    /// The paper's fixed-rate Poisson clock, driven by the job's
    /// `revocation_mean_secs` (`k_r`; `None`/0 = no failures).
    #[default]
    Exponential,
    /// Age-dependent Weibull hazard.
    Weibull { scale_secs: f64, shape: f64 },
    /// Time-of-day modulated Poisson process (`phase_secs` anchors local
    /// t = 0 on the modulation cycle; workloads advance it per admission).
    Seasonal { mean_secs: f64, period_secs: f64, amplitude: f64, phase_secs: f64 },
    /// Deterministic replay of recorded interruption instants.
    Trace { times: Vec<f64> },
}

impl RevocationSpec {
    /// Stable config-file key.
    pub fn key(&self) -> &'static str {
        match self {
            RevocationSpec::Exponential => "exponential",
            RevocationSpec::Weibull { .. } => "weibull",
            RevocationSpec::Seasonal { .. } => "seasonal",
            RevocationSpec::Trace { .. } => "trace",
        }
    }
}

/// Which price series spot capacity is billed against.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PriceSpec {
    /// The catalog's fixed spot rate (factor 1.0 forever).
    #[default]
    Constant,
    /// Piecewise-constant multiplier steps `(at_secs, factor)`.
    Steps(Vec<(f64, f64)>),
}

impl PriceSpec {
    pub fn key(&self) -> &'static str {
        match self {
            PriceSpec::Constant => "constant",
            PriceSpec::Steps(_) => "steps",
        }
    }
}

/// The declarative spot-market configuration carried by
/// [`crate::coordinator::SimConfig`] (trace data resolved inline, so the
/// spec is self-contained and `Debug`-fingerprintable).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MarketSpec {
    pub revocation: RevocationSpec,
    pub price: PriceSpec,
    /// Bid as a multiple of the base spot rate (price-threshold mode).
    pub bid_factor: Option<f64>,
}

impl MarketSpec {
    /// Is this the historical market (exponential `k_r`, constant price, no
    /// bid) whose outputs are bit-identical to the pre-market simulator?
    pub fn is_default(&self) -> bool {
        *self == MarketSpec::default()
    }

    /// The price series of this market.
    ///
    /// Panics on a malformed step trace: TOML-parsed specs are validated at
    /// parse time, but `PriceSpec::Steps` can also be built in code — an
    /// unsorted trace would silently mis-integrate bills, so it is a
    /// programming error, caught here.
    pub fn price_series(&self) -> PriceSeries {
        match &self.price {
            PriceSpec::Constant => PriceSeries::Constant,
            PriceSpec::Steps(points) => {
                // lint:allow(spec-unwrap) -- programmatic-construction guard, not a parse path: TOML-parsed steps are validated in from_table
                PriceSeries::steps(points.clone()).expect("invalid price steps")
            }
        }
    }

    /// Expected spot-price multiplier over the planning horizon `[0, h)` —
    /// what the Initial Mapping / Dynamic Scheduler cost models charge per
    /// spot VM-second relative to the catalog rate. Exactly 1.0 for the
    /// default market.
    pub fn planning_price_factor(&self, horizon_secs: f64) -> f64 {
        self.price_series().mean_factor(horizon_secs)
    }

    /// The next instant strictly after `t` at which the spot price changes,
    /// if any — when admission feasibility of a budget-capped job can next
    /// change without a capacity release (the workload engine retries
    /// price-queued jobs at these instants).
    pub fn next_price_step_after(&self, t: f64) -> Option<f64> {
        match &self.price {
            PriceSpec::Constant => None,
            PriceSpec::Steps(points) => {
                points.iter().map(|&(at, _)| at).find(|&at| at > t)
            }
        }
    }

    /// Re-anchor this market on a clock whose local t = 0 corresponds to
    /// cluster instant `offset` — how multi-job workloads share one market
    /// timeline across jobs admitted at different times: recorded
    /// interruptions at or before the offset are in the past (dropped),
    /// price steps collapse to the factor in effect at the offset, and the
    /// seasonal phase advances. The exponential clock (memoryless) and the
    /// Weibull hazard (instance-age-driven) are shift-invariant, so the
    /// default market — and any `offset = 0.0` — is untouched.
    pub fn shifted(&self, offset: f64) -> MarketSpec {
        if offset == 0.0 {
            return self.clone();
        }
        let revocation = match &self.revocation {
            RevocationSpec::Exponential => RevocationSpec::Exponential,
            w @ RevocationSpec::Weibull { .. } => w.clone(),
            RevocationSpec::Seasonal { mean_secs, period_secs, amplitude, phase_secs } => {
                RevocationSpec::Seasonal {
                    mean_secs: *mean_secs,
                    period_secs: *period_secs,
                    amplitude: *amplitude,
                    phase_secs: phase_secs + offset,
                }
            }
            RevocationSpec::Trace { times } => RevocationSpec::Trace {
                // Instants at or before the offset can no longer fire
                // (sampling is strictly-after-now on the local clock).
                times: times.iter().filter(|&&t| t > offset).map(|&t| t - offset).collect(),
            },
        };
        let price = match &self.price {
            PriceSpec::Constant => PriceSpec::Constant,
            PriceSpec::Steps(points) => {
                // Collapse history into the factor in effect at the offset,
                // re-anchored as a step at local t = 0.
                let at_offset = PriceSeries::Steps(points.clone()).factor_at(offset);
                let mut shifted: Vec<(f64, f64)> = vec![(0.0, at_offset)];
                shifted.extend(
                    points.iter().filter(|&&(at, _)| at > offset).map(|&(at, f)| (at - offset, f)),
                );
                PriceSpec::Steps(shifted)
            }
        };
        MarketSpec { revocation, price, bid_factor: self.bid_factor }
    }

    /// Assemble the runtime model. `k_r` is the job's
    /// `revocation_mean_secs`, consumed only by the exponential default
    /// (the other processes carry their own parameters).
    pub fn build(&self, k_r: Option<f64>) -> MarketModel {
        let revocation: Box<dyn super::RevocationProcess> = match &self.revocation {
            RevocationSpec::Exponential => match k_r {
                Some(k) => Box::new(ExponentialProcess::new(k)),
                None => Box::new(NoRevocations),
            },
            RevocationSpec::Weibull { scale_secs, shape } => {
                // Programmatic-construction guards (TOML parsing already
                // enforces these): out-of-range parameters would silently
                // produce garbage samples, so they are programming errors.
                assert!(
                    scale_secs.is_finite()
                        && *scale_secs > 0.0
                        && shape.is_finite()
                        && *shape > 0.0,
                    "weibull scale/shape must be finite and positive"
                );
                Box::new(WeibullProcess { scale_secs: *scale_secs, shape: *shape })
            }
            RevocationSpec::Seasonal { mean_secs, period_secs, amplitude, phase_secs } => {
                assert!(
                    mean_secs.is_finite()
                        && *mean_secs > 0.0
                        && period_secs.is_finite()
                        && *period_secs > 0.0
                        && (0.0..1.0).contains(amplitude)
                        && phase_secs.is_finite()
                        && *phase_secs >= 0.0,
                    "seasonal parameters out of range (amplitude must be in [0, 1))"
                );
                Box::new(SeasonalProcess {
                    mean_secs: *mean_secs,
                    period_secs: *period_secs,
                    amplitude: *amplitude,
                    phase_secs: *phase_secs,
                })
            }
            RevocationSpec::Trace { times } => {
                // Same programmatic-construction guard as `price_series`:
                // out-of-order instants would replay wrongly. (Empty is
                // fine — `shifted` drops instants that are in the past.)
                assert!(
                    times.iter().all(|t| t.is_finite() && *t >= 0.0)
                        && times.windows(2).all(|w| w[0] < w[1]),
                    "revocation trace times must be finite, non-negative, strictly increasing"
                );
                Box::new(TraceReplay { times: times.clone() })
            }
        };
        MarketModel { revocation, price: self.price_series(), bid_factor: self.bid_factor }
    }

    /// Parse a `[market]` table. `base` is the spec file's directory, used
    /// to resolve relative `*_file` references. Rejects unknown keys — and
    /// parameters belonging to a different revocation/price kind — naming
    /// the offending key.
    pub fn from_table(tbl: &Tbl, base: Option<&Path>) -> anyhow::Result<MarketSpec> {
        let get_str = |key: &str| -> anyhow::Result<Option<&str>> {
            match tbl.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_str()
                    .map(Some)
                    .ok_or_else(|| anyhow::anyhow!("[market] {key} must be a string")),
            }
        };
        let get_pos = |key: &str| -> anyhow::Result<Option<f64>> {
            match tbl.get(key) {
                None => Ok(None),
                Some(v) => {
                    let x = v
                        .as_float()
                        .ok_or_else(|| anyhow::anyhow!("[market] {key} must be a number"))?;
                    anyhow::ensure!(
                        x.is_finite() && x > 0.0,
                        "[market] {key} must be positive, got {x}"
                    );
                    Ok(Some(x))
                }
            }
        };
        let need_pos = |key: &str, kind: &str| -> anyhow::Result<f64> {
            get_pos(key)?
                .ok_or_else(|| anyhow::anyhow!("[market] revocation = \"{kind}\" needs {key}"))
        };
        let num_list = |key: &str| -> anyhow::Result<Option<Vec<f64>>> {
            match tbl.get(key) {
                None => Ok(None),
                Some(v) => {
                    let items = v.as_array().ok_or_else(|| {
                        anyhow::anyhow!("[market] {key} must be an array of numbers")
                    })?;
                    items
                        .iter()
                        .map(|x| {
                            x.as_float().ok_or_else(|| {
                                anyhow::anyhow!("[market] {key} entries must be numbers")
                            })
                        })
                        .collect::<anyhow::Result<Vec<_>>>()
                        .map(Some)
                }
            }
        };

        let rev_kind = get_str("revocation")?.unwrap_or("exponential");
        let revocation = match rev_kind {
            "exponential" => RevocationSpec::Exponential,
            "weibull" => RevocationSpec::Weibull {
                scale_secs: need_pos("scale_secs", "weibull")?,
                shape: need_pos("shape", "weibull")?,
            },
            "seasonal" => {
                let amplitude = match tbl.get("amplitude") {
                    None => 0.0,
                    Some(v) => v.as_float().ok_or_else(|| {
                        anyhow::anyhow!("[market] amplitude must be a number")
                    })?,
                };
                anyhow::ensure!(
                    (0.0..1.0).contains(&amplitude),
                    "[market] amplitude must be in [0, 1), got {amplitude}"
                );
                let phase_secs = match tbl.get("phase_secs") {
                    None => 0.0,
                    Some(v) => {
                        let p = v.as_float().ok_or_else(|| {
                            anyhow::anyhow!("[market] phase_secs must be a number")
                        })?;
                        anyhow::ensure!(
                            p.is_finite() && p >= 0.0,
                            "[market] phase_secs must be non-negative, got {p}"
                        );
                        p
                    }
                };
                RevocationSpec::Seasonal {
                    mean_secs: need_pos("mean_secs", "seasonal")?,
                    period_secs: need_pos("period_secs", "seasonal")?,
                    amplitude,
                    phase_secs,
                }
            }
            "trace" => {
                let inline = num_list("revocation_times")?;
                let file = get_str("revocation_file")?;
                let times = match (inline, file) {
                    (Some(times), None) => times,
                    (None, Some(path)) => load_revocation_trace(&resolve(base, path))?,
                    _ => anyhow::bail!(
                        "[market] revocation = \"trace\" needs exactly one of \
                         revocation_times or revocation_file"
                    ),
                };
                validate_trace_times(&times, "revocation_times")?;
                RevocationSpec::Trace { times }
            }
            other => anyhow::bail!(
                "unknown market revocation {other} (exponential | weibull | seasonal | trace)"
            ),
        };

        let price_kind = get_str("price")?.unwrap_or("constant");
        let price = match price_kind {
            "constant" => PriceSpec::Constant,
            "steps" => {
                let times = num_list("price_times")?;
                let factors = num_list("price_factors")?;
                let file = get_str("price_file")?;
                let points = match (times, factors, file) {
                    (Some(t), Some(f), None) => {
                        anyhow::ensure!(
                            t.len() == f.len(),
                            "[market] price_times has {} entries but price_factors has {}",
                            t.len(),
                            f.len()
                        );
                        t.into_iter().zip(f).collect()
                    }
                    (None, None, Some(path)) => load_price_trace(&resolve(base, path))?,
                    _ => anyhow::bail!(
                        "[market] price = \"steps\" needs either price_times + price_factors \
                         or price_file"
                    ),
                };
                // Validates ordering/positivity; keep the raw points.
                PriceSeries::steps(points.clone())?;
                PriceSpec::Steps(points)
            }
            other => anyhow::bail!("unknown market price {other} (constant | steps)"),
        };

        let bid_factor = get_pos("bid_factor")?;

        // Reject unknown keys — and kind-mismatched parameters — by name.
        let mut allowed: Vec<&str> = vec!["revocation", "price", "bid_factor"];
        match rev_kind {
            "weibull" => allowed.extend(["scale_secs", "shape"]),
            "seasonal" => {
                allowed.extend(["mean_secs", "period_secs", "amplitude", "phase_secs"])
            }
            "trace" => allowed.extend(["revocation_times", "revocation_file"]),
            _ => {}
        }
        if price_kind == "steps" {
            allowed.extend(["price_times", "price_factors", "price_file"]);
        }
        tomlmini::reject_unknown_keys(
            tbl,
            &allowed,
            &format!("[market] (revocation = \"{rev_kind}\", price = \"{price_kind}\")"),
        )?;

        Ok(MarketSpec { revocation, price, bid_factor })
    }
}

/// Parse the `[[market]]` definitions of a sweep/workload spec into a
/// name → spec map. Names must be unique and must not shadow the built-in
/// `"exponential"` default market.
pub fn named_markets(
    root: &Tbl,
    base: Option<&Path>,
) -> anyhow::Result<BTreeMap<String, MarketSpec>> {
    let mut out = BTreeMap::new();
    let Some(tables) = root.get("market") else { return Ok(out) };
    let tables = tables.as_table_array().ok_or_else(|| {
        anyhow::anyhow!("[[market]] must be an array of tables (use [[market]], not [market])")
    })?;
    for (i, tbl) in tables.iter().enumerate() {
        let name = tbl
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("[[market]] #{i} needs a `name`"))?
            .to_string();
        anyhow::ensure!(
            name != "exponential",
            "[[market]] name \"exponential\" is reserved for the built-in default market"
        );
        let mut body = tbl.clone();
        body.remove("name");
        let spec = MarketSpec::from_table(&body, base)
            .map_err(|e| anyhow::anyhow!("[[market]] \"{name}\": {e}"))?;
        anyhow::ensure!(out.insert(name.clone(), spec).is_none(), "duplicate market {name}");
    }
    Ok(out)
}

/// Resolve a market reference from a `markets` grid axis or a per-job
/// `market = "name"` key: a defined name, or the built-in `"exponential"`.
pub fn resolve_market(
    name: &str,
    defs: &BTreeMap<String, MarketSpec>,
) -> anyhow::Result<MarketSpec> {
    if let Some(spec) = defs.get(name) {
        return Ok(spec.clone());
    }
    if name == "exponential" {
        return Ok(MarketSpec::default());
    }
    anyhow::bail!(
        "unknown market {name} (define it as a [[market]] table; built-in: exponential)"
    )
}

fn validate_trace_times(times: &[f64], what: &str) -> anyhow::Result<()> {
    anyhow::ensure!(!times.is_empty(), "[market] {what} is empty");
    let mut prev = f64::NEG_INFINITY;
    for &t in times {
        anyhow::ensure!(
            t.is_finite() && t >= 0.0,
            "[market] {what} entry {t} must be finite and non-negative"
        );
        anyhow::ensure!(t > prev, "[market] {what} must be strictly increasing (got {t})");
        prev = t;
    }
    Ok(())
}

/// Resolve a trace-file reference: the spec directory first (shipped configs
/// reference siblings), then the path as given (crate-root relative).
fn resolve(base: Option<&Path>, path: &str) -> PathBuf {
    if let Some(dir) = base {
        let joined = dir.join(path);
        if joined.exists() {
            return joined;
        }
    }
    PathBuf::from(path)
}

fn read_trace_file(path: &Path) -> anyhow::Result<Tbl> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading market trace {}: {e}", path.display()))?;
    Ok(tomlmini::parse(&text)?)
}

/// A revocation trace file: `[[revocation]]` tables with `at_secs`.
fn load_revocation_trace(path: &Path) -> anyhow::Result<Vec<f64>> {
    let root = read_trace_file(path)?;
    let entries = root
        .get("revocation")
        .and_then(|v| v.as_table_array())
        .ok_or_else(|| {
            anyhow::anyhow!("{}: expected [[revocation]] tables", path.display())
        })?;
    entries
        .iter()
        .map(|e| {
            e.get("at_secs").and_then(|v| v.as_float()).ok_or_else(|| {
                anyhow::anyhow!("{}: every [[revocation]] needs at_secs", path.display())
            })
        })
        .collect()
}

/// A price trace file (AWS spot-price-history shape): `[[step]]` tables with
/// `at_secs` and `factor`.
fn load_price_trace(path: &Path) -> anyhow::Result<Vec<(f64, f64)>> {
    let root = read_trace_file(path)?;
    let entries = root.get("step").and_then(|v| v.as_table_array()).ok_or_else(|| {
        anyhow::anyhow!("{}: expected [[step]] tables", path.display())
    })?;
    entries
        .iter()
        .map(|e| {
            let at = e.get("at_secs").and_then(|v| v.as_float());
            let factor = e.get("factor").and_then(|v| v.as_float());
            match (at, factor) {
                (Some(a), Some(f)) => Ok((a, f)),
                _ => anyhow::bail!(
                    "{}: every [[step]] needs at_secs and factor",
                    path.display()
                ),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> anyhow::Result<MarketSpec> {
        let root = tomlmini::parse(text).unwrap();
        MarketSpec::from_table(&root, None)
    }

    #[test]
    fn defaults_to_the_historical_market() {
        let spec = parse("").unwrap();
        assert!(spec.is_default());
        assert_eq!(spec.revocation.key(), "exponential");
        assert_eq!(spec.price.key(), "constant");
        assert_eq!(spec.planning_price_factor(1e6), 1.0);
    }

    #[test]
    fn parses_every_revocation_kind() {
        let w = parse("revocation = \"weibull\"\nscale_secs = 7200.0\nshape = 0.7\n").unwrap();
        assert_eq!(w.revocation, RevocationSpec::Weibull { scale_secs: 7200.0, shape: 0.7 });
        let s = parse(
            "revocation = \"seasonal\"\nmean_secs = 7200.0\nperiod_secs = 86400.0\namplitude = 0.5\n",
        )
        .unwrap();
        assert_eq!(
            s.revocation,
            RevocationSpec::Seasonal {
                mean_secs: 7200.0,
                period_secs: 86400.0,
                amplitude: 0.5,
                phase_secs: 0.0,
            }
        );
        let t = parse("revocation = \"trace\"\nrevocation_times = [100.0, 900.0]\n").unwrap();
        assert_eq!(t.revocation, RevocationSpec::Trace { times: vec![100.0, 900.0] });
    }

    #[test]
    fn parses_price_steps_and_bid() {
        let spec = parse(
            "price = \"steps\"\nprice_times = [0.0, 3600.0]\nprice_factors = [1.0, 1.8]\nbid_factor = 1.5\n",
        )
        .unwrap();
        assert_eq!(spec.price, PriceSpec::Steps(vec![(0.0, 1.0), (3600.0, 1.8)]));
        assert_eq!(spec.bid_factor, Some(1.5));
        // The assembled model revokes at the crossing.
        let model = spec.build(None);
        let mut rng = crate::simul::Rng::seeded(1);
        let at = model.revocation_at(crate::simul::SimTime::ZERO, &mut rng).unwrap();
        assert_eq!(at.secs(), 3600.0);
    }

    #[test]
    fn rejects_unknown_keys_by_name() {
        let err = parse("oops = 1\n").unwrap_err().to_string();
        assert!(err.contains("unknown key `oops`"), "{err}");
        // Parameters of a *different* kind are offending keys too.
        let err = parse("shape = 2.0\n").unwrap_err().to_string();
        assert!(err.contains("unknown key `shape`"), "{err}");
        let err = parse(
            "revocation = \"weibull\"\nscale_secs = 10.0\nshape = 1.0\nprice_times = [0.0]\n",
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown key `price_times`"), "{err}");
    }

    #[test]
    fn rejects_malformed_parameters() {
        assert!(parse("revocation = \"weibull\"\n").is_err(), "missing params");
        assert!(parse("revocation = \"weibull\"\nscale_secs = -1.0\nshape = 1.0\n").is_err());
        assert!(parse(
            "revocation = \"seasonal\"\nmean_secs = 10.0\nperiod_secs = 10.0\namplitude = 1.0\n"
        )
        .is_err());
        assert!(parse("revocation = \"trace\"\n").is_err(), "no times");
        assert!(
            parse("revocation = \"trace\"\nrevocation_times = [5.0, 5.0]\n").is_err(),
            "non-increasing trace"
        );
        assert!(parse("revocation = \"nope\"\n").is_err());
        assert!(parse("price = \"steps\"\n").is_err(), "no points");
        assert!(parse(
            "price = \"steps\"\nprice_times = [0.0, 1.0]\nprice_factors = [1.0]\n"
        )
        .is_err());
        assert!(parse("bid_factor = 0.0\n").is_err());
    }

    #[test]
    fn shifted_re_anchors_the_market_on_the_cluster_clock() {
        let spec = MarketSpec {
            revocation: RevocationSpec::Trace { times: vec![100.0, 500.0, 900.0] },
            price: PriceSpec::Steps(vec![(0.0, 1.0), (400.0, 2.0), (800.0, 0.5)]),
            bid_factor: Some(1.5),
        };
        let s = spec.shifted(450.0);
        // Past interruptions drop; future ones re-anchor on the local clock.
        assert_eq!(s.revocation, RevocationSpec::Trace { times: vec![50.0, 450.0] });
        // Price history collapses to the factor in effect at the offset.
        assert_eq!(s.price, PriceSpec::Steps(vec![(0.0, 2.0), (350.0, 0.5)]));
        assert_eq!(s.bid_factor, Some(1.5));
        // Offset 0 and the default market are no-ops.
        assert_eq!(spec.shifted(0.0), spec);
        assert!(MarketSpec::default().shifted(1234.5).is_default());
        // Seasonal advances its phase; exponential is memoryless.
        let seasonal = MarketSpec {
            revocation: RevocationSpec::Seasonal {
                mean_secs: 10.0,
                period_secs: 20.0,
                amplitude: 0.5,
                phase_secs: 5.0,
            },
            ..MarketSpec::default()
        };
        match seasonal.shifted(7.0).revocation {
            RevocationSpec::Seasonal { phase_secs, .. } => assert_eq!(phase_secs, 12.0),
            other => panic!("unexpected revocation spec {other:?}"),
        }
    }

    #[test]
    fn named_markets_resolve_and_reserve_the_default() {
        let root = tomlmini::parse(
            r#"
[[market]]
name = "volatile"
price = "steps"
price_times = [0.0]
price_factors = [2.0]
"#,
        )
        .unwrap();
        let defs = named_markets(&root, None).unwrap();
        assert_eq!(defs.len(), 1);
        assert!(resolve_market("volatile", &defs).is_ok());
        assert!(resolve_market("exponential", &defs).unwrap().is_default());
        assert!(resolve_market("nope", &defs).is_err());

        let reserved = tomlmini::parse("[[market]]\nname = \"exponential\"\n").unwrap();
        assert!(named_markets(&reserved, None).is_err());
        let unnamed = tomlmini::parse("[[market]]\nprice = \"constant\"\n").unwrap();
        assert!(named_markets(&unnamed, None).is_err());
    }

    #[test]
    fn trace_files_load_and_resolve_against_base() {
        let dir = std::env::temp_dir().join(format!("mfls-market-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("price.toml"),
            "[[step]]\nat_secs = 0.0\nfactor = 1.0\n\n[[step]]\nat_secs = 60.0\nfactor = 1.2\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("revs.toml"),
            "[[revocation]]\nat_secs = 30.0\n\n[[revocation]]\nat_secs = 90.0\n",
        )
        .unwrap();
        let root = tomlmini::parse(
            "revocation = \"trace\"\nrevocation_file = \"revs.toml\"\nprice = \"steps\"\nprice_file = \"price.toml\"\n",
        )
        .unwrap();
        let spec = MarketSpec::from_table(&root, Some(&dir)).unwrap();
        assert_eq!(spec.revocation, RevocationSpec::Trace { times: vec![30.0, 90.0] });
        assert_eq!(spec.price, PriceSpec::Steps(vec![(0.0, 1.0), (60.0, 1.2)]));
        // A missing file is a named error, not a panic.
        let bad = tomlmini::parse(
            "revocation = \"trace\"\nrevocation_file = \"missing.toml\"\n",
        )
        .unwrap();
        assert!(MarketSpec::from_table(&bad, Some(&dir)).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
