//! Time-varying spot prices.
//!
//! A [`PriceSeries`] is a multiplier over the catalog's base spot rate as a
//! piecewise-constant step function of simulated time — the shape of an AWS
//! spot-price-history export. The constant series (factor 1.0 forever) is
//! today's fixed-rate market and is arithmetically a no-op: every query
//! returns the same bits as the historical fixed-rate code paths, which the
//! default-market parity tests rely on.
//!
//! Steps are left-closed: a step `(at, factor)` puts `factor` in effect from
//! `at` *inclusive*. A billing interval that closes exactly on a step edge
//! therefore never pays the new price — integration is over `[start, end)`
//! (see [`PriceSeries::weighted_secs`]), which is what makes billing at the
//! revocation boundary segment-accurate.

/// A spot-price multiplier over simulated time.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum PriceSeries {
    /// Factor 1.0 forever (the historical fixed-rate market).
    #[default]
    Constant,
    /// Piecewise-constant steps `(at_secs, factor)` with strictly increasing
    /// times; factor 1.0 applies before the first step.
    Steps(Vec<(f64, f64)>),
}

impl PriceSeries {
    /// Build a step series, validating the trace shape.
    pub fn steps(points: Vec<(f64, f64)>) -> anyhow::Result<PriceSeries> {
        anyhow::ensure!(!points.is_empty(), "price series needs at least one step");
        let mut prev = f64::NEG_INFINITY;
        for &(at, factor) in &points {
            anyhow::ensure!(
                at.is_finite() && at >= 0.0,
                "price step time {at} must be finite and non-negative"
            );
            anyhow::ensure!(at > prev, "price step times must be strictly increasing (got {at})");
            anyhow::ensure!(
                factor.is_finite() && factor > 0.0,
                "price factor {factor} must be finite and positive"
            );
            prev = at;
        }
        Ok(PriceSeries::Steps(points))
    }

    /// Multiplier in effect at instant `t` (the last step at or before `t`;
    /// 1.0 before the first step). Binary search over the validated
    /// strictly-increasing step times — this is the outlook's window-integral
    /// hot path, queried per candidate per revocation.
    pub fn factor_at(&self, t: f64) -> f64 {
        match self {
            PriceSeries::Constant => 1.0,
            PriceSeries::Steps(points) => {
                // partition_point keeps the left-closed edge semantics of the
                // former linear scan: a step at exactly `t` is in effect.
                let idx = points.partition_point(|&(at, _)| at <= t);
                if idx == 0 {
                    1.0
                } else {
                    points[idx - 1].1
                }
            }
        }
    }

    /// Factor-weighted seconds: `∫ factor(t) dt` over `[start, end)`,
    /// clamped to 0 for empty intervals. The constant series returns exactly
    /// `(end - start).max(0.0)` — the historical fixed-rate duration — so
    /// `rate · weighted_secs` is bit-identical to the pre-market ledger.
    pub fn weighted_secs(&self, start: f64, end: f64) -> f64 {
        match self {
            PriceSeries::Constant => (end - start).max(0.0),
            PriceSeries::Steps(points) => {
                if end <= start {
                    return 0.0;
                }
                let mut total = 0.0;
                let mut seg_start = start;
                let mut f = self.factor_at(start);
                for &(at, factor) in points {
                    if at <= start {
                        continue; // already reflected in factor_at(start)
                    }
                    if at >= end {
                        break;
                    }
                    total += f * (at - seg_start);
                    seg_start = at;
                    f = factor;
                }
                total + f * (end - seg_start)
            }
        }
    }

    /// Mean factor over the planning horizon `[0, horizon_secs)` — the
    /// expected spot-price multiplier the Initial Mapping and Dynamic
    /// Scheduler cost models use. Degenerate horizons (zero, non-finite)
    /// fall back to the factor at t = 0; the constant series is always 1.0.
    pub fn mean_factor(&self, horizon_secs: f64) -> f64 {
        match self {
            PriceSeries::Constant => 1.0,
            PriceSeries::Steps(_) => {
                if horizon_secs.is_finite() && horizon_secs > 0.0 {
                    self.weighted_secs(0.0, horizon_secs) / horizon_secs
                } else {
                    self.factor_at(0.0)
                }
            }
        }
    }

    /// First step instant strictly after `t` whose factor exceeds `bid` —
    /// the eviction instant of a bid-priced spot VM provisioned at `t`.
    /// Acquisition itself is honored even when the price at `t` already
    /// exceeds the bid (the engine's events are strictly-after-now): such a
    /// VM is evicted only by the next step still above the bid, if any.
    /// `None` = the bid is never outbid again.
    pub fn first_crossing_above(&self, t: f64, bid: f64) -> Option<f64> {
        match self {
            PriceSeries::Constant => None,
            PriceSeries::Steps(points) => points
                .iter()
                .find(|&&(at, factor)| at > t && factor > bid)
                .map(|&(at, _)| at),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn series() -> PriceSeries {
        // 1.0 until t=100, 2.0 until t=300, then 0.5.
        PriceSeries::steps(vec![(0.0, 1.0), (100.0, 2.0), (300.0, 0.5)]).unwrap()
    }

    #[test]
    fn constant_is_identity() {
        let c = PriceSeries::Constant;
        assert_eq!(c.factor_at(0.0), 1.0);
        assert_eq!(c.factor_at(1e9), 1.0);
        // Bit-exact: weighted seconds of the constant series are the plain
        // duration, including the negative-interval clamp.
        let (a, b) = (123.456789, 7890.12345);
        assert_eq!(c.weighted_secs(a, b).to_bits(), (b - a).max(0.0).to_bits());
        assert_eq!(c.weighted_secs(b, a), 0.0);
        assert_eq!(c.mean_factor(1e4), 1.0);
        assert_eq!(c.first_crossing_above(0.0, 1.0), None);
    }

    #[test]
    fn factor_lookup_is_left_closed() {
        let s = series();
        assert_eq!(s.factor_at(0.0), 1.0);
        assert_eq!(s.factor_at(99.999), 1.0);
        assert_eq!(s.factor_at(100.0), 2.0, "step edge belongs to the new price");
        assert_eq!(s.factor_at(299.0), 2.0);
        assert_eq!(s.factor_at(300.0), 0.5);
        assert_eq!(s.factor_at(1e9), 0.5);
        // Before the first step the factor is 1.0.
        let late = PriceSeries::steps(vec![(50.0, 3.0)]).unwrap();
        assert_eq!(late.factor_at(0.0), 1.0);
        assert_eq!(late.factor_at(49.0), 1.0);
    }

    #[test]
    fn binary_search_lookup_matches_the_linear_scan_bit_for_bit() {
        // Regression for the partition_point rewrite of `factor_at`: pin it
        // against the former linear scan at every step edge, just around the
        // edges, and well outside the trace — identical bits everywhere.
        let linear_scan = |points: &[(f64, f64)], t: f64| -> f64 {
            let mut f = 1.0;
            for &(at, factor) in points {
                if at <= t {
                    f = factor;
                } else {
                    break;
                }
            }
            f
        };
        let points = vec![(0.0, 1.0), (100.0, 2.0), (300.0, 0.5), (1e6, 3.25)];
        let s = PriceSeries::steps(points.clone()).unwrap();
        let mut probes: Vec<f64> = vec![-1.0, -1e-9, 1e9, f64::INFINITY];
        for &(at, _) in &points {
            probes.extend([at - 1e-9, at, at + 1e-9, at + 50.0]);
        }
        for t in probes {
            assert_eq!(
                s.factor_at(t).to_bits(),
                linear_scan(&points, t).to_bits(),
                "divergence at t={t}"
            );
        }
        // A series starting after t=0 still reads 1.0 before its first step.
        let late = PriceSeries::steps(vec![(50.0, 3.0)]).unwrap();
        assert_eq!(late.factor_at(49.999).to_bits(), 1.0f64.to_bits());
        assert_eq!(late.factor_at(50.0).to_bits(), 3.0f64.to_bits());
    }

    #[test]
    fn weighted_secs_hand_computed_segments() {
        let s = series();
        // [0, 100): 100·1.0; [100, 300): 200·2.0; [300, 400): 100·0.5.
        assert!((s.weighted_secs(0.0, 400.0) - (100.0 + 400.0 + 50.0)).abs() < 1e-9);
        // Interval entirely inside one segment.
        assert!((s.weighted_secs(120.0, 180.0) - 120.0).abs() < 1e-9);
        // Interval straddling one edge.
        assert!((s.weighted_secs(50.0, 150.0) - (50.0 + 100.0)).abs() < 1e-9);
        // Closing exactly on an edge pays only the pre-step price.
        assert!((s.weighted_secs(50.0, 100.0) - 50.0).abs() < 1e-9);
        // Empty/inverted intervals.
        assert_eq!(s.weighted_secs(200.0, 200.0), 0.0);
        assert_eq!(s.weighted_secs(300.0, 200.0), 0.0);
    }

    #[test]
    fn mean_factor_over_horizon() {
        let s = series();
        // Over [0, 200): (100·1 + 100·2)/200 = 1.5.
        assert!((s.mean_factor(200.0) - 1.5).abs() < 1e-12);
        // Degenerate horizons fall back to the t=0 factor.
        assert_eq!(s.mean_factor(0.0), 1.0);
        assert_eq!(s.mean_factor(f64::INFINITY), 1.0);
    }

    #[test]
    fn bid_crossing_finds_first_exceeding_step() {
        let s = series();
        assert_eq!(s.first_crossing_above(0.0, 1.5), Some(100.0));
        assert_eq!(s.first_crossing_above(100.0, 1.5), None, "strictly-after semantics");
        assert_eq!(s.first_crossing_above(0.0, 2.0), None, "equal factor does not outbid");
        assert_eq!(s.first_crossing_above(0.0, 0.4), Some(100.0));
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(PriceSeries::steps(vec![]).is_err());
        assert!(PriceSeries::steps(vec![(0.0, 1.0), (0.0, 2.0)]).is_err(), "non-increasing");
        assert!(PriceSeries::steps(vec![(-1.0, 1.0)]).is_err());
        assert!(PriceSeries::steps(vec![(0.0, 0.0)]).is_err(), "zero factor");
        assert!(PriceSeries::steps(vec![(0.0, f64::NAN)]).is_err());
    }
}
