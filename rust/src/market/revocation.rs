//! Pluggable spot-revocation processes.
//!
//! The paper models preemption as a fixed-rate Poisson clock (§5.6's `k_r`);
//! real spot markets have provider-specific interruption behaviour, hazard
//! rates that change with instance age, time-of-day seasonality, and — when
//! replaying recorded histories — fully deterministic interruption
//! timestamps. Each of those is one [`RevocationProcess`] implementation;
//! the platform ([`crate::cloudsim::MultiCloud`]) pre-samples a revocation
//! instant from the process at provisioning time, exactly where the inline
//! exponential draw used to live.
//!
//! Determinism contract: a process may only draw from the `rng` handed to
//! [`RevocationProcess::sample`] (the platform's provisioning stream), and
//! [`ExponentialProcess`] performs *exactly one* `exponential` draw per
//! sample — the same expression, in the same stream order, as the historical
//! inline code — so the default market is bit-identical to the pre-market
//! simulator (`tests/market_parity.rs`).

use crate::simul::{Rng, SimTime};

/// Samples the preemption instant of a spot VM at provisioning time.
pub trait RevocationProcess: Send + Sync + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Revocation instant for a spot VM provisioned at `now`, or `None` for
    /// "never revoked". `rng` is the platform's provisioning stream; draws
    /// must be a pure function of (process parameters, `now`, stream state).
    fn sample(&self, now: SimTime, rng: &mut Rng) -> Option<SimTime>;
}

/// Revocations disabled (`k_r = None`); never touches the stream.
#[derive(Debug, Clone, Copy)]
pub struct NoRevocations;

impl RevocationProcess for NoRevocations {
    fn name(&self) -> &'static str {
        "none"
    }

    fn sample(&self, _now: SimTime, _rng: &mut Rng) -> Option<SimTime> {
        None
    }
}

/// The paper's fixed-rate Poisson clock: exponential time-to-revocation with
/// mean `k_r` seconds from the moment the instance starts (§5.6).
#[derive(Debug, Clone, Copy)]
pub struct ExponentialProcess {
    pub mean_secs: f64,
}

impl ExponentialProcess {
    pub fn new(mean_secs: f64) -> Self {
        assert!(mean_secs > 0.0);
        Self { mean_secs }
    }
}

impl RevocationProcess for ExponentialProcess {
    fn name(&self) -> &'static str {
        "exponential"
    }

    fn sample(&self, now: SimTime, rng: &mut Rng) -> Option<SimTime> {
        // Verbatim the historical inline draw (one stream advance).
        Some(now + rng.exponential(1.0 / self.mean_secs))
    }
}

/// Age-dependent hazard: Weibull time-to-revocation. `shape < 1` models the
/// empirical "young instances die fast" regime (interruption risk decays
/// with age); `shape > 1` models wear-out; `shape = 1` degenerates to
/// [`ExponentialProcess`] with mean `scale_secs` (asserted in the tests).
#[derive(Debug, Clone, Copy)]
pub struct WeibullProcess {
    /// Scale λ in seconds (the 63rd-percentile lifetime).
    pub scale_secs: f64,
    /// Shape k (> 0).
    pub shape: f64,
}

impl RevocationProcess for WeibullProcess {
    fn name(&self) -> &'static str {
        "weibull"
    }

    fn sample(&self, now: SimTime, rng: &mut Rng) -> Option<SimTime> {
        // Inverse-CDF: age = λ·(-ln U)^(1/k), with U in (0, 1]. For k = 1
        // this is exactly the exponential draw's expression.
        let u = rng.next_f64_open();
        let age = self.scale_secs * (-u.ln()).powf(1.0 / self.shape);
        Some(now + age)
    }
}

/// Time-of-day modulated Poisson process: rate
/// `λ(t) = (1 + amplitude·sin(2π·t/period)) / mean_secs`, so interruption
/// pressure peaks once per period (e.g. business hours) and relaxes half a
/// period later. Sampled by inversion of the integrated hazard, which is
/// available in closed form; the root is isolated by doubling and bisection,
/// so one sample costs exactly one stream advance.
#[derive(Debug, Clone, Copy)]
pub struct SeasonalProcess {
    /// Time-averaged mean time between revocations, seconds.
    pub mean_secs: f64,
    /// Modulation period, seconds (86 400 = diurnal).
    pub period_secs: f64,
    /// Modulation depth in [0, 1): 0 = plain exponential.
    pub amplitude: f64,
    /// Phase offset added to the local clock — aligns a simulation whose
    /// local t = 0 is some later cluster instant with the shared timeline
    /// (see `MarketSpec::shifted`).
    pub phase_secs: f64,
}

impl SeasonalProcess {
    /// Integrated hazard `Λ(a, b) = ∫_a^b λ(t) dt` (closed form).
    fn integrated_hazard(&self, a: f64, b: f64) -> f64 {
        let w = std::f64::consts::TAU / self.period_secs;
        let sine_term = self.amplitude / w * ((w * a).cos() - (w * b).cos());
        ((b - a) + sine_term) / self.mean_secs
    }
}

impl RevocationProcess for SeasonalProcess {
    fn name(&self) -> &'static str {
        "seasonal"
    }

    fn sample(&self, now: SimTime, rng: &mut Rng) -> Option<SimTime> {
        // Inversion: find x with Λ(now, now+x) = E, E ~ Exp(1). Λ is
        // continuous and strictly increasing in x (amplitude < 1 keeps the
        // rate positive), so doubling + bisection converges to full f64
        // precision deterministically.
        let e = -rng.next_f64_open().ln();
        let t0 = now.secs() + self.phase_secs;
        let mut hi = self.mean_secs.max(1.0);
        while self.integrated_hazard(t0, t0 + hi) < e {
            hi *= 2.0;
        }
        let mut lo = 0.0;
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if mid <= lo || mid >= hi {
                break; // interval at f64 resolution
            }
            if self.integrated_hazard(t0, t0 + mid) < e {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(now + hi)
    }
}

/// Replays recorded interruption timestamps (a provider history export): a
/// VM provisioned at `now` is pre-assigned the first trace instant strictly
/// after `now`, so one recorded capacity reclaim threatens every VM alive
/// at it — correlated interruptions, unlike the independent per-VM clocks.
/// Consumes no randomness; a trace-replay market is fully deterministic
/// even across replacement VMs. (The event loop keeps its established
/// one-revocation-per-event semantics: instants that land inside the
/// replacement's boot wait are absorbed, exactly as they always were for
/// coinciding exponential draws.)
#[derive(Debug, Clone)]
pub struct TraceReplay {
    /// Interruption instants, seconds, strictly increasing.
    pub times: Vec<f64>,
}

impl RevocationProcess for TraceReplay {
    fn name(&self) -> &'static str {
        "trace"
    }

    fn sample(&self, now: SimTime, _rng: &mut Rng) -> Option<SimTime> {
        let t = now.secs();
        self.times.iter().find(|&&at| at > t).map(|&at| SimTime::from_secs(at))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_matches_historical_inline_draw() {
        // The process must advance the stream exactly like the old inline
        // `rng.exponential(1.0 / k_r)` — same expression, same order.
        let proc_ = ExponentialProcess::new(7200.0);
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..50 {
            let now = SimTime::from_secs(a.uniform(0.0, 1e5));
            let _ = b.uniform(0.0, 1e5); // keep streams aligned
            let got = proc_.sample(now, &mut a).unwrap();
            let want = now + b.exponential(1.0 / 7200.0);
            assert_eq!(got.secs().to_bits(), want.secs().to_bits());
        }
    }

    #[test]
    fn weibull_shape_one_is_exponential() {
        let w = WeibullProcess { scale_secs: 3600.0, shape: 1.0 };
        let mut a = Rng::seeded(7);
        let mut b = Rng::seeded(7);
        let got = w.sample(SimTime::ZERO, &mut a).unwrap();
        let u = b.next_f64_open();
        let want = 3600.0 * (-u.ln());
        assert!((got.secs() - want).abs() < 1e-9);
    }

    #[test]
    fn weibull_mean_matches_gamma_formula() {
        // E[Weibull(λ, k)] = λ·Γ(1 + 1/k); for k = 2, Γ(1.5) = √π/2.
        let w = WeibullProcess { scale_secs: 1000.0, shape: 2.0 };
        let mut rng = Rng::seeded(3);
        let n = 50_000;
        let mean: f64 = (0..n)
            .map(|_| w.sample(SimTime::ZERO, &mut rng).unwrap().secs())
            .sum::<f64>()
            / n as f64;
        let expected = 1000.0 * std::f64::consts::PI.sqrt() / 2.0;
        assert!((mean - expected).abs() < expected * 0.03, "mean={mean}");
    }

    #[test]
    fn seasonal_zero_amplitude_has_exponential_mean() {
        let s = SeasonalProcess {
            mean_secs: 5000.0,
            period_secs: 86_400.0,
            amplitude: 0.0,
            phase_secs: 0.0,
        };
        let mut rng = Rng::seeded(5);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| s.sample(SimTime::ZERO, &mut rng).unwrap().secs())
            .sum::<f64>()
            / n as f64;
        assert!((mean - 5000.0).abs() < 5000.0 * 0.05, "mean={mean}");
    }

    #[test]
    fn seasonal_hazard_inversion_is_consistent() {
        // Λ(now, sample) must equal the implied exponential deviate: verify
        // by inverting the sample back through the closed-form hazard.
        let s = SeasonalProcess {
            mean_secs: 3600.0,
            period_secs: 7200.0,
            amplitude: 0.8,
            phase_secs: 0.0,
        };
        let mut a = Rng::seeded(11);
        let mut b = Rng::seeded(11);
        for _ in 0..100 {
            let got = s.sample(SimTime::from_secs(500.0), &mut a).unwrap();
            let e = -b.next_f64_open().ln();
            let lambda = s.integrated_hazard(500.0, got.secs());
            assert!((lambda - e).abs() < 1e-6, "Λ={lambda} vs E={e}");
        }
    }

    #[test]
    fn seasonal_revokes_more_during_peak() {
        // Deep modulation with the period much longer than the mean life:
        // a VM provisioned at the rate peak (sin = +1, t = period/4) lives
        // its whole typical lifetime under ≈1.95× hazard, one provisioned
        // at the trough under ≈0.05× — the sample means must be far apart.
        let s = SeasonalProcess {
            mean_secs: 10_000.0,
            period_secs: 40_000.0,
            amplitude: 0.95,
            phase_secs: 0.0,
        };
        let mut rng = Rng::seeded(9);
        let n = 5_000;
        let avg_from = |t0: f64, rng: &mut Rng| -> f64 {
            (0..n)
                .map(|_| s.sample(SimTime::from_secs(t0), rng).unwrap().secs() - t0)
                .sum::<f64>()
                / n as f64
        };
        let peak = avg_from(10_000.0, &mut rng); // sin(2π·10000/40000) = 1
        let trough = avg_from(30_000.0, &mut rng); // sin(2π·30000/40000) = −1
        assert!(peak * 1.5 < trough, "peak={peak} trough={trough}");
    }

    #[test]
    fn trace_replay_returns_first_instant_strictly_after() {
        let t = TraceReplay { times: vec![100.0, 250.0, 900.0] };
        let mut rng = Rng::seeded(1);
        let at = |now: f64| t.sample(SimTime::from_secs(now), &mut rng).map(|s| s.secs());
        assert_eq!(at(0.0), Some(100.0));
        assert_eq!(at(100.0), Some(250.0), "a VM provisioned at an event survives it");
        assert_eq!(at(899.9), Some(900.0));
        assert_eq!(at(900.0), None, "trace exhausted");
        // No randomness consumed: the stream is untouched.
        let mut fresh = Rng::seeded(1);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn no_revocations_never_fires_nor_draws() {
        let mut rng = Rng::seeded(2);
        assert!(NoRevocations.sample(SimTime::ZERO, &mut rng).is_none());
        let mut fresh = Rng::seeded(2);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }
}
