//! Dynamic Scheduler module (§4.4): Algorithms 1–3.
//!
//! When the Fault Tolerance module detects a revocation (or runtime error),
//! this module selects the replacement VM for the faulty task with a greedy
//! heuristic: for every candidate instance it re-computes the expected round
//! makespan (Algorithm 1) and financial cost (Algorithm 2) of the *whole*
//! current placement with the candidate substituted in, scores the pair with
//! the same normalized weighted objective as the Initial Mapping
//! (`α·cost/cost_max + (1-α)·makespan/T_max`), and picks the minimum
//! (Algorithm 3).
//!
//! Policy knob: the paper observed that a revoked spot type cannot be
//! immediately re-allocated in the same AWS region ([47]), so Algorithm 3
//! removes the revoked type from the candidate set. CloudLab allows instant
//! re-allocation, which Table 6 exploits by keeping the revoked type; this is
//! [`DynSchedPolicy::remove_revoked`].
//!
//! The simulated pipeline consults this module through the pluggable
//! `DynScheduler` trait (`crate::framework::modules`); candidate ranking
//! uses the shared [`crate::mapping::rank`] comparator.

use crate::cloud::VmTypeId;
use crate::mapping::problem::MappingProblem;
use crate::mapping::rank;
use crate::market::MarketView;
use crate::simul::SimTime;
use crate::telemetry::{Candidate, Elimination};

/// Which task failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultyTask {
    Server,
    Client(usize),
}

/// Current placement state consulted by the re-calculation algorithms
/// (`current_map` in the paper's pseudocode).
#[derive(Debug, Clone)]
pub struct CurrentMap {
    pub server: VmTypeId,
    pub clients: Vec<VmTypeId>,
}

/// Behaviour knobs for Algorithm 3.
#[derive(Debug, Clone, Copy)]
pub struct DynSchedPolicy {
    /// Remove the revoked instance type from the candidate set (AWS
    /// behaviour, Table 5). When false the same type may be re-selected
    /// immediately (CloudLab behaviour, Tables 6–8).
    pub remove_revoked: bool,
}

impl DynSchedPolicy {
    pub fn different_vm() -> Self {
        Self { remove_revoked: true }
    }
    pub fn same_vm_allowed() -> Self {
        Self { remove_revoked: false }
    }
}

/// Everything a Dynamic Scheduler may consult when picking a replacement
/// for one revoked task — the single argument of
/// [`crate::framework::DynScheduler::select`] and [`select_instance`].
///
/// A context struct instead of positional arguments so the API can grow
/// without breaking every implementation: `at` (the revocation instant) and
/// `market` (read access to the job's price series, the first step toward
/// market-aware replacement policies) were both later additions that now
/// ride along for free. All fields are borrows or `Copy`, and the struct
/// itself is `Copy`, so wrappers can cheaply re-issue a context with one
/// field swapped (`RevocationCtx { candidates: &filtered, ..*ctx }` — how
/// the workload engine's quota filter narrows the candidate set).
#[derive(Clone, Copy)]
pub struct RevocationCtx<'a> {
    /// The job's mapping problem (catalog snapshot, slowdowns, objective).
    pub problem: &'a MappingProblem<'a>,
    /// Where every task currently runs.
    pub map: &'a CurrentMap,
    /// Which task was revoked.
    pub faulty: FaultyTask,
    /// The task's current candidate set `I_t`.
    pub candidates: &'a [VmTypeId],
    /// The revoked VM type.
    pub revoked: VmTypeId,
    /// Algorithm 3's behaviour knobs.
    pub policy: DynSchedPolicy,
    /// The revocation instant on the caller's simulation clock.
    pub at: SimTime,
    /// Estimated seconds of FL work remaining at `at` (rounds left ×
    /// expected round makespan). Outlook-aware selection prices candidates
    /// over `[at, at + remaining_secs)`; 0.0 when the caller has no
    /// estimate (falls back to the instantaneous factor).
    pub remaining_secs: f64,
    /// Read-only view of the job's spot market (same clock as `at`).
    pub market: MarketView<'a>,
}

/// Algorithm 1: Makespan Re-calculation.
///
/// Expected round makespan if task `t` runs on `candidate` while every other
/// task keeps its current VM.
pub fn recompute_makespan(
    p: &MappingProblem,
    map: &CurrentMap,
    t: FaultyTask,
    candidate: VmTypeId,
) -> f64 {
    let mut max_makespan = f64::NEG_INFINITY;
    match t {
        FaultyTask::Server => {
            // New server instance: every client re-times against it.
            for (i, &cvm) in map.clients.iter().enumerate() {
                let total = p.t_exec(i, cvm) + p.t_comm(cvm, candidate) + p.t_aggreg(candidate);
                max_makespan = max_makespan.max(total);
            }
        }
        FaultyTask::Client(ct) => {
            let server = map.server;
            max_makespan =
                p.t_exec(ct, candidate) + p.t_comm(candidate, server) + p.t_aggreg(server);
            for (i, &cvm) in map.clients.iter().enumerate() {
                if i == ct {
                    continue;
                }
                let total = p.t_exec(i, cvm) + p.t_comm(cvm, server) + p.t_aggreg(server);
                max_makespan = max_makespan.max(total);
            }
        }
    }
    max_makespan
}

/// Algorithm 2: Financial Cost Re-calculation.
///
/// Expected round cost (VM time at `makespan` + message exchange, Eq. 6) if
/// task `t` runs on `candidate`.
pub fn recompute_cost(
    p: &MappingProblem,
    map: &CurrentMap,
    t: FaultyTask,
    candidate: VmTypeId,
    makespan: f64,
) -> f64 {
    let rate = |vm: VmTypeId| p.rate_per_sec(vm);
    let mut total = 0.0;
    match t {
        FaultyTask::Server => {
            total += rate(candidate) * makespan;
            for &cvm in &map.clients {
                total += rate(cvm) * makespan + p.comm_cost(cvm, candidate);
            }
        }
        FaultyTask::Client(ct) => {
            let server = map.server;
            total += rate(server) * makespan;
            total += rate(candidate) * makespan + p.comm_cost(candidate, server);
            for (i, &cvm) in map.clients.iter().enumerate() {
                if i == ct {
                    continue;
                }
                total += rate(cvm) * makespan + p.comm_cost(cvm, server);
            }
        }
    }
    total
}

/// Result of one Algorithm-3 selection.
#[derive(Debug, Clone)]
pub struct Selection {
    pub vm: VmTypeId,
    pub expected_makespan: f64,
    pub expected_cost: f64,
    pub value: f64,
    /// Candidates examined (for the trace / benches).
    pub candidates_considered: usize,
}

/// Algorithm 3: Instance Selection.
///
/// `ctx.candidates` is `I_t`, the current candidate instances for the task
/// (initially all catalog VMs; shrinks as types are removed after
/// revocations when the policy says so). Returns the chosen VM and the new
/// candidate set (with the revoked VM removed if the policy demands it), or
/// None when the set is exhausted.
pub fn select_instance(ctx: &RevocationCtx<'_>) -> (Option<Selection>, Vec<VmTypeId>) {
    let (map, t) = (ctx.map, ctx.faulty);
    // Outlook-aware pricing: with a MarketOutlook on the problem, charge
    // candidates the expected factor over the remaining-rounds window
    // `[at, at + remaining_secs)` instead of the flat planning factor.
    // `windowed` is the identity without an outlook, keeping the default
    // path bit-identical.
    let p = &ctx.problem.windowed(ctx.at.secs(), ctx.remaining_secs);
    let set: Vec<VmTypeId> = if ctx.policy.remove_revoked {
        ctx.candidates.iter().copied().filter(|&v| v != ctx.revoked).collect()
    } else {
        ctx.candidates.to_vec()
    };
    // Minimize the weighted objective with the shared first-wins comparator
    // (same tie-break as the Initial Mapping baselines' rankings). Each
    // candidate's makespan/cost is computed exactly once.
    let best = rank::argmin_by_f64(
        set.iter().map(|&vm| {
            let makespan = recompute_makespan(p, map, t, vm);
            let cost = recompute_cost(p, map, t, vm, makespan);
            (vm, makespan, cost)
        }),
        |&(_, makespan, cost)| p.objective_value(cost, makespan),
    )
    .map(|((vm, expected_makespan, expected_cost), value)| Selection {
        vm,
        expected_makespan,
        expected_cost,
        value,
        candidates_considered: set.len(),
    });
    (best, set)
}

/// Decision provenance for one Algorithm-3 selection: the ranked candidate
/// table over the *incoming* candidate set `I_t`, with the revoked type
/// flagged `policy-banned` when the policy removes it and every other loser
/// `dominated`.
///
/// Replays the same windowed pricing and makespan/cost re-calculations as
/// [`select_instance`] post-hoc, so recording provenance cannot perturb the
/// selection itself.
pub fn explain_candidates(ctx: &RevocationCtx<'_>, chosen: Option<VmTypeId>) -> Vec<Candidate> {
    let (map, t) = (ctx.map, ctx.faulty);
    let p = &ctx.problem.windowed(ctx.at.secs(), ctx.remaining_secs);
    let cat = p.catalog;
    let mut rows: Vec<Candidate> = ctx
        .candidates
        .iter()
        .map(|&vm| {
            let makespan = recompute_makespan(p, map, t, vm);
            let cost = recompute_cost(p, map, t, vm, makespan);
            // Chosen wins over the policy ban: the quota-fallback restart
            // legitimately re-picks the revoked type.
            let eliminated = if chosen == Some(vm) {
                None
            } else if ctx.policy.remove_revoked && vm == ctx.revoked {
                Some(Elimination::PolicyBanned)
            } else {
                Some(Elimination::Dominated)
            };
            Candidate {
                label: format!(
                    "{}/{} {}",
                    cat.provider(cat.provider_of(vm)).name,
                    cat.region(cat.region_of(vm)).name,
                    cat.vm(vm).id
                ),
                objective: p.objective_value(cost, makespan),
                price_factor: p.spot_price_factor,
                eliminated,
            }
        })
        .collect();
    rank::sort_by_key_f64(&mut rows, |c| c.objective);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::Market;
    use crate::mapping::problem::testutil::*;
    use crate::mapping::problem::MappingProblem;
    use crate::market::MarketSpec;

    /// The default (constant-price) market every unit test runs under.
    fn default_market() -> MarketSpec {
        MarketSpec::default()
    }

    fn setup() -> (crate::cloudsim::MultiCloud, crate::presched::SlowdownReport, crate::mapping::problem::JobProfile) {
        let mc = cloudlab_sim();
        let sl = slowdowns(&mc);
        let job = til_profile();
        (mc, sl, job)
    }

    fn problem<'a>(
        mc: &'a crate::cloudsim::MultiCloud,
        sl: &'a crate::presched::SlowdownReport,
        job: &'a crate::mapping::problem::JobProfile,
    ) -> MappingProblem<'a> {
        MappingProblem {
            catalog: &mc.catalog,
            slowdowns: sl,
            job,
            alpha: 0.5,
            market: Market::Spot,
            spot_price_factor: 1.0,
            budget_round: 1e9,
            deadline_round: 1e9,
            outlook: None,
        }
    }

    fn til_map(mc: &crate::cloudsim::MultiCloud) -> CurrentMap {
        CurrentMap {
            server: mc.catalog.vm_by_id("vm121").unwrap(),
            clients: vec![mc.catalog.vm_by_id("vm126").unwrap(); 4],
        }
    }

    #[test]
    fn makespan_recalc_server_candidate_matches_evaluate() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        // Replacing the server with the same VM must reproduce the standard
        // evaluation's makespan.
        let m = recompute_makespan(&p, &map, FaultyTask::Server, map.server);
        let ev = p.evaluate(&crate::mapping::problem::Mapping {
            server: map.server,
            clients: map.clients.clone(),
            market: Market::Spot,
        });
        assert!((m - ev.makespan).abs() < 1e-9);
    }

    #[test]
    fn cost_recalc_matches_evaluate() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let makespan = recompute_makespan(&p, &map, FaultyTask::Server, map.server);
        let cost = recompute_cost(&p, &map, FaultyTask::Server, map.server, makespan);
        let ev = p.evaluate(&crate::mapping::problem::Mapping {
            server: map.server,
            clients: map.clients.clone(),
            market: Market::Spot,
        });
        assert!((cost - ev.total_cost).abs() < 1e-9, "{cost} vs {}", ev.total_cost);
    }

    #[test]
    fn client_recalc_uses_current_server() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let vm138 = mc.catalog.vm_by_id("vm138").unwrap();
        // Restarting client 0 on vm138 (slower than vm126) raises makespan
        // to client 0's new time.
        let m = recompute_makespan(&p, &map, FaultyTask::Client(0), vm138);
        let expected = p.t_exec(0, vm138) + p.t_comm(vm138, map.server) + p.t_aggreg(map.server);
        assert!((m - expected).abs() < 1e-9);
    }

    #[test]
    fn paper_restart_choices_til() {
        // §5.6.1 (Table 5 scenario, remove-revoked policy): "Clients start on
        // a VM vm126 and restart on a VM vm138. The server starts on a VM
        // vm121 and restarts in a VM vm212."
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let all: Vec<_> = mc.catalog.vm_ids().collect();

        let market = default_market();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let (sel, new_set) = select_instance(&RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Client(0),
            candidates: &all,
            revoked: vm126,
            policy: DynSchedPolicy::different_vm(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        });
        let sel = sel.unwrap();
        assert_eq!(mc.catalog.vm(sel.vm).id, "vm138", "client restart VM");
        assert!(!new_set.contains(&vm126));

        let vm121 = mc.catalog.vm_by_id("vm121").unwrap();
        let (sel, _) = select_instance(&RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Server,
            candidates: &all,
            revoked: vm121,
            policy: DynSchedPolicy::different_vm(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        });
        let sel = sel.unwrap();
        // The paper reports the server restarting on vm212; with the
        // published Table 3/4 slowdowns, vm124 (vm121's same-price twin in
        // the same region) strictly dominates vm212 on both expected cost
        // and makespan, so Algorithm 3 selects it. We assert the choice is
        // one of those two and that minimality holds (separate test).
        let id = mc.catalog.vm(sel.vm).id.clone();
        assert!(id == "vm124" || id == "vm212", "server restart VM = {id}");
    }

    #[test]
    fn same_vm_policy_reselects_revoked_type() {
        // Table 6: with the CloudLab policy the revoked type stays in I_t and
        // (being optimal) is selected again.
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let all: Vec<_> = mc.catalog.vm_ids().collect();
        let market = default_market();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let (sel, new_set) = select_instance(&RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Client(0),
            candidates: &all,
            revoked: vm126,
            policy: DynSchedPolicy::same_vm_allowed(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        });
        assert_eq!(sel.unwrap().vm, vm126);
        assert_eq!(new_set.len(), all.len());
    }

    #[test]
    fn candidate_set_shrinks_across_revocations() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let mut set: Vec<_> = mc.catalog.vm_ids().collect();
        let policy = DynSchedPolicy::different_vm();
        let market = default_market();
        let n0 = set.len();
        // Three successive client revocations, each removing the chosen VM.
        let mut revoked = mc.catalog.vm_by_id("vm126").unwrap();
        for k in 1..=3 {
            let (sel, new_set) = select_instance(&RevocationCtx {
                problem: &p,
                map: &map,
                faulty: FaultyTask::Client(0),
                candidates: &set,
                revoked,
                policy,
                at: SimTime::ZERO,
                remaining_secs: 0.0,
                market: MarketView::new(&market),
            });
            set = new_set;
            assert_eq!(set.len(), n0 - k);
            revoked = sel.unwrap().vm;
        }
    }

    #[test]
    fn exhausted_candidate_set_returns_none() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let market = default_market();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let (sel, set) = select_instance(&RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Client(0),
            candidates: &[vm126],
            revoked: vm126,
            policy: DynSchedPolicy::different_vm(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        });
        assert!(sel.is_none());
        assert!(set.is_empty());
    }

    #[test]
    fn explain_matches_the_selection_and_types_the_losses() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let all: Vec<_> = mc.catalog.vm_ids().collect();
        let market = default_market();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let ctx = RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Client(0),
            candidates: &all,
            revoked: vm126,
            policy: DynSchedPolicy::different_vm(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        };
        let (sel, _) = select_instance(&ctx);
        let sel = sel.unwrap();
        let rows = explain_candidates(&ctx, Some(sel.vm));
        assert_eq!(rows.len(), all.len(), "one row per incoming candidate");
        let chosen: Vec<_> = rows.iter().filter(|r| r.eliminated.is_none()).collect();
        assert_eq!(chosen.len(), 1);
        assert!(chosen[0].label.ends_with(&mc.catalog.vm(sel.vm).id));
        assert!((chosen[0].objective - sel.value).abs() < 1e-12, "objective = Algorithm 3's value");
        let banned: Vec<_> =
            rows.iter().filter(|r| r.eliminated == Some(Elimination::PolicyBanned)).collect();
        assert_eq!(banned.len(), 1, "exactly the revoked type is policy-banned");
        assert!(banned[0].label.ends_with("vm126"));
        for w in rows.windows(2) {
            assert!(w[0].objective <= w[1].objective, "rows are ranked");
        }
    }

    #[test]
    fn selection_minimizes_objective_value() {
        let (mc, sl, job) = setup();
        let p = problem(&mc, &sl, &job);
        let map = til_map(&mc);
        let all: Vec<_> = mc.catalog.vm_ids().collect();
        let market = default_market();
        let vm126 = mc.catalog.vm_by_id("vm126").unwrap();
        let (sel, set) = select_instance(&RevocationCtx {
            problem: &p,
            map: &map,
            faulty: FaultyTask::Client(0),
            candidates: &all,
            revoked: vm126,
            policy: DynSchedPolicy::different_vm(),
            at: SimTime::ZERO,
            remaining_secs: 0.0,
            market: MarketView::new(&market),
        });
        let sel = sel.unwrap();
        for &vm in &set {
            let m = recompute_makespan(&p, &map, FaultyTask::Client(0), vm);
            let c = recompute_cost(&p, &map, FaultyTask::Client(0), vm, m);
            assert!(sel.value <= p.objective_value(c, m) + 1e-12);
        }
    }
}
