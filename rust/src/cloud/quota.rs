//! Quota accounting: tracks GPU and vCPU usage against the provider-wide
//! (`N_GPU_j`, `N_CPU_j`) and per-region (`N_L_GPU_jk`, `N_L_CPU_jk`) bounds
//! of the environment model (Constraints 12–15 of the formulation, enforced
//! at runtime by the simulator and at planning time by the mapping solver).

use std::collections::HashMap;

use super::catalog::Catalog;
use super::{ProviderId, RegionId, VmTypeId};

#[derive(Debug, Clone, Default)]
struct Usage {
    gpus: u32,
    vcpus: u32,
}

/// Mutable quota state over a catalog.
#[derive(Debug, Clone)]
pub struct QuotaTracker {
    provider_usage: HashMap<ProviderId, Usage>,
    region_usage: HashMap<RegionId, Usage>,
}

/// Why an allocation was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QuotaError {
    ProviderGpu(String),
    ProviderCpu(String),
    RegionGpu(String),
    RegionCpu(String),
}

impl std::fmt::Display for QuotaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuotaError::ProviderGpu(p) => write!(f, "provider {p} GPU quota exceeded"),
            QuotaError::ProviderCpu(p) => write!(f, "provider {p} vCPU quota exceeded"),
            QuotaError::RegionGpu(r) => write!(f, "region {r} GPU quota exceeded"),
            QuotaError::RegionCpu(r) => write!(f, "region {r} vCPU quota exceeded"),
        }
    }
}

impl std::error::Error for QuotaError {}

impl QuotaTracker {
    pub fn new() -> Self {
        Self { provider_usage: HashMap::new(), region_usage: HashMap::new() }
    }

    /// Check whether allocating one VM of type `vm` fits all four bounds.
    pub fn check(&self, cat: &Catalog, vm: VmTypeId) -> Result<(), QuotaError> {
        let spec = cat.vm(vm);
        let region = cat.region_of(vm);
        let provider = cat.provider_of(vm);
        let pu = self.provider_usage.get(&provider).cloned().unwrap_or_default();
        let ru = self.region_usage.get(&region).cloned().unwrap_or_default();
        let pspec = cat.provider(provider);
        let rspec = cat.region(region);
        if let Some(max) = pspec.max_gpus {
            if pu.gpus + spec.gpus > max {
                return Err(QuotaError::ProviderGpu(pspec.name.clone()));
            }
        }
        if let Some(max) = pspec.max_vcpus {
            if pu.vcpus + spec.vcpus > max {
                return Err(QuotaError::ProviderCpu(pspec.name.clone()));
            }
        }
        if let Some(max) = rspec.max_gpus {
            if ru.gpus + spec.gpus > max {
                return Err(QuotaError::RegionGpu(rspec.name.clone()));
            }
        }
        if let Some(max) = rspec.max_vcpus {
            if ru.vcpus + spec.vcpus > max {
                return Err(QuotaError::RegionCpu(rspec.name.clone()));
            }
        }
        Ok(())
    }

    /// Allocate one VM of type `vm`, failing atomically if any bound breaks.
    pub fn allocate(&mut self, cat: &Catalog, vm: VmTypeId) -> Result<(), QuotaError> {
        self.check(cat, vm)?;
        let spec = cat.vm(vm);
        let region = cat.region_of(vm);
        let provider = cat.provider_of(vm);
        let pu = self.provider_usage.entry(provider).or_default();
        pu.gpus += spec.gpus;
        pu.vcpus += spec.vcpus;
        let ru = self.region_usage.entry(region).or_default();
        ru.gpus += spec.gpus;
        ru.vcpus += spec.vcpus;
        Ok(())
    }

    /// Release one VM of type `vm` (e.g. after termination or revocation).
    pub fn release(&mut self, cat: &Catalog, vm: VmTypeId) {
        let spec = cat.vm(vm);
        let region = cat.region_of(vm);
        let provider = cat.provider_of(vm);
        let pu = self.provider_usage.entry(provider).or_default();
        pu.gpus = pu.gpus.saturating_sub(spec.gpus);
        pu.vcpus = pu.vcpus.saturating_sub(spec.vcpus);
        let ru = self.region_usage.entry(region).or_default();
        ru.gpus = ru.gpus.saturating_sub(spec.gpus);
        ru.vcpus = ru.vcpus.saturating_sub(spec.vcpus);
    }

    pub fn provider_gpus_in_use(&self, p: ProviderId) -> u32 {
        self.provider_usage.get(&p).map(|u| u.gpus).unwrap_or(0)
    }

    pub fn provider_vcpus_in_use(&self, p: ProviderId) -> u32 {
        self.provider_usage.get(&p).map(|u| u.vcpus).unwrap_or(0)
    }

    pub fn region_gpus_in_use(&self, r: RegionId) -> u32 {
        self.region_usage.get(&r).map(|u| u.gpus).unwrap_or(0)
    }
}

impl Default for QuotaTracker {
    fn default() -> Self {
        Self::new()
    }
}

/// Planning-time helper: check that a *whole assignment* (a multiset of VM
/// types) satisfies the quota constraints. Used by the mapping solvers.
pub fn assignment_fits(cat: &Catalog, vms: &[VmTypeId]) -> Result<(), QuotaError> {
    let mut q = QuotaTracker::new();
    for &vm in vms {
        q.allocate(cat, vm)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::tables;
    use super::*;

    #[test]
    fn cloudlab_is_unbounded() {
        let cat = tables::cloudlab();
        let mut q = QuotaTracker::new();
        let vm126 = cat.vm_by_id("vm126").unwrap();
        for _ in 0..100 {
            q.allocate(&cat, vm126).unwrap();
        }
    }

    #[test]
    fn aws_gpu_quota_enforced() {
        let cat = tables::aws_gcp();
        let mut q = QuotaTracker::new();
        let g4dn = cat.vm_by_id("vm311").unwrap();
        for _ in 0..4 {
            q.allocate(&cat, g4dn).unwrap();
        }
        // 5th GPU exceeds the N_GPU=4 provider bound.
        let err = q.allocate(&cat, g4dn).unwrap_err();
        assert!(matches!(err, QuotaError::ProviderGpu(_) | QuotaError::RegionGpu(_)));
    }

    #[test]
    fn gcp_quota_is_per_provider() {
        // 4 GPUs in GCP us-central1 blocks us-west1 too (provider bound),
        // but AWS capacity is unaffected.
        let cat = tables::aws_gcp();
        let mut q = QuotaTracker::new();
        let v100_c = cat.vm_by_id("vm413").unwrap();
        let v100_w = cat.vm_by_id("vm422").unwrap();
        for _ in 0..4 {
            q.allocate(&cat, v100_c).unwrap();
        }
        assert!(q.allocate(&cat, v100_w).is_err());
        let g4dn = cat.vm_by_id("vm311").unwrap();
        q.allocate(&cat, g4dn).unwrap();
    }

    #[test]
    fn release_restores_capacity() {
        let cat = tables::aws_gcp();
        let mut q = QuotaTracker::new();
        let g4dn = cat.vm_by_id("vm311").unwrap();
        for _ in 0..4 {
            q.allocate(&cat, g4dn).unwrap();
        }
        assert!(q.allocate(&cat, g4dn).is_err());
        q.release(&cat, g4dn);
        q.allocate(&cat, g4dn).unwrap();
    }

    #[test]
    fn vcpu_quota_enforced() {
        let cat = tables::aws_gcp();
        let mut q = QuotaTracker::new();
        let g3 = cat.vm_by_id("vm312").unwrap(); // 16 vCPUs, 1 GPU
        // 4 allocations = 64 vCPUs, 4 GPUs: GPU bound binds first on the 5th.
        for _ in 0..4 {
            q.allocate(&cat, g3).unwrap();
        }
        assert!(q.allocate(&cat, g3).is_err());
    }

    #[test]
    fn assignment_fits_whole_plan() {
        let cat = tables::aws_gcp();
        let g4dn = cat.vm_by_id("vm311").unwrap();
        let t2 = cat.vm_by_id("vm313").unwrap();
        assert!(assignment_fits(&cat, &[g4dn, g4dn, t2]).is_ok());
        assert!(assignment_fits(&cat, &[g4dn; 5]).is_err());
    }
}
