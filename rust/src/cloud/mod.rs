//! Environment model (§3 of the paper).
//!
//! A multi-cloud platform is a set of providers `P`; each provider `p_j` has
//! regions `R_j`; each region `r_jk` offers VM instance types `V_jk` with a
//! number of vCPUs/GPUs and a fixed price per second, in two markets
//! (on-demand and spot/preemptible). Providers also have global and
//! per-region GPU/vCPU quotas and a flat egress cost per GB (`cost_t_j`).
//!
//! The [`Catalog`] is what the scheduler *sees*. The simulator's ground-truth
//! performance parameterization (how fast each VM actually computes, how fast
//! each region pair actually communicates) lives in
//! [`tables::GroundTruth`] — the Pre-Scheduling module measures slowdowns by
//! running a dummy application against it, exactly as the paper measures
//! Tables 3 and 4 on CloudLab.

pub mod catalog;
pub mod quota;
pub mod tables;

pub use catalog::{Catalog, ProviderSpec, RegionSpec, VmTypeSpec};
pub use quota::QuotaTracker;


/// Index of a provider within a [`Catalog`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ProviderId(pub usize);

/// Index of a region within a [`Catalog`] (global, not per-provider).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub usize);

/// Index of a VM instance type within a [`Catalog`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VmTypeId(pub usize);

/// Pricing market for a VM allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Market {
    /// Full price, never revoked by the provider.
    OnDemand,
    /// Deep discount (the paper uses 70% off on-demand for CloudLab), but the
    /// provider may revoke the VM at any time.
    Spot,
}

impl std::fmt::Display for Market {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Market::OnDemand => write!(f, "on-demand"),
            Market::Spot => write!(f, "spot"),
        }
    }
}
