//! The multi-cloud catalog: providers, regions, VM instance types, prices,
//! quotas. Loadable from TOML (see `configs/`) and provided as built-ins for
//! the paper's two testbeds (Table 2: CloudLab; Table 9: AWS+GCP) in
//! [`super::tables`].


use super::{Market, ProviderId, RegionId, VmTypeId};

/// A cloud provider (`p_j`).
#[derive(Debug, Clone)]
pub struct ProviderSpec {
    pub name: String,
    /// `cost_t_j`: $ per GB for any message *sent* from a VM of this provider.
    pub egress_cost_per_gb: f64,
    /// Seconds of warning the provider gives before terminating a spot VM
    /// (AWS ≈ 120 s, GCP ≈ 30 s).
    pub revocation_notice_secs: f64,
    /// Time from provision request to the task being able to run. The paper
    /// measured 2:34 on AWS, 13:35 on GCP and 39:43 on CloudLab (bare-metal).
    pub boot_time_secs: f64,
    /// `N_GPU_j`: provider-wide GPU quota (None = unlimited, e.g. CloudLab).
    pub max_gpus: Option<u32>,
    /// `N_CPU_j`: provider-wide vCPU quota.
    pub max_vcpus: Option<u32>,
}

/// A region (`r_jk`) of a provider.
#[derive(Debug, Clone)]
pub struct RegionSpec {
    pub name: String,
    pub provider: ProviderId,
    /// `N_L_GPU_jk`: per-region GPU quota.
    pub max_gpus: Option<u32>,
    /// `N_L_CPU_jk`: per-region vCPU quota.
    pub max_vcpus: Option<u32>,
}

/// A VM instance type (`vm_jkl`) offered in a region.
#[derive(Debug, Clone)]
pub struct VmTypeSpec {
    /// Paper id, e.g. `"vm126"`.
    pub id: String,
    /// Hardware / commercial name, e.g. `"c240g5"` or `"g4dn.2xlarge"`.
    pub hw_name: String,
    pub region: RegionId,
    pub vcpus: u32,
    pub gpus: u32,
    pub gpu_model: Option<String>,
    pub ram_gb: f64,
    pub on_demand_hourly: f64,
    pub spot_hourly: f64,
}

impl VmTypeSpec {
    /// `cost_jkl` in $ per second for the given market.
    pub fn cost_per_sec(&self, market: Market) -> f64 {
        let hourly = match market {
            Market::OnDemand => self.on_demand_hourly,
            Market::Spot => self.spot_hourly,
        };
        hourly / 3600.0
    }
}

/// The full environment the scheduler sees.
#[derive(Debug, Clone)]
pub struct Catalog {
    pub name: String,
    pub providers: Vec<ProviderSpec>,
    pub regions: Vec<RegionSpec>,
    pub vm_types: Vec<VmTypeSpec>,
}

impl Catalog {
    pub fn provider(&self, p: ProviderId) -> &ProviderSpec {
        &self.providers[p.0]
    }

    pub fn region(&self, r: RegionId) -> &RegionSpec {
        &self.regions[r.0]
    }

    pub fn vm(&self, v: VmTypeId) -> &VmTypeSpec {
        &self.vm_types[v.0]
    }

    /// Provider that hosts VM type `v`.
    pub fn provider_of(&self, v: VmTypeId) -> ProviderId {
        self.regions[self.vm_types[v.0].region.0].provider
    }

    pub fn region_of(&self, v: VmTypeId) -> RegionId {
        self.vm_types[v.0].region
    }

    pub fn vm_ids(&self) -> impl Iterator<Item = VmTypeId> + '_ {
        (0..self.vm_types.len()).map(VmTypeId)
    }

    pub fn region_ids(&self) -> impl Iterator<Item = RegionId> + '_ {
        (0..self.regions.len()).map(RegionId)
    }

    pub fn provider_ids(&self) -> impl Iterator<Item = ProviderId> + '_ {
        (0..self.providers.len()).map(ProviderId)
    }

    /// VM types offered in region `r` (the set `V_jk`).
    pub fn vms_in_region(&self, r: RegionId) -> Vec<VmTypeId> {
        self.vm_ids().filter(|&v| self.vm_types[v.0].region == r).collect()
    }

    /// Look up a VM type by its paper id (e.g. `"vm126"`) or hardware name.
    pub fn vm_by_id(&self, id: &str) -> Option<VmTypeId> {
        self.vm_ids()
            .find(|&v| self.vm_types[v.0].id == id || self.vm_types[v.0].hw_name == id)
    }

    pub fn region_by_name(&self, name: &str) -> Option<RegionId> {
        self.region_ids().find(|&r| self.regions[r.0].name == name)
    }

    /// Most expensive per-second VM rate, used in the `cost_max`
    /// normalization term (Eq. 7).
    pub fn max_cost_per_sec(&self, market: Market) -> f64 {
        self.vm_types
            .iter()
            .map(|v| v.cost_per_sec(market))
            .fold(0.0, f64::max)
    }

    /// Load a catalog from a TOML file (the config-system entry point).
    pub fn from_toml_file(path: &std::path::Path) -> anyhow::Result<Catalog> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse a catalog from TOML text. Schema: see `configs/cloudlab.toml`.
    pub fn from_toml(text: &str) -> anyhow::Result<Catalog> {
        use crate::util::tomlmini as t;
        type Tbl = std::collections::BTreeMap<String, t::Value>;
        let root = t::parse(text)?;
        fn need_str(m: &Tbl, k: &str) -> anyhow::Result<String> {
            Ok(m.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("missing string key {k}"))?
                .to_string())
        }
        fn need_f64(m: &Tbl, k: &str) -> anyhow::Result<f64> {
            m.get(k)
                .and_then(|v| v.as_float())
                .ok_or_else(|| anyhow::anyhow!("missing numeric key {k}"))
        }
        fn opt_u32(m: &Tbl, k: &str) -> Option<u32> {
            m.get(k).and_then(|v| v.as_int()).map(|i| i as u32)
        }

        t::reject_unknown_keys(&root, &["name", "provider", "region", "vm"], "catalog")?;
        let name = root
            .get("name")
            .and_then(|v| v.as_str())
            .unwrap_or("unnamed")
            .to_string();
        let mut providers = Vec::new();
        for p in root
            .get("provider")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| anyhow::anyhow!("missing [[provider]] sections"))?
        {
            t::reject_unknown_keys(
                p,
                &[
                    "name",
                    "egress_cost_per_gb",
                    "revocation_notice_secs",
                    "boot_time_secs",
                    "max_gpus",
                    "max_vcpus",
                ],
                "[[provider]]",
            )?;
            providers.push(ProviderSpec {
                name: need_str(p, "name")?,
                egress_cost_per_gb: need_f64(p, "egress_cost_per_gb")?,
                revocation_notice_secs: need_f64(p, "revocation_notice_secs")?,
                boot_time_secs: need_f64(p, "boot_time_secs")?,
                max_gpus: opt_u32(p, "max_gpus"),
                max_vcpus: opt_u32(p, "max_vcpus"),
            });
        }
        let mut regions = Vec::new();
        for r in root
            .get("region")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| anyhow::anyhow!("missing [[region]] sections"))?
        {
            t::reject_unknown_keys(r, &["name", "provider", "max_gpus", "max_vcpus"], "[[region]]")?;
            let pname = need_str(r, "provider")?;
            let provider = providers
                .iter()
                .position(|p| p.name == pname)
                .ok_or_else(|| anyhow::anyhow!("region references unknown provider {pname}"))?;
            regions.push(RegionSpec {
                name: need_str(r, "name")?,
                provider: ProviderId(provider),
                max_gpus: opt_u32(r, "max_gpus"),
                max_vcpus: opt_u32(r, "max_vcpus"),
            });
        }
        let mut vm_types = Vec::new();
        for v in root
            .get("vm")
            .and_then(|v| v.as_table_array())
            .ok_or_else(|| anyhow::anyhow!("missing [[vm]] sections"))?
        {
            t::reject_unknown_keys(
                v,
                &[
                    "id",
                    "hw_name",
                    "region",
                    "vcpus",
                    "gpus",
                    "gpu_model",
                    "ram_gb",
                    "on_demand_hourly",
                    "spot_hourly",
                ],
                "[[vm]]",
            )?;
            let rname = need_str(v, "region")?;
            let region = regions
                .iter()
                .position(|r| r.name == rname)
                .ok_or_else(|| anyhow::anyhow!("vm references unknown region {rname}"))?;
            vm_types.push(VmTypeSpec {
                id: need_str(v, "id")?,
                hw_name: need_str(v, "hw_name")?,
                region: RegionId(region),
                vcpus: opt_u32(v, "vcpus").ok_or_else(|| anyhow::anyhow!("missing vcpus"))?,
                gpus: opt_u32(v, "gpus").unwrap_or(0),
                gpu_model: v.get("gpu_model").and_then(|x| x.as_str()).map(|s| s.to_string()),
                ram_gb: need_f64(v, "ram_gb")?,
                on_demand_hourly: need_f64(v, "on_demand_hourly")?,
                spot_hourly: need_f64(v, "spot_hourly")?,
            });
        }
        let cat = Catalog { name, providers, regions, vm_types };
        cat.validate()?;
        Ok(cat)
    }

    /// Serialize to the TOML schema accepted by [`Self::from_toml`].
    pub fn to_toml(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "name = \"{}\"", self.name);
        for p in &self.providers {
            let _ = writeln!(out, "\n[[provider]]");
            let _ = writeln!(out, "name = \"{}\"", p.name);
            let _ = writeln!(out, "egress_cost_per_gb = {}", p.egress_cost_per_gb);
            let _ = writeln!(out, "revocation_notice_secs = {:.1}", p.revocation_notice_secs);
            let _ = writeln!(out, "boot_time_secs = {:.1}", p.boot_time_secs);
            if let Some(g) = p.max_gpus {
                let _ = writeln!(out, "max_gpus = {g}");
            }
            if let Some(c) = p.max_vcpus {
                let _ = writeln!(out, "max_vcpus = {c}");
            }
        }
        for r in &self.regions {
            let _ = writeln!(out, "\n[[region]]");
            let _ = writeln!(out, "name = \"{}\"", r.name);
            let _ = writeln!(out, "provider = \"{}\"", self.providers[r.provider.0].name);
            if let Some(g) = r.max_gpus {
                let _ = writeln!(out, "max_gpus = {g}");
            }
            if let Some(c) = r.max_vcpus {
                let _ = writeln!(out, "max_vcpus = {c}");
            }
        }
        for v in &self.vm_types {
            let _ = writeln!(out, "\n[[vm]]");
            let _ = writeln!(out, "id = \"{}\"", v.id);
            let _ = writeln!(out, "hw_name = \"{}\"", v.hw_name);
            let _ = writeln!(out, "region = \"{}\"", self.regions[v.region.0].name);
            let _ = writeln!(out, "vcpus = {}", v.vcpus);
            let _ = writeln!(out, "gpus = {}", v.gpus);
            if let Some(m) = &v.gpu_model {
                let _ = writeln!(out, "gpu_model = \"{m}\"");
            }
            let _ = writeln!(out, "ram_gb = {}", v.ram_gb);
            let _ = writeln!(out, "on_demand_hourly = {}", v.on_demand_hourly);
            let _ = writeln!(out, "spot_hourly = {}", v.spot_hourly);
        }
        out
    }

    /// Structural sanity checks (indices in range, prices non-negative,
    /// spot ≤ on-demand).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (i, r) in self.regions.iter().enumerate() {
            anyhow::ensure!(
                r.provider.0 < self.providers.len(),
                "region {i} references missing provider {}",
                r.provider.0
            );
        }
        for v in &self.vm_types {
            anyhow::ensure!(
                v.region.0 < self.regions.len(),
                "vm {} references missing region {}",
                v.id,
                v.region.0
            );
            anyhow::ensure!(v.on_demand_hourly >= 0.0 && v.spot_hourly >= 0.0);
            anyhow::ensure!(
                v.spot_hourly <= v.on_demand_hourly,
                "vm {}: spot price above on-demand",
                v.id
            );
            anyhow::ensure!(v.vcpus > 0, "vm {}: zero vCPUs", v.id);
        }
        let mut seen = std::collections::HashSet::new();
        for v in &self.vm_types {
            anyhow::ensure!(seen.insert(&v.id), "duplicate vm id {}", v.id);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::tables;
    use super::*;

    #[test]
    fn cloudlab_catalog_matches_table2() {
        let cat = tables::cloudlab();
        cat.validate().unwrap();
        assert_eq!(cat.providers.len(), 2, "Cloud A and Cloud B");
        assert_eq!(cat.regions.len(), 5, "Utah, Wisconsin, Clemson, APT, Mass");
        assert_eq!(cat.vm_types.len(), 13);
        let vm126 = cat.vm(cat.vm_by_id("vm126").unwrap());
        assert_eq!(vm126.hw_name, "c240g5");
        assert_eq!(vm126.vcpus, 40);
        assert_eq!(vm126.gpus, 1);
        assert!((vm126.on_demand_hourly - 4.693).abs() < 1e-9);
        assert!((vm126.spot_hourly - 1.408).abs() < 1e-9);
        let vm138 = cat.vm(cat.vm_by_id("vm138").unwrap());
        assert_eq!(vm138.gpu_model.as_deref(), Some("V100S"));
        assert_eq!(vm138.vcpus, 128);
        assert!((vm138.on_demand_hourly - 11.159).abs() < 1e-9);
    }

    #[test]
    fn spot_is_70_percent_discount_on_cloudlab() {
        let cat = tables::cloudlab();
        for v in &cat.vm_types {
            let expected = v.on_demand_hourly * 0.3;
            assert!(
                (v.spot_hourly - expected).abs() < 0.005,
                "{}: spot {} vs 30% of od {}",
                v.id,
                v.spot_hourly,
                expected
            );
        }
    }

    #[test]
    fn aws_gcp_catalog_matches_table9() {
        let cat = tables::aws_gcp();
        cat.validate().unwrap();
        assert_eq!(cat.providers.len(), 2);
        assert_eq!(cat.regions.len(), 3, "us-east-1, us-central1, us-west1");
        assert_eq!(cat.vm_types.len(), 8);
        let g4dn = cat.vm(cat.vm_by_id("vm311").unwrap());
        assert_eq!(g4dn.hw_name, "g4dn.2xlarge");
        assert!((g4dn.on_demand_hourly - 0.752).abs() < 1e-9);
        assert!((g4dn.spot_hourly - 0.318).abs() < 1e-9);
        let t2 = cat.vm(cat.vm_by_id("vm313").unwrap());
        assert_eq!(t2.gpus, 0);
        assert!((t2.on_demand_hourly - 0.186).abs() < 1e-9);
    }

    #[test]
    fn provider_of_resolves_through_region() {
        let cat = tables::cloudlab();
        let vm212 = cat.vm_by_id("vm212").unwrap();
        let p = cat.provider_of(vm212);
        assert_eq!(cat.provider(p).name, "Cloud B");
        assert_eq!(cat.region(cat.region_of(vm212)).name, "APT");
    }

    #[test]
    fn cost_per_sec() {
        let cat = tables::cloudlab();
        let vm121 = cat.vm(cat.vm_by_id("vm121").unwrap());
        assert!((vm121.cost_per_sec(Market::OnDemand) - 1.670 / 3600.0).abs() < 1e-12);
        assert!((vm121.cost_per_sec(Market::Spot) - 0.501 / 3600.0).abs() < 1e-12);
    }

    #[test]
    fn toml_round_trip() {
        let cat = tables::cloudlab();
        let text = cat.to_toml();
        let back = Catalog::from_toml(&text).unwrap();
        assert_eq!(back.vm_types.len(), cat.vm_types.len());
        assert_eq!(back.providers[0].name, cat.providers[0].name);
        let vm126 = back.vm(back.vm_by_id("vm126").unwrap());
        assert_eq!(vm126.gpu_model.as_deref(), Some("P100"));
        assert!((vm126.spot_hourly - 1.408).abs() < 1e-9);
    }

    #[test]
    fn vms_in_region() {
        let cat = tables::cloudlab();
        let utah = cat.region_by_name("Utah").unwrap();
        let vms = cat.vms_in_region(utah);
        assert_eq!(vms.len(), 3);
    }
}
