//! Built-in environments: the paper's two testbeds and their measured
//! ground-truth performance.
//!
//! * [`cloudlab`] — Table 2: 13 instance types across 5 CloudLab clusters
//!   grouped into two simulated clouds, priced with GCP's December-2022
//!   per-vCPU / per-GB policy and a 70% spot discount.
//! * [`aws_gcp`] — Table 9: the AWS us-east-1 + GCP us-central1/us-west1
//!   proof-of-concept environment.
//! * [`GroundTruth`] — the *simulator parameterization*: per-VM dummy-app
//!   execution times (Table 3) and per-region-pair message-exchange times
//!   (Table 4). The Pre-Scheduling module re-derives the paper's slowdowns
//!   by running the dummy app against this ground truth, which is exactly
//!   how the paper produced Tables 3 and 4 on the real testbed.
//!
//! Substitution note (see DESIGN.md): we cannot allocate CloudLab/AWS/GCP
//! machines here, so the measured numbers published in the paper *are* the
//! ground truth of our simulated multi-cloud.

use std::collections::HashMap;

use super::catalog::{Catalog, ProviderSpec, RegionSpec, VmTypeSpec};
use super::{ProviderId, RegionId};

/// Boot ("VM preparation") times measured by the paper (§5.4).
pub const BOOT_CLOUDLAB_SECS: f64 = 39.0 * 60.0 + 43.0; // 39:43 bare-metal
pub const BOOT_AWS_SECS: f64 = 2.0 * 60.0 + 34.0; // 2:34
pub const BOOT_GCP_SECS: f64 = 13.0 * 60.0 + 35.0; // 13:35

/// Revocation notice windows (§4.3).
pub const NOTICE_AWS_SECS: f64 = 120.0;
pub const NOTICE_GCP_SECS: f64 = 30.0;

/// Egress price used for both CloudLab clouds (§5.4): GCP's $0.012/GB.
pub const EGRESS_CLOUDLAB: f64 = 0.012;

/// Table 2: the CloudLab testbed as two simulated clouds.
pub fn cloudlab() -> Catalog {
    let providers = vec![
        ProviderSpec {
            name: "Cloud A".into(),
            egress_cost_per_gb: EGRESS_CLOUDLAB,
            revocation_notice_secs: NOTICE_AWS_SECS,
            boot_time_secs: BOOT_CLOUDLAB_SECS,
            max_gpus: None, // CloudLab does not limit vCPUs/GPUs per user
            max_vcpus: None,
        },
        ProviderSpec {
            name: "Cloud B".into(),
            egress_cost_per_gb: EGRESS_CLOUDLAB,
            revocation_notice_secs: NOTICE_GCP_SECS,
            boot_time_secs: BOOT_CLOUDLAB_SECS,
            max_gpus: None,
            max_vcpus: None,
        },
    ];
    let regions = vec![
        RegionSpec { name: "Utah".into(), provider: ProviderId(0), max_gpus: None, max_vcpus: None },
        RegionSpec { name: "Wisconsin".into(), provider: ProviderId(0), max_gpus: None, max_vcpus: None },
        RegionSpec { name: "Clemson".into(), provider: ProviderId(0), max_gpus: None, max_vcpus: None },
        RegionSpec { name: "APT".into(), provider: ProviderId(1), max_gpus: None, max_vcpus: None },
        RegionSpec { name: "Massachusetts".into(), provider: ProviderId(1), max_gpus: None, max_vcpus: None },
    ];
    // (id, hw, region, vcpus, gpus, gpu_model, ram, on_demand, spot)
    let raw: &[(&str, &str, usize, u32, u32, Option<&str>, f64, f64, f64)] = &[
        ("vm112", "c6525-25g", 0, 32, 0, None, 128.0, 1.670, 0.501),
        ("vm114", "m510", 0, 16, 0, None, 64.0, 0.835, 0.250),
        ("vm115", "xl170", 0, 20, 0, None, 64.0, 0.971, 0.291),
        ("vm121", "c220g1", 1, 32, 0, None, 128.0, 1.670, 0.501),
        ("vm122", "c220g2", 1, 40, 0, None, 160.0, 2.087, 0.626),
        ("vm124", "c240g1", 1, 32, 0, None, 128.0, 1.670, 0.501),
        ("vm126", "c240g5", 1, 40, 1, Some("P100"), 192.0, 4.693, 1.408),
        ("vm135", "dss7500", 2, 24, 0, None, 128.0, 1.398, 0.419),
        ("vm138", "r7525", 2, 128, 1, Some("V100S"), 512.0, 11.159, 3.348),
        ("vm211", "c6220", 3, 32, 0, None, 64.0, 1.283, 0.385),
        ("vm212", "r320", 3, 12, 0, None, 16.0, 0.574, 0.172),
        ("vm221", "rs440", 4, 64, 0, None, 192.0, 2.837, 0.851),
        ("vm222", "rs630", 4, 40, 0, None, 256.0, 2.349, 0.705),
    ];
    let vm_types = raw
        .iter()
        .map(|&(id, hw, region, vcpus, gpus, gpu_model, ram, od, spot)| VmTypeSpec {
            id: id.into(),
            hw_name: hw.into(),
            region: RegionId(region),
            vcpus,
            gpus,
            gpu_model: gpu_model.map(|s| s.to_string()),
            ram_gb: ram,
            on_demand_hourly: od,
            spot_hourly: spot,
        })
        .collect();
    Catalog { name: "cloudlab".into(), providers, regions, vm_types }
}

/// Table 9: the real two-cloud proof-of-concept environment (AWS + GCP).
pub fn aws_gcp() -> Catalog {
    let providers = vec![
        ProviderSpec {
            name: "AWS".into(),
            egress_cost_per_gb: 0.012,
            revocation_notice_secs: NOTICE_AWS_SECS,
            boot_time_secs: BOOT_AWS_SECS,
            max_gpus: Some(4), // the GPU quota the paper hit (§5.2)
            max_vcpus: Some(128),
        },
        ProviderSpec {
            name: "GCP".into(),
            egress_cost_per_gb: 0.012,
            revocation_notice_secs: NOTICE_GCP_SECS,
            boot_time_secs: BOOT_GCP_SECS,
            max_gpus: Some(4),
            max_vcpus: Some(128),
        },
    ];
    let regions = vec![
        RegionSpec { name: "us-east-1".into(), provider: ProviderId(0), max_gpus: Some(4), max_vcpus: Some(128) },
        RegionSpec { name: "us-central1".into(), provider: ProviderId(1), max_gpus: Some(4), max_vcpus: Some(128) },
        RegionSpec { name: "us-west1".into(), provider: ProviderId(1), max_gpus: Some(4), max_vcpus: Some(128) },
    ];
    let raw: &[(&str, &str, usize, u32, u32, Option<&str>, f64, f64, f64)] = &[
        ("vm311", "g4dn.2xlarge", 0, 8, 1, Some("T4"), 32.0, 0.752, 0.318),
        ("vm312", "g3.4xlarge", 0, 16, 1, Some("M60"), 122.0, 1.140, 0.638),
        ("vm313", "t2.xlarge", 0, 4, 0, None, 16.0, 0.186, 0.140),
        ("vm411", "n1-standard-8-t4", 1, 8, 1, Some("T4"), 30.0, 0.730, 0.196),
        ("vm413", "n1-standard-8-v100", 1, 8, 1, Some("V100"), 30.0, 2.860, 0.857),
        ("vm414", "e2-standard-4", 1, 4, 0, None, 16.0, 0.134, 0.040),
        ("vm422", "n1-standard-8-v100-w", 2, 8, 1, Some("V100"), 30.0, 2.860, 0.857),
        ("vm423", "e2-standard-4-w", 2, 4, 0, None, 16.0, 0.134, 0.040),
    ];
    let vm_types = raw
        .iter()
        .map(|&(id, hw, region, vcpus, gpus, gpu_model, ram, od, spot)| VmTypeSpec {
            id: id.into(),
            hw_name: hw.into(),
            region: RegionId(region),
            vcpus,
            gpus,
            gpu_model: gpu_model.map(|s| s.to_string()),
            ram_gb: ram,
            on_demand_hourly: od,
            spot_hourly: spot,
        })
        .collect();
    Catalog { name: "aws-gcp".into(), providers, regions, vm_types }
}

/// Measured dummy-application times for one VM type (Table 3): training and
/// test times of the first and second rounds, in seconds. The paper's
/// slowdowns use round 2 (round 1 includes warm-up).
#[derive(Debug, Clone, Copy)]
pub struct DummyTimes {
    pub train_r1: f64,
    pub train_r2: f64,
    pub test_r1: f64,
    pub test_r2: f64,
}

impl DummyTimes {
    /// Steady-state (round ≥ 2) train+test time.
    pub fn steady(&self) -> f64 {
        self.train_r2 + self.test_r2
    }

    /// Warm-up overhead of the first round relative to steady state.
    pub fn warmup_extra(&self) -> f64 {
        (self.train_r1 + self.test_r1) - self.steady()
    }
}

/// Measured message-exchange times for one region pair (Table 4): total time
/// to exchange the dummy job's training messages (≈2 GB) and test messages
/// (≈1 GB), in seconds.
#[derive(Debug, Clone, Copy)]
pub struct CommTimes {
    pub train: f64,
    pub test: f64,
}

impl CommTimes {
    pub fn total(&self) -> f64 {
        self.train + self.test
    }
}

/// Message volumes behind Table 4 (§5.3): "the training and test phases
/// exchange a total of 2 GB in messages and a little more than 1 GB".
pub const DUMMY_TRAIN_GB: f64 = 2.0;
pub const DUMMY_TEST_GB: f64 = 1.0;

/// Ground-truth performance of an environment: what the simulator uses to
/// produce execution/communication times, and what Pre-Scheduling rediscovers.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    /// Dummy-app times per VM type id.
    pub dummy: HashMap<String, DummyTimes>,
    /// Message times per unordered region-name pair.
    pub comm: HashMap<(String, String), CommTimes>,
    /// Baseline VM for execution slowdowns (vm121 in the paper).
    pub baseline_vm: String,
    /// Baseline region pair for communication slowdowns (APT–APT).
    pub baseline_pair: (String, String),
}

impl GroundTruth {
    fn key(a: &str, b: &str) -> (String, String) {
        if a <= b {
            (a.to_string(), b.to_string())
        } else {
            (b.to_string(), a.to_string())
        }
    }

    pub fn dummy_times(&self, vm_id: &str) -> DummyTimes {
        *self
            .dummy
            .get(vm_id)
            .unwrap_or_else(|| panic!("no ground-truth dummy times for {vm_id}"))
    }

    pub fn comm_times(&self, region_a: &str, region_b: &str) -> CommTimes {
        let k = Self::key(region_a, region_b);
        *self
            .comm
            .get(&k)
            .unwrap_or_else(|| panic!("no ground-truth comm times for {k:?}"))
    }

    /// `sl_inst` for a VM type: steady-state dummy time ratio vs baseline VM.
    pub fn exec_slowdown(&self, vm_id: &str) -> f64 {
        self.dummy_times(vm_id).steady() / self.dummy_times(&self.baseline_vm).steady()
    }

    /// `sl_comm` for a region pair: message time ratio vs baseline pair.
    pub fn comm_slowdown(&self, region_a: &str, region_b: &str) -> f64 {
        let base = self
            .comm_times(&self.baseline_pair.0, &self.baseline_pair.1)
            .total();
        self.comm_times(region_a, region_b).total() / base
    }

    /// Effective bandwidth for the pair in GB/s implied by the measurements
    /// (3 GB exchanged over the measured total time). Used by the network
    /// simulator to time arbitrary message sizes.
    pub fn pair_gb_per_sec(&self, region_a: &str, region_b: &str) -> f64 {
        (DUMMY_TRAIN_GB + DUMMY_TEST_GB) / self.comm_times(region_a, region_b).total()
    }
}

/// Tables 3 and 4: the CloudLab ground truth.
pub fn cloudlab_ground_truth() -> GroundTruth {
    let mut dummy = HashMap::new();
    // (vm, train_r1, train_r2, test_r1, test_r2) — Table 3 verbatim.
    let raw: &[(&str, f64, f64, f64, f64)] = &[
        ("vm112", 123.12, 120.93, 1.61, 1.47),
        ("vm114", 163.16, 158.95, 4.71, 4.62),
        ("vm115", 113.22, 110.32, 2.95, 2.86),
        ("vm121", 119.89, 112.83, 2.30, 2.22),
        ("vm122", 139.04, 131.74, 1.93, 1.96),
        ("vm124", 119.05, 110.45, 2.23, 2.12),
        ("vm126", 16.37, 4.53, 1.44, 0.62),
        ("vm135", 128.46, 122.39, 2.79, 2.67),
        ("vm138", 71.67, 60.14, 5.39, 5.24),
        ("vm211", 147.79, 141.62, 4.22, 4.26),
        ("vm212", 263.89, 256.73, 11.18, 11.13),
        ("vm221", 94.23, 92.42, 1.26, 1.20),
        ("vm222", 112.44, 103.59, 1.91, 1.75),
    ];
    for &(id, tr1, tr2, te1, te2) in raw {
        dummy.insert(
            id.to_string(),
            DummyTimes { train_r1: tr1, train_r2: tr2, test_r1: te1, test_r2: te2 },
        );
    }
    let mut comm = HashMap::new();
    // Table 4 verbatim: (region a, region b, train secs, test secs).
    let raw_comm: &[(&str, &str, f64, f64)] = &[
        ("APT", "APT", 5.61, 3.05),
        ("APT", "Clemson", 12.05, 5.94),
        ("APT", "Massachusetts", 106.90, 54.51),
        ("APT", "Utah", 4.84, 2.58),
        ("APT", "Wisconsin", 16.19, 7.64),
        ("Clemson", "Clemson", 5.36, 2.91),
        ("Clemson", "Massachusetts", 75.63, 32.31),
        ("Clemson", "Utah", 11.39, 5.34),
        ("Clemson", "Wisconsin", 6.65, 3.53),
        ("Massachusetts", "Massachusetts", 5.23, 2.81),
        ("Massachusetts", "Utah", 86.08, 35.95),
        ("Massachusetts", "Wisconsin", 138.31, 75.85),
        ("Utah", "Utah", 2.07, 1.15),
        ("Utah", "Wisconsin", 21.81, 10.57),
        ("Wisconsin", "Wisconsin", 5.77, 3.08),
    ];
    for &(a, b, train, test) in raw_comm {
        comm.insert(GroundTruth::key(a, b), CommTimes { train, test });
    }
    GroundTruth {
        dummy,
        comm,
        baseline_vm: "vm121".into(),
        baseline_pair: ("APT".into(), "APT".into()),
    }
}

/// Ground truth for the AWS/GCP proof-of-concept environment. The paper does
/// not republish slowdown tables for Table 9 (they come from [1]); we derive
/// a consistent parameterization calibrated so that the paper's reported
/// outcome holds: the Initial Mapping selects server=vm313 (t2.xlarge) and
/// clients=vm311 (g4dn.2xlarge) all in AWS, with a 10-round TIL job taking
/// ≈2:00:18 on on-demand VMs (§5.7).
pub fn aws_gcp_ground_truth() -> GroundTruth {
    let mut dummy = HashMap::new();
    // Steady ≈ dummy-app time; g4dn (T4) is the baseline = 1.0.
    // V100s are somewhat faster, M60 much slower, CPU-only VMs ~20x slower.
    let raw: &[(&str, f64, f64, f64, f64)] = &[
        ("vm311", 30.0, 24.0, 1.4, 1.0), // T4 baseline: steady 25.0
        ("vm312", 52.0, 44.0, 2.4, 1.0), // M60: 1.8x
        ("vm313", 505.0, 488.0, 13.0, 12.0), // CPU-only: 20x
        ("vm411", 31.5, 25.2, 1.5, 1.05), // T4 in GCP: 1.05x
        ("vm413", 26.0, 21.5, 1.2, 1.0), // V100: 0.9x
        ("vm414", 505.0, 488.0, 13.0, 12.0),
        ("vm422", 26.0, 21.5, 1.2, 1.0),
        ("vm423", 505.0, 488.0, 13.0, 12.0),
    ];
    for &(id, tr1, tr2, te1, te2) in raw {
        dummy.insert(
            id.to_string(),
            DummyTimes { train_r1: tr1, train_r2: tr2, test_r1: te1, test_r2: te2 },
        );
    }
    let mut comm = HashMap::new();
    let raw_comm: &[(&str, &str, f64, f64)] = &[
        // Intra-region transfers are fast; AWS↔GCP crosses the public
        // internet and is markedly slower (calibrated so the §5.7 all-AWS
        // optimum holds).
        ("us-east-1", "us-east-1", 3.3, 1.7),
        ("us-east-1", "us-central1", 25.0, 12.0),
        ("us-east-1", "us-west1", 33.0, 16.0),
        ("us-central1", "us-central1", 3.3, 1.7),
        ("us-central1", "us-west1", 10.0, 5.0),
        ("us-west1", "us-west1", 3.3, 1.7),
    ];
    for &(a, b, train, test) in raw_comm {
        comm.insert(GroundTruth::key(a, b), CommTimes { train, test });
    }
    GroundTruth {
        dummy,
        comm,
        baseline_vm: "vm311".into(),
        baseline_pair: ("us-east-1".into(), "us-east-1".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_slowdowns_reproduced() {
        // The published slowdown column of Table 3, to 3 decimals.
        let gt = cloudlab_ground_truth();
        let expected: &[(&str, f64)] = &[
            ("vm112", 1.064),
            ("vm114", 1.422),
            ("vm115", 0.984),
            ("vm121", 1.000),
            ("vm122", 1.162),
            ("vm124", 0.970),
            ("vm126", 0.045),
            ("vm135", 1.087),
            ("vm138", 0.568),
            ("vm211", 1.268),
            ("vm212", 2.328),
            ("vm221", 0.814),
            ("vm222", 0.916),
        ];
        for &(vm, sl) in expected {
            let got = gt.exec_slowdown(vm);
            // Paper rounding differs slightly on a couple of rows (e.g. the
            // published 0.970 for vm124 vs the 0.978 its own Table 3 inputs
            // imply); 1% tolerance.
            assert!(
                (got - sl).abs() < 0.01,
                "{vm}: computed {got:.4} vs paper {sl}"
            );
        }
    }

    #[test]
    fn table4_slowdowns_reproduced() {
        let gt = cloudlab_ground_truth();
        let expected: &[(&str, &str, f64)] = &[
            ("APT", "APT", 1.000),
            ("APT", "Clemson", 2.078),
            ("APT", "Massachusetts", 18.641),
            ("APT", "Utah", 0.857),
            ("APT", "Wisconsin", 2.752),
            ("Clemson", "Clemson", 0.954),
            ("Clemson", "Massachusetts", 12.464),
            ("Clemson", "Utah", 1.932),
            ("Clemson", "Wisconsin", 1.175),
            ("Massachusetts", "Massachusetts", 0.929),
            ("Massachusetts", "Utah", 14.092),
            ("Massachusetts", "Wisconsin", 24.731),
            ("Utah", "Utah", 0.372),
            ("Utah", "Wisconsin", 3.738),
            ("Wisconsin", "Wisconsin", 1.022),
        ];
        for &(a, b, sl) in expected {
            let got = gt.comm_slowdown(a, b);
            assert!(
                (got - sl).abs() < 0.01,
                "{a}-{b}: computed {got:.4} vs paper {sl}"
            );
        }
    }

    #[test]
    fn comm_lookup_is_symmetric() {
        let gt = cloudlab_ground_truth();
        assert_eq!(
            gt.comm_times("Utah", "Wisconsin").total(),
            gt.comm_times("Wisconsin", "Utah").total()
        );
    }

    #[test]
    fn aws_gcp_ground_truth_covers_catalog() {
        let cat = aws_gcp();
        let gt = aws_gcp_ground_truth();
        for v in &cat.vm_types {
            assert!(gt.dummy.contains_key(&v.id), "missing dummy times for {}", v.id);
        }
        for a in cat.region_ids() {
            for b in cat.region_ids() {
                let _ = gt.comm_times(&cat.region(a).name, &cat.region(b).name);
            }
        }
    }

    #[test]
    fn cloudlab_ground_truth_covers_catalog() {
        let cat = cloudlab();
        let gt = cloudlab_ground_truth();
        for v in &cat.vm_types {
            assert!(gt.dummy.contains_key(&v.id));
        }
        for a in cat.region_ids() {
            for b in cat.region_ids() {
                let _ = gt.comm_times(&cat.region(a).name, &cat.region(b).name);
            }
        }
    }

    #[test]
    fn warmup_positive_on_gpu_vms() {
        let gt = cloudlab_ground_truth();
        assert!(gt.dummy_times("vm126").warmup_extra() > 0.0);
        assert!(gt.dummy_times("vm138").warmup_extra() > 0.0);
    }
}
