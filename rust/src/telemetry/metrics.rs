//! Deterministic counters and histograms.
//!
//! A [`MetricsRegistry`] is a `BTreeMap`-backed bag of named counters and
//! fixed-bucket histograms. Everything about it is deterministic: names
//! iterate in sorted order, histogram buckets are a fixed compile-time
//! schedule, and [`MetricsRegistry::merge`] is plain addition — so merging
//! per-trial registries *in trial index order* (the order
//! `sweep::run_indexed` already guarantees) produces identical bits for
//! any `--jobs N`.

use crate::util::Json;
use std::collections::BTreeMap;

/// Histogram bucket upper bounds in seconds (the last bucket is +∞).
/// Log-ish schedule covering boot waits through multi-day campaigns.
pub const BUCKET_BOUNDS: [f64; 12] = [
    1.0, 10.0, 60.0, 300.0, 600.0, 1800.0, 3600.0, 7200.0, 14_400.0, 43_200.0, 86_400.0,
    604_800.0,
];

/// A fixed-bucket histogram (counts per [`BUCKET_BOUNDS`] bucket plus an
/// overflow bucket, with sum/min/max for the mean and range).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    pub counts: [u64; BUCKET_BOUNDS.len() + 1],
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; BUCKET_BOUNDS.len() + 1],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn observe(&mut self, v: f64) {
        let idx = BUCKET_BOUNDS.iter().position(|&b| v <= b).unwrap_or(BUCKET_BOUNDS.len());
        self.counts[idx] += 1;
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Named counters + histograms with deterministic merge (see module docs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to the named counter (creating it at zero).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record one observation in the named histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.histograms.is_empty()
    }

    /// Additive merge: counters and bucket counts add, ranges widen. Merging
    /// registries in a fixed order is associative on the counters and bucket
    /// counts; histogram sums are f64 adds, so the fixed trial-index order
    /// is what makes cross-worker results bit-identical.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// JSON form: `{counters: {...}, histograms: {name: {n, mean, min, max,
    /// buckets}}}` with sorted keys (the `Json` writer sorts by design).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.insert(k, *v as i64);
        }
        let mut hists = Json::obj();
        for (k, h) in &self.histograms {
            let mut hj = Json::obj();
            hj.insert("n", h.n as i64);
            hj.insert("mean", h.mean());
            if h.n > 0 {
                hj.insert("min", h.min);
                hj.insert("max", h.max);
            }
            hj.insert("buckets", h.counts.iter().map(|&c| c as i64).collect::<Vec<i64>>());
            hists.insert(k, hj);
        }
        let mut j = Json::obj();
        j.insert("counters", counters);
        j.insert("histograms", hists);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_read_back() {
        let mut m = MetricsRegistry::new();
        m.inc("revocations", 2);
        m.inc("revocations", 3);
        assert_eq!(m.counter("revocations"), 5);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn histogram_buckets_cover_the_schedule() {
        let mut h = Histogram::default();
        h.observe(0.5); // bucket 0 (≤ 1 s)
        h.observe(90.0); // ≤ 300 s → bucket 3
        h.observe(1e9); // overflow
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
        assert_eq!(h.counts[BUCKET_BOUNDS.len()], 1);
        assert_eq!(h.n, 3);
        assert!((h.min - 0.5).abs() < 1e-12 && (h.max - 1e9).abs() < 1e-3);
    }

    #[test]
    fn merge_is_additive_and_order_deterministic() {
        let mut a = MetricsRegistry::new();
        a.inc("x", 1);
        a.observe("t", 5.0);
        let mut b = MetricsRegistry::new();
        b.inc("x", 2);
        b.inc("y", 7);
        b.observe("t", 50.0);
        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab.counter("x"), 3);
        assert_eq!(ab.counter("y"), 7);
        let h = ab.histogram("t").unwrap();
        assert_eq!(h.n, 2);
        assert!((h.sum - 55.0).abs() < 1e-12);
        // Same operands in the same order → identical bits.
        let mut ab2 = a.clone();
        ab2.merge(&b);
        assert_eq!(ab, ab2);
    }

    #[test]
    fn json_renders_sorted_and_stable() {
        let mut m = MetricsRegistry::new();
        m.inc("b", 1);
        m.inc("a", 2);
        m.observe("boot", 120.0);
        let s = m.to_json().to_string_compact();
        assert!(s.find("\"a\":2").unwrap() < s.find("\"b\":1").unwrap(), "{s}");
        assert!(s.contains("\"boot\""), "{s}");
    }
}
