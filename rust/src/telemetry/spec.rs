//! The `[telemetry]` spec table.
//!
//! Presence enables: a job spec carrying a `[telemetry]` table (even an
//! empty one) turns the telemetry layer on for that job; without the table
//! the executor's arithmetic *and* its event log are bit-identical to the
//! telemetry-less simulator (parity-enforced by `tests/telemetry.rs`).
//!
//! ```toml
//! [telemetry]
//! enabled   = true   # default true when the table is present
//! spans     = true   # build Round/VmLifetime/Job/Solver spans
//! metrics   = true   # build the counters/histogram registry
//! decisions = true   # record DecisionRecord provenance (multi-fedls explain)
//! ```

use crate::util::tomlmini::{self, Value};
use std::collections::BTreeMap;

type Tbl = BTreeMap<String, Value>;

/// Parsed `[telemetry]` table (see the module docs).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySpec {
    /// Master gate: off means no extra events, no spans, no metrics.
    pub enabled: bool,
    /// Build the span model ([`super::JobTelemetry::vms`] etc.).
    pub spans: bool,
    /// Build the [`super::MetricsRegistry`].
    pub metrics: bool,
    /// Record decision provenance ([`super::DecisionRecord`]).
    pub decisions: bool,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec { enabled: false, spans: true, metrics: true, decisions: true }
    }
}

impl TelemetrySpec {
    /// A fully-enabled spec (what `--trace-out` forces per job).
    pub fn on() -> TelemetrySpec {
        TelemetrySpec { enabled: true, ..TelemetrySpec::default() }
    }

    /// Parse a `[telemetry]` table. Table presence enables telemetry unless
    /// the table itself says `enabled = false`.
    pub fn from_table(tbl: &Tbl) -> anyhow::Result<TelemetrySpec> {
        let flag = |key: &str, default: bool| -> anyhow::Result<bool> {
            match tbl.get(key) {
                None => Ok(default),
                Some(Value::Bool(b)) => Ok(*b),
                Some(_) => anyhow::bail!("[telemetry] {key} must be a boolean"),
            }
        };
        let enabled = flag("enabled", true)?;
        let spans = flag("spans", true)?;
        let metrics = flag("metrics", true)?;
        let decisions = flag("decisions", true)?;
        tomlmini::reject_unknown_keys(
            tbl,
            &["enabled", "spans", "metrics", "decisions"],
            "[telemetry]",
        )?;
        Ok(TelemetrySpec { enabled, spans, metrics, decisions })
    }

    /// True when the run should collect [`super::DecisionRecord`]s.
    pub fn record_decisions(&self) -> bool {
        self.enabled && self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> anyhow::Result<TelemetrySpec> {
        let root = tomlmini::parse(text).unwrap();
        let Some(Value::Table(tbl)) = root.get("telemetry") else {
            panic!("fixture must contain a [telemetry] table");
        };
        TelemetrySpec::from_table(tbl)
    }

    #[test]
    fn default_is_disabled_and_table_presence_enables() {
        assert!(!TelemetrySpec::default().enabled);
        assert!(!TelemetrySpec::default().record_decisions());
        let spec = parse("[telemetry]\n").unwrap();
        assert!(spec.enabled && spec.spans && spec.metrics && spec.decisions);
        assert!(spec.record_decisions());
    }

    #[test]
    fn parses_all_keys() {
        let spec = parse(
            "[telemetry]\nenabled = true\nspans = false\nmetrics = true\ndecisions = false\n",
        )
        .unwrap();
        assert!(spec.enabled);
        assert!(!spec.spans);
        assert!(spec.metrics);
        assert!(!spec.decisions);
        assert!(!spec.record_decisions(), "decisions = false mutes provenance");
        let off = parse("[telemetry]\nenabled = false\n").unwrap();
        assert!(!off.enabled);
        assert!(!off.record_decisions(), "master gate wins over the default");
    }

    #[test]
    fn rejects_unknown_keys_and_bad_types() {
        let err = parse("[telemetry]\nverbose = true\n").unwrap_err().to_string();
        assert!(err.contains("verbose"), "{err}");
        let err = parse("[telemetry]\nspans = 3\n").unwrap_err().to_string();
        assert!(err.contains("spans"), "{err}");
        let err = parse("[telemetry]\ndecisions = \"yes\"\n").unwrap_err().to_string();
        assert!(err.contains("decisions"), "{err}");
    }
}
