//! Telemetry sinks: the JSONL event log and the collapsed-stack flamegraph.
//!
//! * **JSONL** — one compact JSON object per line, keys sorted (the `Json`
//!   writer sorts by construction), floats rendered by the deterministic
//!   shortest-representation formatter. Because workload trials are
//!   expanded with fixed seeds and executed index-ordered, the bytes are
//!   identical for any `--jobs N` (CI diffs `--jobs 1` vs `--jobs 4`).
//! * **Flamegraph** — `folded` collapsed-stack lines (`frame;frame weight`)
//!   over the span tree, weights in integer sim-milliseconds; feed to any
//!   `flamegraph.pl`-compatible renderer.

use crate::util::Json;

use super::{EventKind, JobTelemetry};

/// One event on the workload's cluster clock, attributed to a job/tenant
/// (`None` for cluster-level events like price steps).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Cluster time in seconds (job-local event times are re-anchored by
    /// the admission instant when segments are spliced in).
    pub at: f64,
    pub job: Option<String>,
    pub tenant: Option<String>,
    pub kind: EventKind,
}

impl TraceEvent {
    /// The JSONL line object: `at`/`job`/`tenant` envelope + the kind's
    /// structured fields.
    pub fn to_json(&self) -> Json {
        let mut j = self.kind.to_json();
        j.insert("at", self.at);
        if let Some(job) = &self.job {
            j.insert("job", job.as_str());
        }
        if let Some(tenant) = &self.tenant {
            j.insert("tenant", tenant.as_str());
        }
        j
    }
}

/// Render one trial's trace as JSONL, tagging every line with the grid
/// point and trial index so concatenated campaign traces stay attributable.
pub fn trace_jsonl(point: usize, trial: usize, trace: &[TraceEvent]) -> String {
    let mut out = String::new();
    for e in trace {
        let mut j = e.to_json();
        j.insert("point", point as i64);
        j.insert("trial", trial as i64);
        out.push_str(&j.to_string_compact());
        out.push('\n');
    }
    out
}

/// Collapsed-stack flamegraph of one job's span tree. Frames:
///
/// ```text
/// job;setup                      (submission → FL start: boot/deferral)
/// job;fl;round-N                 (completed round attempts)
/// job;fl;round-N-voided          (attempts voided by revocation/preemption)
/// vms;PROVIDER;VMTYPE#INSTANCE   (billed VM lifetimes)
/// ```
///
/// Weights are integer sim-milliseconds (rounded), one line per frame,
/// deterministic order (span order is event/ledger order).
pub fn flamegraph_folded(tel: &JobTelemetry) -> String {
    let ms = |secs: f64| -> u64 { (secs * 1000.0).round().max(0.0) as u64 };
    let mut out = String::new();
    let setup = tel.job.fl_start - tel.job.start;
    if setup > 0.0 {
        out.push_str(&format!("job;setup {}\n", ms(setup)));
    }
    for r in &tel.rounds {
        let suffix = if r.completed { "" } else { "-voided" };
        out.push_str(&format!("job;fl;round-{}{} {}\n", r.round, suffix, ms(r.end - r.start)));
    }
    for v in &tel.vms {
        out.push_str(&format!(
            "vms;{};{}#{} {}\n",
            v.provider.replace([' ', ';'], "-"),
            v.vm,
            v.instance,
            ms(v.end - v.start)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{JobSpan, MetricsRegistry, RoundSpan, VmLifetimeSpan};

    #[test]
    fn jsonl_lines_carry_envelope_and_kind_fields() {
        let trace = vec![
            TraceEvent {
                at: 0.0,
                job: Some("til-0".into()),
                tenant: Some("acme".into()),
                kind: EventKind::Arrival { job: "til-0".into(), tenant: "acme".into() },
            },
            TraceEvent { at: 3600.0, job: None, tenant: None, kind: EventKind::PriceStep { factor: 1.8 } },
        ];
        let text = trace_jsonl(2, 1, &trace);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"kind\":\"arrival\"") && lines[0].contains("\"point\":2"));
        assert!(lines[1].contains("\"factor\":1.8") && lines[1].contains("\"trial\":1"));
        assert!(lines[0].starts_with('{') && lines[0].ends_with('}'));
    }

    #[test]
    fn flamegraph_folds_rounds_and_vms_with_ms_weights() {
        let tel = JobTelemetry {
            job: JobSpan { start: 0.0, end: 400.0, fl_start: 120.5, fl_end: 400.0 },
            rounds: vec![
                RoundSpan { round: 1, start: 120.5, end: 220.5, completed: true },
                RoundSpan { round: 2, start: 220.5, end: 300.0, completed: false },
            ],
            vms: vec![VmLifetimeSpan {
                vm: "vm126".into(),
                instance: 1,
                provider: "Cloud A".into(),
                region: "Utah".into(),
                spot: true,
                start: 0.0,
                end: 400.0,
                billed_cost: 0.5,
            }],
            solver: Vec::new(),
            metrics: MetricsRegistry::new(),
            decisions: Vec::new(),
        };
        let folded = flamegraph_folded(&tel);
        assert!(folded.contains("job;setup 120500\n"), "{folded}");
        assert!(folded.contains("job;fl;round-1 100000\n"), "{folded}");
        assert!(folded.contains("job;fl;round-2-voided 79500\n"), "{folded}");
        assert!(folded.contains("vms;Cloud-A;vm126#1 400000\n"), "{folded}");
    }
}
