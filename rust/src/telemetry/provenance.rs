//! Decision provenance: *why* every scheduling decision was made.
//!
//! Every decision point in the stack — Initial Mapping solves, Dynamic
//! Scheduler replacements, outlook deferrals, and the workload engine's
//! admission / retry / rejection / preemption-victim choices — emits one
//! [`DecisionRecord`]: a monotonic decision ID, the sim-time instant, the
//! chosen option, and a ranked candidate table where every losing candidate
//! carries a typed [`Elimination`] reason. Records are collected only when
//! `[telemetry]` is enabled (with `decisions = true`, the default), so the
//! telemetry-off path stays bit-identical to the pre-provenance simulator.
//!
//! Records serialize into the `--trace-out` JSONL alongside events (as
//! `"kind":"decision"` lines, with `"kind":"vm-span"` lines for billed VM
//! lifetimes) and are queried by `multi-fedls explain`. Event kinds that
//! *result from* a decision carry the decision ID
//! ([`super::EventKind::decision_id`]), so a trace forms causal chains:
//! revocation → selection decision → provision → billed cost.

use crate::util::Json;

/// Why a candidate lost. One typed reason per eliminated candidate; the
/// chosen candidate carries none.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Elimination {
    /// Its cheapest completion exceeds `B_round`.
    OverBudget,
    /// It cannot finish a round within `T_round`.
    PastDeadline,
    /// The provider's GPU/vCPU quota cannot host it.
    QuotaExhausted,
    /// The Dynamic Scheduler policy bans it (revoked type removed).
    PolicyBanned,
    /// The per-task revocation cap forbids another spot replacement.
    RevocationCapped,
    /// Feasible, but another candidate scores a better objective.
    Dominated,
}

impl Elimination {
    /// Stable machine-readable tag (the JSONL `eliminated` field).
    pub fn key(self) -> &'static str {
        match self {
            Elimination::OverBudget => "over-budget",
            Elimination::PastDeadline => "past-deadline",
            Elimination::QuotaExhausted => "quota-exhausted",
            Elimination::PolicyBanned => "policy-banned",
            Elimination::RevocationCapped => "revocation-capped",
            Elimination::Dominated => "dominated",
        }
    }

    pub fn from_key(key: &str) -> Option<Elimination> {
        match key {
            "over-budget" => Some(Elimination::OverBudget),
            "past-deadline" => Some(Elimination::PastDeadline),
            "quota-exhausted" => Some(Elimination::QuotaExhausted),
            "policy-banned" => Some(Elimination::PolicyBanned),
            "revocation-capped" => Some(Elimination::RevocationCapped),
            "dominated" => Some(Elimination::Dominated),
            _ => None,
        }
    }

    /// Every reason (exhaustiveness tests).
    pub fn all() -> [Elimination; 6] {
        [
            Elimination::OverBudget,
            Elimination::PastDeadline,
            Elimination::QuotaExhausted,
            Elimination::PolicyBanned,
            Elimination::RevocationCapped,
            Elimination::Dominated,
        ]
    }
}

/// Which decision point produced a record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionKind {
    /// An Initial Mapping solve (exact/MILP/baseline/pinned).
    InitialMapping,
    /// An outlook deferral: provisioning delayed past a price spike.
    Deferral,
    /// A Dynamic Scheduler replacement (Algorithms 1–3).
    Replacement,
    /// Workload admission: the job entered the cluster.
    Admission,
    /// Workload admission retry on a price step.
    AdmissionRetry,
    /// Workload rejection: no feasible placement at any price level.
    Rejection,
    /// Workload preemption-victim selection.
    PreemptionVictim,
}

impl DecisionKind {
    /// Stable machine-readable tag (the JSONL `decision_kind` field).
    pub fn key(self) -> &'static str {
        match self {
            DecisionKind::InitialMapping => "initial-mapping",
            DecisionKind::Deferral => "deferral",
            DecisionKind::Replacement => "replacement",
            DecisionKind::Admission => "admission",
            DecisionKind::AdmissionRetry => "admission-retry",
            DecisionKind::Rejection => "rejection",
            DecisionKind::PreemptionVictim => "preemption-victim",
        }
    }

    pub fn from_key(key: &str) -> Option<DecisionKind> {
        match key {
            "initial-mapping" => Some(DecisionKind::InitialMapping),
            "deferral" => Some(DecisionKind::Deferral),
            "replacement" => Some(DecisionKind::Replacement),
            "admission" => Some(DecisionKind::Admission),
            "admission-retry" => Some(DecisionKind::AdmissionRetry),
            "rejection" => Some(DecisionKind::Rejection),
            "preemption-victim" => Some(DecisionKind::PreemptionVictim),
            _ => None,
        }
    }

    /// Every kind (exhaustiveness tests).
    pub fn all() -> [DecisionKind; 7] {
        [
            DecisionKind::InitialMapping,
            DecisionKind::Deferral,
            DecisionKind::Replacement,
            DecisionKind::Admission,
            DecisionKind::AdmissionRetry,
            DecisionKind::Rejection,
            DecisionKind::PreemptionVictim,
        ]
    }
}

/// One row of a decision's ranked candidate table.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Human-stable identity: `"{provider}/{region} {vm}"` for placements,
    /// the job name for preemption victims.
    pub label: String,
    /// Objective value the decision scored this candidate at
    /// (`f64::INFINITY` when infeasibility made scoring moot).
    pub objective: f64,
    /// Spot-price multiplier the scoring used.
    pub price_factor: f64,
    /// `None` for the chosen candidate; the typed loss reason otherwise.
    pub eliminated: Option<Elimination>,
}

impl Candidate {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("label", self.label.as_str());
        // Non-finite objectives (infeasible candidates) are omitted — the
        // compact writer only emits valid JSON numbers.
        if self.objective.is_finite() {
            j.insert("objective", self.objective);
        }
        j.insert("price_factor", self.price_factor);
        if let Some(e) = self.eliminated {
            j.insert("eliminated", e.key());
        }
        j
    }

    pub fn from_json(j: &Json) -> Option<Candidate> {
        Some(Candidate {
            label: j.get("label")?.as_str()?.to_string(),
            objective: j.get("objective").and_then(|v| v.as_f64()).unwrap_or(f64::INFINITY),
            price_factor: j.get("price_factor").and_then(|v| v.as_f64()).unwrap_or(1.0),
            eliminated: j
                .get("eliminated")
                .and_then(|v| v.as_str())
                .and_then(Elimination::from_key),
        })
    }
}

/// One scheduling decision: what was chosen, over which ranked candidates,
/// and why each loser lost.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionRecord {
    /// Monotonic per-trace ID (trace order; gaps allowed after preemption
    /// replays). Events caused by this decision carry the same ID.
    pub id: u64,
    /// Sim-time instant (cluster clock in workload traces).
    pub at: f64,
    pub kind: DecisionKind,
    /// Owning job/tenant (workload traces; `None` in single-job runs).
    pub job: Option<String>,
    pub tenant: Option<String>,
    /// Label of the chosen candidate; `None` when the decision chose
    /// nothing (rejections, zero-deferral advice).
    pub chosen: Option<String>,
    /// One human sentence: why the decision went this way.
    pub reason: String,
    /// Ranked candidate table, best objective first.
    pub candidates: Vec<Candidate>,
    /// VM instance numbers provisioned as a result of this decision
    /// (the initial fleet, or a single replacement).
    pub instances: Vec<u64>,
    /// Σ downstream `VmLifetimeSpan.billed_cost` over `instances`, filled
    /// post-hoc when the run's billing is known.
    pub attributed_cost: Option<f64>,
}

impl DecisionRecord {
    /// Re-anchor a job-local record onto the cluster clock/ID space.
    pub fn rebase(&mut self, id_offset: u64, at_offset: f64) {
        self.id += id_offset;
        self.at += at_offset;
    }

    /// The JSONL line object (`"kind":"decision"` lines; the caller adds
    /// `point`/`trial` envelope keys).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("kind", "decision");
        j.insert("at", self.at);
        j.insert("decision", self.id as i64);
        j.insert("decision_kind", self.kind.key());
        if let Some(job) = &self.job {
            j.insert("job", job.as_str());
        }
        if let Some(tenant) = &self.tenant {
            j.insert("tenant", tenant.as_str());
        }
        if let Some(chosen) = &self.chosen {
            j.insert("chosen", chosen.as_str());
        }
        j.insert("reason", self.reason.as_str());
        j.insert("candidates", Json::Arr(self.candidates.iter().map(|c| c.to_json()).collect()));
        j.insert(
            "instances",
            Json::Arr(self.instances.iter().map(|&i| Json::from(i)).collect()),
        );
        if let Some(cost) = self.attributed_cost {
            j.insert("attributed_cost", cost);
        }
        j
    }

    /// Parse one `"kind":"decision"` JSONL object (the `explain` reader).
    pub fn from_json(j: &Json) -> Option<DecisionRecord> {
        if j.get("kind")?.as_str()? != "decision" {
            return None;
        }
        let kind = DecisionKind::from_key(j.get("decision_kind")?.as_str()?)?;
        let candidates = match j.get("candidates") {
            Some(Json::Arr(items)) => items.iter().filter_map(Candidate::from_json).collect(),
            _ => Vec::new(),
        };
        let instances = match j.get("instances") {
            Some(Json::Arr(items)) => {
                items.iter().filter_map(|v| v.as_f64()).map(|f| f as u64).collect()
            }
            _ => Vec::new(),
        };
        Some(DecisionRecord {
            id: j.get("decision")?.as_f64()? as u64,
            at: j.get("at")?.as_f64()?,
            kind,
            job: j.get("job").and_then(|v| v.as_str()).map(|s| s.to_string()),
            tenant: j.get("tenant").and_then(|v| v.as_str()).map(|s| s.to_string()),
            chosen: j.get("chosen").and_then(|v| v.as_str()).map(|s| s.to_string()),
            reason: j.get("reason").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            candidates,
            instances,
            attributed_cost: j.get("attributed_cost").and_then(|v| v.as_f64()),
        })
    }

    /// One-line human summary (the `explain` listing row).
    pub fn render(&self) -> String {
        let who = match (&self.job, &self.tenant) {
            (Some(j), Some(t)) if !t.is_empty() => format!(" [{j}/{t}]"),
            (Some(j), _) => format!(" [{j}]"),
            _ => String::new(),
        };
        let chose = match &self.chosen {
            Some(c) => format!("chose {c}"),
            None => "chose nothing".to_string(),
        };
        let cost = match self.attributed_cost {
            Some(c) => format!(", ${c:.4} billed downstream"),
            None => String::new(),
        };
        format!(
            "decision #{} at t={:.0}s{} — {}: {} over {} candidate(s) ({}{})",
            self.id,
            self.at,
            who,
            self.kind.key(),
            chose,
            self.candidates.len(),
            self.reason,
            cost
        )
    }

    /// Multi-line human rendering with the full ranked candidate table.
    pub fn render_full(&self) -> String {
        let mut out = self.render();
        out.push('\n');
        for c in &self.candidates {
            let obj = if c.objective.is_finite() {
                format!("{:.5}", c.objective)
            } else {
                "inf".to_string()
            };
            let verdict = match c.eliminated {
                None => "chosen".to_string(),
                Some(e) => e.key().to_string(),
            };
            out.push_str(&format!(
                "  {:<30} objective {:<10} price {:.3}x  {}\n",
                c.label, obj, c.price_factor, verdict
            ));
        }
        out
    }
}

/// One billed VM lifetime as a trace line (`"kind":"vm-span"`), carrying
/// the job attribution the `explain --vm` query sums over.
#[derive(Debug, Clone, PartialEq)]
pub struct VmSpanRecord {
    pub job: Option<String>,
    pub tenant: Option<String>,
    pub vm: String,
    pub instance: u64,
    pub provider: String,
    pub region: String,
    pub spot: bool,
    pub start: f64,
    pub end: f64,
    pub billed_cost: f64,
}

impl VmSpanRecord {
    /// Re-anchor a job-local span onto the cluster clock.
    pub fn rebase(&mut self, at_offset: f64) {
        self.start += at_offset;
        self.end += at_offset;
    }

    /// The JSONL line object (the caller adds `point`/`trial` keys).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("kind", "vm-span");
        j.insert("at", self.start);
        if let Some(job) = &self.job {
            j.insert("job", job.as_str());
        }
        if let Some(tenant) = &self.tenant {
            j.insert("tenant", tenant.as_str());
        }
        j.insert("vm", self.vm.as_str());
        j.insert("instance", self.instance as i64);
        j.insert("provider", self.provider.as_str());
        j.insert("region", self.region.as_str());
        j.insert("market", if self.spot { "spot" } else { "on-demand" });
        j.insert("end", self.end);
        j.insert("billed_cost", self.billed_cost);
        j
    }

    /// Parse one `"kind":"vm-span"` JSONL object.
    pub fn from_json(j: &Json) -> Option<VmSpanRecord> {
        if j.get("kind")?.as_str()? != "vm-span" {
            return None;
        }
        Some(VmSpanRecord {
            job: j.get("job").and_then(|v| v.as_str()).map(|s| s.to_string()),
            tenant: j.get("tenant").and_then(|v| v.as_str()).map(|s| s.to_string()),
            vm: j.get("vm")?.as_str()?.to_string(),
            instance: j.get("instance").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            provider: j.get("provider").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            region: j.get("region").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            spot: j.get("market").and_then(|v| v.as_str()) == Some("spot"),
            start: j.get("at")?.as_f64()?,
            end: j.get("end").and_then(|v| v.as_f64()).unwrap_or(0.0),
            billed_cost: j.get("billed_cost").and_then(|v| v.as_f64()).unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> DecisionRecord {
        DecisionRecord {
            id: 7,
            at: 3600.0,
            kind: DecisionKind::Replacement,
            job: Some("high".into()),
            tenant: Some("acme".into()),
            chosen: Some("Cloud A/Utah vm138".into()),
            reason: "minimizes the weighted objective".into(),
            candidates: vec![
                Candidate {
                    label: "Cloud A/Utah vm138".into(),
                    objective: 0.123,
                    price_factor: 1.0,
                    eliminated: None,
                },
                Candidate {
                    label: "Cloud A/Utah vm126".into(),
                    objective: f64::INFINITY,
                    price_factor: 1.0,
                    eliminated: Some(Elimination::PolicyBanned),
                },
            ],
            instances: vec![6],
            attributed_cost: Some(1.25),
        }
    }

    #[test]
    fn keys_round_trip() {
        for k in DecisionKind::all() {
            assert_eq!(DecisionKind::from_key(k.key()), Some(k));
        }
        for e in Elimination::all() {
            assert_eq!(Elimination::from_key(e.key()), Some(e));
        }
        assert_eq!(DecisionKind::from_key("nope"), None);
        assert_eq!(Elimination::from_key("nope"), None);
    }

    #[test]
    fn decision_json_round_trips() {
        let r = record();
        let j = r.to_json();
        let s = j.to_string_compact();
        assert!(s.contains("\"kind\":\"decision\""), "{s}");
        assert!(s.contains("\"decision\":7"), "{s}");
        assert!(!s.contains("inf"), "non-finite objectives must be omitted: {s}");
        let parsed = Json::parse(&s).unwrap();
        let back = DecisionRecord::from_json(&parsed).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn vm_span_json_round_trips() {
        let span = VmSpanRecord {
            job: Some("low-0".into()),
            tenant: Some("zeta".into()),
            vm: "vm311".into(),
            instance: 3,
            provider: "AWS".into(),
            region: "us-east-1".into(),
            spot: true,
            start: 120.0,
            end: 4000.0,
            billed_cost: 0.75,
        };
        let s = span.to_json().to_string_compact();
        assert!(s.contains("\"kind\":\"vm-span\""), "{s}");
        assert!(s.contains("\"at\":120"), "at = span start: {s}");
        let back = VmSpanRecord::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(back, span);
    }

    #[test]
    fn rebase_shifts_ids_and_times() {
        let mut r = record();
        r.rebase(100, 500.0);
        assert_eq!(r.id, 107);
        assert!((r.at - 4100.0).abs() < 1e-12);
        let mut v = VmSpanRecord::from_json(
            &Json::parse(
                "{\"kind\":\"vm-span\",\"at\":10,\"vm\":\"vm1\",\"end\":20,\"instance\":1}",
            )
            .unwrap(),
        )
        .unwrap();
        v.rebase(5.0);
        assert!((v.start - 15.0).abs() < 1e-12 && (v.end - 25.0).abs() < 1e-12);
    }

    #[test]
    fn renderings_carry_the_essentials() {
        let r = record();
        let line = r.render();
        assert!(line.contains("decision #7"), "{line}");
        assert!(line.contains("replacement"), "{line}");
        assert!(line.contains("Cloud A/Utah vm138"), "{line}");
        assert!(line.contains("$1.2500 billed downstream"), "{line}");
        let full = r.render_full();
        assert!(full.contains("policy-banned"), "{full}");
        assert!(full.contains("chosen"), "{full}");
    }

    #[test]
    fn parsers_reject_other_kinds() {
        let ev = Json::parse("{\"kind\":\"revocation\",\"at\":1}").unwrap();
        assert!(DecisionRecord::from_json(&ev).is_none());
        assert!(VmSpanRecord::from_json(&ev).is_none());
    }
}
