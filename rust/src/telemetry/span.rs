//! The span model: sim-clock intervals reconstructed from the typed event
//! log plus the billing ledger.
//!
//! Spans are built *post-hoc* at the end of one executor run — the hot loop
//! never maintains span state, which is what keeps the telemetry-off (and
//! even telemetry-on) overhead near zero. Cost attribution is exact by
//! construction: every [`VmLifetimeSpan::billed_cost`] is the ledger's own
//! per-charge arithmetic ([`Ledger::charge_cost`]), summed in charge order,
//! so the span total equals [`Ledger::vm_cost`] bit for bit
//! (`tests/telemetry.rs` enforces this on the Table 5 configuration).

use crate::cloud::{Catalog, Market};
use crate::cloudsim::Ledger;
use crate::coordinator::sim::SimEvent;
use crate::simul::SimTime;

use super::{DecisionRecord, EventKind, MetricsRegistry, TelemetrySpec};

/// The root span: one job from submission (t = 0) to teardown, with the FL
/// execution window inside it.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpan {
    pub start: f64,
    pub end: f64,
    pub fl_start: f64,
    pub fl_end: f64,
}

/// One round *attempt*: opened at `RoundStart`, closed by `RoundEnd`
/// (`completed = true`) or by the revocation/preemption that voided it.
#[derive(Debug, Clone, PartialEq)]
pub struct RoundSpan {
    pub round: u32,
    pub start: f64,
    pub end: f64,
    pub completed: bool,
}

/// One billed VM charge as a span: provision to termination (or `now` for
/// a charge still open), with the ledger's exact billed cost attached.
#[derive(Debug, Clone, PartialEq)]
pub struct VmLifetimeSpan {
    pub vm: String,
    pub instance: u64,
    pub provider: String,
    pub region: String,
    pub spot: bool,
    pub start: f64,
    pub end: f64,
    pub billed_cost: f64,
}

/// One solver invocation (instantaneous on the sim clock — solving takes
/// zero simulated time; the span records *when* and *why* it ran).
#[derive(Debug, Clone, PartialEq)]
pub struct SolverSpan {
    pub what: String,
    pub at: f64,
}

/// Everything telemetry collected for one executor run.
#[derive(Debug, Clone, PartialEq)]
pub struct JobTelemetry {
    pub job: JobSpan,
    pub rounds: Vec<RoundSpan>,
    pub vms: Vec<VmLifetimeSpan>,
    pub solver: Vec<SolverSpan>,
    pub metrics: MetricsRegistry,
    /// Decision provenance, filled by the executor after the run (the
    /// span pass reconstructs intervals; decisions are recorded live at
    /// each decision point and attributed against `vms` afterwards).
    pub decisions: Vec<DecisionRecord>,
}

impl JobTelemetry {
    /// Sum of per-VM billed costs, in charge order — must equal the
    /// ledger's `vm_cost` bit for bit (same addends, same order).
    pub fn vm_billed_total(&self) -> f64 {
        self.vms.iter().map(|s| s.billed_cost).sum()
    }
}

/// Reconstruct spans + metrics from one run's event log and ledger.
/// `now` is the teardown instant, `fl_start` the instant FL rounds began.
pub fn build_job_telemetry(
    spec: &TelemetrySpec,
    catalog: &Catalog,
    ledger: &Ledger,
    events: &[SimEvent],
    now: SimTime,
    fl_start: SimTime,
) -> JobTelemetry {
    let mut rounds = Vec::new();
    let mut vms = Vec::new();
    let mut solver = Vec::new();
    if spec.spans {
        // Round spans: pair each RoundStart with the event that ends the
        // attempt (RoundEnd, or the revocation/preemption voiding it).
        let mut open: Option<(u32, f64)> = None;
        for e in events {
            match &e.kind {
                EventKind::RoundStart { round, .. } => open = Some((*round, e.at.secs())),
                EventKind::RoundEnd { round, .. } => {
                    if let Some((r, start)) = open.take() {
                        debug_assert_eq!(r, *round);
                        rounds.push(RoundSpan {
                            round: r,
                            start,
                            end: e.at.secs(),
                            completed: true,
                        });
                    }
                }
                EventKind::BatchedRevocation { .. }
                | EventKind::Revocation { .. }
                | EventKind::Preemption { .. } => {
                    if let Some((r, start)) = open.take() {
                        rounds.push(RoundSpan {
                            round: r,
                            start,
                            end: e.at.secs(),
                            completed: false,
                        });
                    }
                }
                _ => {}
            }
        }
        // A round still open at teardown (preempted mid-boot) closes there.
        if let Some((r, start)) = open.take() {
            rounds.push(RoundSpan { round: r, start, end: now.secs(), completed: false });
        }

        for c in &ledger.vm_charges {
            let vm = catalog.vm(c.vm_type);
            vms.push(VmLifetimeSpan {
                vm: vm.id.clone(),
                instance: c.vm.0,
                provider: catalog.provider(catalog.provider_of(c.vm_type)).name.clone(),
                region: catalog.region(catalog.region_of(c.vm_type)).name.clone(),
                spot: c.market == Market::Spot,
                start: c.start.secs(),
                end: c.end.unwrap_or(now).secs(),
                billed_cost: ledger.charge_cost(c, now),
            });
        }

        for e in events {
            match &e.kind {
                EventKind::InitialMapping { .. } => {
                    solver.push(SolverSpan { what: "initial-mapping".into(), at: e.at.secs() })
                }
                EventKind::Replacement { .. } => {
                    solver.push(SolverSpan { what: "dynsched-replacement".into(), at: e.at.secs() })
                }
                _ => {}
            }
        }
    }

    let mut metrics = MetricsRegistry::new();
    if spec.metrics {
        for e in events {
            metrics.inc(&format!("events.{}", e.kind.key()), 1);
            match &e.kind {
                EventKind::Deferral { defer_secs, .. } => {
                    metrics.observe("deferral_secs", *defer_secs);
                }
                EventKind::Provision { boot_done, .. }
                | EventKind::Replacement { boot_done, .. } => {
                    metrics.observe("boot_secs", (*boot_done - e.at).max(0.0));
                }
                EventKind::CheckpointRestore { lost, .. } => {
                    metrics.inc("rounds.lost", u64::from(*lost));
                }
                EventKind::Preemption { lost, .. } => {
                    metrics.inc("rounds.lost", u64::from(*lost));
                }
                EventKind::RoundEnd { egress_gb, .. } => {
                    metrics.inc("rounds.completed", 1);
                    metrics.observe("round_egress_gb", *egress_gb);
                }
                _ => {}
            }
        }
        metrics.inc(
            "solver.invocations",
            metrics.counter("events.initial-mapping") + metrics.counter("events.replacement"),
        );
        for span in &rounds {
            if span.completed {
                metrics.observe("round_secs", span.end - span.start);
            }
        }
        for span in &vms {
            metrics.observe("vm_billed_cost", span.billed_cost);
            metrics.observe("vm_lifetime_secs", span.end - span.start);
        }
    }

    JobTelemetry {
        job: JobSpan {
            start: 0.0,
            end: now.secs(),
            fl_start: fl_start.secs(),
            fl_end: now.secs(),
        },
        rounds,
        vms,
        solver,
        metrics,
        decisions: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, kind: EventKind) -> SimEvent {
        SimEvent { at: SimTime::from_secs(at), kind }
    }

    #[test]
    fn round_spans_pair_starts_with_their_closers() {
        let cat = crate::cloud::tables::cloudlab();
        let ledger = Ledger::new();
        let events = vec![
            ev(0.0, EventKind::RoundStart { round: 1, predicted_secs: 100.0 }),
            ev(100.0, EventKind::RoundEnd { round: 1, egress_gb: 2.0 }),
            ev(100.0, EventKind::RoundStart { round: 2, predicted_secs: 100.0 }),
            ev(
                150.0,
                EventKind::Revocation {
                    task: "server".into(),
                    vm: "vm126".into(),
                    round: 2,
                    provider: "Cloud A".into(),
                    region: "Utah".into(),
                },
            ),
            ev(400.0, EventKind::RoundStart { round: 2, predicted_secs: 100.0 }),
            ev(500.0, EventKind::RoundEnd { round: 2, egress_gb: 2.0 }),
        ];
        let tel = build_job_telemetry(
            &TelemetrySpec::on(),
            &cat,
            &ledger,
            &events,
            SimTime::from_secs(500.0),
            SimTime::ZERO,
        );
        assert_eq!(tel.rounds.len(), 3);
        assert!(tel.rounds[0].completed);
        assert!(!tel.rounds[1].completed);
        assert!((tel.rounds[1].end - 150.0).abs() < 1e-12);
        assert!(tel.rounds[2].completed);
        assert_eq!(tel.metrics.counter("rounds.completed"), 2);
        assert_eq!(tel.metrics.counter("events.revocation"), 1);
        let h = tel.metrics.histogram("round_secs").unwrap();
        assert_eq!(h.n, 2);
    }

    #[test]
    fn vm_spans_bill_exactly_what_the_ledger_bills() {
        use crate::cloudsim::VmId;
        let cat = crate::cloud::tables::cloudlab();
        let mut ledger = Ledger::new();
        let vm126 = cat.vm_by_id("vm126").unwrap();
        let vm121 = cat.vm_by_id("vm121").unwrap();
        ledger.open_vm(&cat, VmId(1), vm126, Market::OnDemand, SimTime::ZERO);
        ledger.open_vm(&cat, VmId(2), vm121, Market::Spot, SimTime::ZERO);
        ledger.close_vm(VmId(2), SimTime::from_secs(1800.0));
        let now = SimTime::from_secs(3600.0);
        let tel = build_job_telemetry(&TelemetrySpec::on(), &cat, &ledger, &[], now, SimTime::ZERO);
        assert_eq!(tel.vms.len(), 2);
        assert_eq!(tel.vm_billed_total().to_bits(), ledger.vm_cost(now).to_bits());
        assert!(tel.vms[1].spot);
        assert_eq!(tel.vms[0].provider, "Cloud A");
    }

    #[test]
    fn spans_flag_gates_the_span_model_but_not_metrics() {
        let cat = crate::cloud::tables::cloudlab();
        let ledger = Ledger::new();
        let spec = TelemetrySpec { enabled: true, spans: false, metrics: true, decisions: true };
        let events = vec![ev(0.0, EventKind::FlStart)];
        let tel =
            build_job_telemetry(&spec, &cat, &ledger, &events, SimTime::from_secs(1.0), SimTime::ZERO);
        assert!(tel.rounds.is_empty() && tel.vms.is_empty() && tel.solver.is_empty());
        assert_eq!(tel.metrics.counter("events.fl-start"), 1);
    }
}
