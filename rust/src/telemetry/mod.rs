//! Structured telemetry: typed events, sim-clock spans, metrics, sinks.
//!
//! The observability layer of the stack. Four pieces:
//!
//! * [`EventKind`] — the typed event vocabulary. The executor's event log
//!   ([`crate::coordinator::sim::SimEvent`]) carries these instead of
//!   free-text strings; [`EventKind::render`] reproduces the historical
//!   human-readable lines character for character (parity-enforced).
//! * [`span`] — `RoundSpan` / `VmLifetimeSpan` / `JobSpan` / `SolverSpan`
//!   reconstructed post-hoc from the event log + billing ledger; per-VM
//!   billed cost attributes exactly (bitwise) to the `Ledger` total.
//! * [`MetricsRegistry`] — deterministic counters/histograms that merge
//!   additively in trial index order (bit-identical for any `--jobs N`).
//! * [`sink`] — JSONL event-log export (`--trace-out`), collapsed-stack
//!   flamegraphs, and the structures behind `multi-fedls report`.
//! * [`provenance`] — [`DecisionRecord`]s explaining *why* every scheduling
//!   decision went the way it did (ranked candidates with typed elimination
//!   reasons), queried by `multi-fedls explain`.
//!
//! Everything is gated by the `[telemetry]` spec table ([`TelemetrySpec`],
//! off by default): telemetry-off runs are bit-identical to the
//! pre-telemetry simulator — same arithmetic, same event list — and the
//! enabled path only appends events and does one post-hoc pass, so the
//! overhead is near zero either way (`benches/telemetry_overhead.rs`).

pub mod event;
pub mod metrics;
pub mod provenance;
pub mod sink;
pub mod span;
pub mod spec;

pub use event::EventKind;
pub use metrics::{Histogram, MetricsRegistry};
pub use provenance::{Candidate, DecisionKind, DecisionRecord, Elimination, VmSpanRecord};
pub use sink::{flamegraph_folded, trace_jsonl, TraceEvent};
pub use span::{
    build_job_telemetry, JobSpan, JobTelemetry, RoundSpan, SolverSpan, VmLifetimeSpan,
};
pub use spec::TelemetrySpec;
