//! The typed event vocabulary.
//!
//! Every observable state change in the simulator is one [`EventKind`]
//! variant carrying structured fields (task, VM type, provider/region,
//! market, cost-relevant quantities). The free-text lines the simulator
//! historically emitted are now a *rendering* of these events
//! ([`EventKind::render`]) — `tests/framework_parity.rs` pins the rendered
//! strings to the frozen pre-refactor simulator character for character.
//!
//! Kinds split into two groups:
//!
//! * **core** events that the executor always records (initial mapping,
//!   deferral, revocations, replacements, restores, preemption, teardown) —
//!   telemetry-off emits exactly these, bit-identical to the historical
//!   event log;
//! * **telemetry-only** events (`Provision`, `RoundStart`/`RoundEnd`,
//!   `CheckpointSave`) plus the workload-level kinds (`Arrival`,
//!   `Admission`, `QuotaWait`, `PriceStep`, `AdmissionRetry`, `Rejection`,
//!   `JobComplete`) that only appear when `[telemetry]` is enabled.

use crate::simul::SimTime;
use crate::util::Json;

/// One structured simulation event (see the module docs for the split
/// between always-on core kinds and telemetry-only kinds).
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Initial Mapping solved (§4.2): the chosen placement plus the solver's
    /// predicted per-round makespan/cost.
    InitialMapping {
        server: String,
        clients: Vec<String>,
        predicted_makespan: f64,
        predicted_cost: f64,
        /// Provenance: the [`super::DecisionRecord`] this solve produced.
        decision: Option<u64>,
    },
    /// Outlook deferral: provisioning delayed past a price spike.
    Deferral { defer_secs: f64, decision: Option<u64> },
    /// Every VM booted; synchronous FL rounds begin.
    FlStart,
    /// A VM instance was requested (telemetry-only).
    Provision {
        task: String,
        vm: String,
        provider: String,
        region: String,
        spot: bool,
        boot_done: SimTime,
        /// Provenance: the mapping/replacement decision that caused it.
        decision: Option<u64>,
    },
    /// A round attempt began (telemetry-only). One round may start several
    /// times: every revocation voids the in-flight attempt.
    RoundStart { round: u32, predicted_secs: f64 },
    /// A round completed (telemetry-only); `egress_gb` is the round's
    /// message-exchange volume across all clients (Eq. 6).
    RoundEnd { round: u32, egress_gb: f64 },
    /// The FT module saved a server-side checkpoint (telemetry-only).
    CheckpointSave { round: u32 },
    /// Several co-timed revocations processed as one batched event.
    BatchedRevocation { count: usize },
    /// A spot VM was revoked mid-round.
    Revocation { task: String, vm: String, round: u32, provider: String, region: String },
    /// The Dynamic Scheduler picked a replacement (§4.4).
    Replacement {
        task: String,
        vm: String,
        value: f64,
        boot_done: SimTime,
        decision: Option<u64>,
    },
    /// Server loss rolled progress back to the freshest checkpoint (§4.3).
    CheckpointRestore { restore_round: u32, lost: u32 },
    /// Workload-level checkpoint-preemption halted the job.
    Preemption { round: u32, lost: u32, decision: Option<u64> },
    /// All live VMs terminated.
    Teardown { preempted: bool },
    /// A job entered the cluster (workload-level, telemetry-only).
    Arrival { job: String, tenant: String },
    /// A job was admitted after `wait_secs` in the queue.
    Admission { job: String, wait_secs: f64, decision: Option<u64> },
    /// Admission failed on residual quota; the job stays queued.
    QuotaWait { job: String },
    /// The cluster clock crossed a spot-price step; `factor` is the new
    /// price multiplier.
    PriceStep { factor: f64 },
    /// A price step triggered an admission retry for a queued job.
    AdmissionRetry { job: String, decision: Option<u64> },
    /// A job was rejected (infeasible or admission policy).
    Rejection { job: String, reason: String, decision: Option<u64> },
    /// A job finished; the closing cost/progress summary.
    JobComplete {
        job: String,
        tenant: String,
        cost: f64,
        rounds: u32,
        revocations: u32,
        preemptions: u32,
        wait_secs: f64,
        fl_secs: f64,
    },
}

impl EventKind {
    /// Stable machine-readable tag (the JSONL `kind` field).
    pub fn key(&self) -> &'static str {
        match self {
            EventKind::InitialMapping { .. } => "initial-mapping",
            EventKind::Deferral { .. } => "deferral",
            EventKind::FlStart => "fl-start",
            EventKind::Provision { .. } => "provision",
            EventKind::RoundStart { .. } => "round-start",
            EventKind::RoundEnd { .. } => "round-end",
            EventKind::CheckpointSave { .. } => "checkpoint-save",
            EventKind::BatchedRevocation { .. } => "batched-revocation",
            EventKind::Revocation { .. } => "revocation",
            EventKind::Replacement { .. } => "replacement",
            EventKind::CheckpointRestore { .. } => "checkpoint-restore",
            EventKind::Preemption { .. } => "preemption",
            EventKind::Teardown { .. } => "teardown",
            EventKind::Arrival { .. } => "arrival",
            EventKind::Admission { .. } => "admission",
            EventKind::QuotaWait { .. } => "quota-wait",
            EventKind::PriceStep { .. } => "price-step",
            EventKind::AdmissionRetry { .. } => "admission-retry",
            EventKind::Rejection { .. } => "rejection",
            EventKind::JobComplete { .. } => "job-complete",
        }
    }

    /// The provenance decision that caused this event, for the kinds that
    /// result from one (mapping, deferral, provision, replacement,
    /// preemption, admission, retry, rejection). Always `None` when
    /// telemetry is off or `decisions = false`.
    pub fn decision_id(&self) -> Option<u64> {
        match self {
            EventKind::InitialMapping { decision, .. }
            | EventKind::Deferral { decision, .. }
            | EventKind::Provision { decision, .. }
            | EventKind::Replacement { decision, .. }
            | EventKind::Preemption { decision, .. }
            | EventKind::Admission { decision, .. }
            | EventKind::AdmissionRetry { decision, .. }
            | EventKind::Rejection { decision, .. } => *decision,
            _ => None,
        }
    }

    /// Shift a carried decision ID by `offset` (re-anchoring job-local IDs
    /// onto the workload trace's cluster-wide ID space).
    pub fn shift_decision_id(&mut self, offset: u64) {
        match self {
            EventKind::InitialMapping { decision, .. }
            | EventKind::Deferral { decision, .. }
            | EventKind::Provision { decision, .. }
            | EventKind::Replacement { decision, .. }
            | EventKind::Preemption { decision, .. }
            | EventKind::Admission { decision, .. }
            | EventKind::AdmissionRetry { decision, .. }
            | EventKind::Rejection { decision, .. } => {
                if let Some(id) = decision {
                    *id += offset;
                }
            }
            _ => {}
        }
    }

    /// True for the kinds the executor only records when telemetry is on.
    pub fn telemetry_only(&self) -> bool {
        matches!(
            self,
            EventKind::Provision { .. }
                | EventKind::RoundStart { .. }
                | EventKind::RoundEnd { .. }
                | EventKind::CheckpointSave { .. }
                | EventKind::Arrival { .. }
                | EventKind::Admission { .. }
                | EventKind::QuotaWait { .. }
                | EventKind::PriceStep { .. }
                | EventKind::AdmissionRetry { .. }
                | EventKind::Rejection { .. }
                | EventKind::JobComplete { .. }
        )
    }

    /// Human-readable line for this event at instant `at`. For the core
    /// kinds this reproduces the historical free-text `what` strings
    /// character for character (parity-enforced).
    pub fn render(&self, at: SimTime) -> String {
        match self {
            EventKind::InitialMapping {
                server, clients, predicted_makespan, predicted_cost, ..
            } => {
                format!(
                    "initial mapping: server={server} clients={clients:?} \
                     (predicted round {predicted_makespan:.1}s, ${predicted_cost:.4})"
                )
            }
            EventKind::Deferral { defer_secs, .. } => {
                format!("outlook: provisioning deferred {defer_secs:.0}s past the price spike")
            }
            EventKind::FlStart => "all VMs prepared; FL execution starts".into(),
            EventKind::Provision { task, vm, provider, region, spot, boot_done, .. } => {
                format!(
                    "provision: {task} on {vm} ({provider}/{region}, {}); booting until {}",
                    if *spot { "spot" } else { "on-demand" },
                    boot_done.hms()
                )
            }
            EventKind::RoundStart { round, predicted_secs } => {
                format!("round {round} started (predicted {predicted_secs:.1}s)")
            }
            EventKind::RoundEnd { round, egress_gb } => {
                format!("round {round} complete ({egress_gb:.3} GB exchanged)")
            }
            EventKind::CheckpointSave { round } => {
                format!("server checkpoint saved at round {round}")
            }
            EventKind::BatchedRevocation { count } => {
                format!("batched event: {count} co-timed revocations")
            }
            EventKind::Revocation { task, vm, round, .. } => {
                format!("revocation: {task} on {vm} during round {round}")
            }
            EventKind::Replacement { task, vm, value, boot_done, .. } => {
                format!(
                    "dynamic scheduler: {task} → {vm} (value {value:.5}); booting until {}",
                    boot_done.hms()
                )
            }
            EventKind::CheckpointRestore { restore_round, lost } => {
                format!("server restore from round {restore_round} (lost {lost} rounds)")
            }
            EventKind::Preemption { round, lost, .. } => {
                format!(
                    "preempted at {} (checkpointed progress: round {round}, {lost} lost)",
                    at.hms()
                )
            }
            EventKind::Teardown { preempted } => {
                if *preempted {
                    "preemption teardown; VMs terminated".into()
                } else {
                    "all rounds complete; VMs terminated".into()
                }
            }
            EventKind::Arrival { job, tenant } => {
                format!("arrival: {job} (tenant {tenant})")
            }
            EventKind::Admission { job, wait_secs, .. } => {
                format!("admission: {job} after {wait_secs:.0}s in queue")
            }
            EventKind::QuotaWait { job } => {
                format!("quota wait: {job} blocked on residual quota")
            }
            EventKind::PriceStep { factor } => {
                format!("price step: spot factor now {factor:.3}×")
            }
            EventKind::AdmissionRetry { job, .. } => {
                format!("admission retry: {job} re-solved on the price step")
            }
            EventKind::Rejection { job, reason, .. } => {
                format!("rejection: {job} ({reason})")
            }
            EventKind::JobComplete { job, cost, rounds, revocations, .. } => {
                format!(
                    "job complete: {job} (${cost:.4}, {rounds} rounds, {revocations} revocations)"
                )
            }
        }
    }

    /// Structured-field JSON for the JSONL sink (kind tag included; the
    /// caller adds `at`/`job`/`tenant` envelope keys).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.insert("kind", self.key());
        // Decision provenance rides on every decision-caused kind; absent
        // when telemetry is off, so the off-path JSONL shape is unchanged.
        if let Some(id) = self.decision_id() {
            j.insert("decision", id as i64);
        }
        match self {
            EventKind::InitialMapping {
                server, clients, predicted_makespan, predicted_cost, ..
            } => {
                j.insert("server", server.as_str());
                j.insert("clients", clients.clone());
                j.insert("predicted_makespan_secs", *predicted_makespan);
                j.insert("predicted_cost", *predicted_cost);
            }
            EventKind::Deferral { defer_secs, .. } => {
                j.insert("defer_secs", *defer_secs);
            }
            EventKind::FlStart => {}
            EventKind::Provision { task, vm, provider, region, spot, boot_done, .. } => {
                j.insert("task", task.as_str());
                j.insert("vm", vm.as_str());
                j.insert("provider", provider.as_str());
                j.insert("region", region.as_str());
                j.insert("market", if *spot { "spot" } else { "on-demand" });
                j.insert("boot_done_secs", boot_done.secs());
            }
            EventKind::RoundStart { round, predicted_secs } => {
                j.insert("round", *round as i64);
                j.insert("predicted_secs", *predicted_secs);
            }
            EventKind::RoundEnd { round, egress_gb } => {
                j.insert("round", *round as i64);
                j.insert("egress_gb", *egress_gb);
            }
            EventKind::CheckpointSave { round } => {
                j.insert("round", *round as i64);
            }
            EventKind::BatchedRevocation { count } => {
                j.insert("count", *count as i64);
            }
            EventKind::Revocation { task, vm, round, provider, region } => {
                j.insert("task", task.as_str());
                j.insert("vm", vm.as_str());
                j.insert("round", *round as i64);
                j.insert("provider", provider.as_str());
                j.insert("region", region.as_str());
            }
            EventKind::Replacement { task, vm, value, boot_done, .. } => {
                j.insert("task", task.as_str());
                j.insert("vm", vm.as_str());
                j.insert("value", *value);
                j.insert("boot_done_secs", boot_done.secs());
            }
            EventKind::CheckpointRestore { restore_round, lost } => {
                j.insert("restore_round", *restore_round as i64);
                j.insert("rounds_lost", *lost as i64);
            }
            EventKind::Preemption { round, lost, .. } => {
                j.insert("round", *round as i64);
                j.insert("rounds_lost", *lost as i64);
            }
            EventKind::Teardown { preempted } => {
                j.insert("preempted", *preempted);
            }
            EventKind::Arrival { job, tenant } => {
                j.insert("job", job.as_str());
                j.insert("tenant", tenant.as_str());
            }
            EventKind::Admission { job, wait_secs, .. } => {
                j.insert("job", job.as_str());
                j.insert("wait_secs", *wait_secs);
            }
            EventKind::QuotaWait { job } => {
                j.insert("job", job.as_str());
            }
            EventKind::PriceStep { factor } => {
                j.insert("factor", *factor);
            }
            EventKind::AdmissionRetry { job, .. } => {
                j.insert("job", job.as_str());
            }
            EventKind::Rejection { job, reason, .. } => {
                j.insert("job", job.as_str());
                j.insert("reason", reason.as_str());
            }
            EventKind::JobComplete {
                job,
                tenant,
                cost,
                rounds,
                revocations,
                preemptions,
                wait_secs,
                fl_secs,
            } => {
                j.insert("job", job.as_str());
                j.insert("tenant", tenant.as_str());
                j.insert("cost", *cost);
                j.insert("rounds", *rounds as i64);
                j.insert("revocations", *revocations as i64);
                j.insert("preemptions", *preemptions as i64);
                j.insert("wait_secs", *wait_secs);
                j.insert("fl_secs", *fl_secs);
            }
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_renderings_match_the_historical_lines() {
        let at = SimTime::from_secs(3723.0);
        assert_eq!(
            EventKind::InitialMapping {
                server: "vm126".into(),
                clients: vec!["vm126".into(), "vm138".into()],
                predicted_makespan: 123.456,
                predicted_cost: 1.23456,
                decision: None,
            }
            .render(at),
            "initial mapping: server=vm126 clients=[\"vm126\", \"vm138\"] \
             (predicted round 123.5s, $1.2346)"
        );
        assert_eq!(
            EventKind::Deferral { defer_secs: 10_800.0, decision: None }.render(at),
            "outlook: provisioning deferred 10800s past the price spike"
        );
        assert_eq!(EventKind::FlStart.render(at), "all VMs prepared; FL execution starts");
        assert_eq!(
            EventKind::BatchedRevocation { count: 3 }.render(at),
            "batched event: 3 co-timed revocations"
        );
        assert_eq!(
            EventKind::Revocation {
                task: "client-2".into(),
                vm: "vm121".into(),
                round: 7,
                provider: "Cloud A".into(),
                region: "Utah".into(),
            }
            .render(at),
            "revocation: client-2 on vm121 during round 7"
        );
        assert_eq!(
            EventKind::Replacement {
                task: "server".into(),
                vm: "vm138".into(),
                value: 0.123456,
                boot_done: SimTime::from_secs(3900.0),
                decision: None,
            }
            .render(at),
            format!(
                "dynamic scheduler: server → vm138 (value 0.12346); booting until {}",
                SimTime::from_secs(3900.0).hms()
            )
        );
        assert_eq!(
            EventKind::CheckpointRestore { restore_round: 5, lost: 2 }.render(at),
            "server restore from round 5 (lost 2 rounds)"
        );
        assert_eq!(
            EventKind::Preemption { round: 4, lost: 1, decision: None }.render(at),
            format!("preempted at {} (checkpointed progress: round 4, 1 lost)", at.hms())
        );
        assert_eq!(
            EventKind::Teardown { preempted: false }.render(at),
            "all rounds complete; VMs terminated"
        );
        assert_eq!(
            EventKind::Teardown { preempted: true }.render(at),
            "preemption teardown; VMs terminated"
        );
    }

    #[test]
    fn telemetry_only_split_matches_the_executor_gating() {
        assert!(!EventKind::FlStart.telemetry_only());
        assert!(!EventKind::Teardown { preempted: false }.telemetry_only());
        assert!(EventKind::RoundStart { round: 1, predicted_secs: 1.0 }.telemetry_only());
        assert!(EventKind::CheckpointSave { round: 1 }.telemetry_only());
        assert!(EventKind::PriceStep { factor: 1.5 }.telemetry_only());
    }

    /// One literal per variant. The inner match is the compile-time guard:
    /// adding an `EventKind` variant breaks it until the sample list (and
    /// therefore every sink assertion below) is extended.
    fn exhaustive_samples() -> Vec<EventKind> {
        fn _covered(k: &EventKind) {
            match k {
                EventKind::InitialMapping { .. }
                | EventKind::Deferral { .. }
                | EventKind::FlStart
                | EventKind::Provision { .. }
                | EventKind::RoundStart { .. }
                | EventKind::RoundEnd { .. }
                | EventKind::CheckpointSave { .. }
                | EventKind::BatchedRevocation { .. }
                | EventKind::Revocation { .. }
                | EventKind::Replacement { .. }
                | EventKind::CheckpointRestore { .. }
                | EventKind::Preemption { .. }
                | EventKind::Teardown { .. }
                | EventKind::Arrival { .. }
                | EventKind::Admission { .. }
                | EventKind::QuotaWait { .. }
                | EventKind::PriceStep { .. }
                | EventKind::AdmissionRetry { .. }
                | EventKind::Rejection { .. }
                | EventKind::JobComplete { .. } => {}
            }
        }
        vec![
            EventKind::InitialMapping {
                server: "vm126".into(),
                clients: vec!["vm138".into()],
                predicted_makespan: 120.0,
                predicted_cost: 1.5,
                decision: Some(0),
            },
            EventKind::Deferral { defer_secs: 3600.0, decision: Some(1) },
            EventKind::FlStart,
            EventKind::Provision {
                task: "server".into(),
                vm: "vm126".into(),
                provider: "Cloud A".into(),
                region: "Utah".into(),
                spot: true,
                boot_done: SimTime::from_secs(300.0),
                decision: Some(0),
            },
            EventKind::RoundStart { round: 1, predicted_secs: 120.0 },
            EventKind::RoundEnd { round: 1, egress_gb: 0.5 },
            EventKind::CheckpointSave { round: 1 },
            EventKind::BatchedRevocation { count: 2 },
            EventKind::Revocation {
                task: "client-1".into(),
                vm: "vm121".into(),
                round: 2,
                provider: "Cloud B".into(),
                region: "SP".into(),
            },
            EventKind::Replacement {
                task: "client-1".into(),
                vm: "vm138".into(),
                value: 0.5,
                boot_done: SimTime::from_secs(900.0),
                decision: Some(2),
            },
            EventKind::CheckpointRestore { restore_round: 1, lost: 1 },
            EventKind::Preemption { round: 3, lost: 1, decision: Some(3) },
            EventKind::Teardown { preempted: false },
            EventKind::Arrival { job: "low-0".into(), tenant: "zeta".into() },
            EventKind::Admission { job: "low-0".into(), wait_secs: 0.0, decision: Some(4) },
            EventKind::QuotaWait { job: "low-1".into() },
            EventKind::PriceStep { factor: 1.4 },
            EventKind::AdmissionRetry { job: "low-1".into(), decision: Some(5) },
            EventKind::Rejection {
                job: "low-2".into(),
                reason: "infeasible".into(),
                decision: Some(6),
            },
            EventKind::JobComplete {
                job: "low-0".into(),
                tenant: "zeta".into(),
                cost: 2.0,
                rounds: 6,
                revocations: 1,
                preemptions: 1,
                wait_secs: 10.0,
                fl_secs: 500.0,
            },
        ]
    }

    #[test]
    fn every_variant_renders_and_round_trips_jsonl() {
        use crate::coordinator::sim::SimEvent;
        let at = SimTime::from_secs(100.0);
        let samples = exhaustive_samples();
        let mut keys = std::collections::BTreeSet::new();
        for kind in samples {
            let what = SimEvent { at, kind: kind.clone() }.what();
            assert!(!what.is_empty(), "{:?} must render", kind.key());
            let s = kind.to_json().to_string_compact();
            let parsed = Json::parse(&s).expect("sink line is valid JSON");
            assert_eq!(
                parsed.get("kind").and_then(|v| v.as_str()),
                Some(kind.key()),
                "kind tag survives the round trip"
            );
            assert_eq!(parsed.to_string_compact(), s, "round-trip is lossless: {s}");
            assert_eq!(
                parsed.get("decision").and_then(|v| v.as_f64()).map(|f| f as u64),
                kind.decision_id(),
                "decision provenance survives the round trip: {s}"
            );
            keys.insert(kind.key());
        }
        assert_eq!(keys.len(), 20, "every variant has a distinct key");
    }

    #[test]
    fn decision_ids_shift_and_stay_absent_on_causeless_kinds() {
        let mut ev = EventKind::Admission { job: "j".into(), wait_secs: 0.0, decision: Some(3) };
        ev.shift_decision_id(100);
        assert_eq!(ev.decision_id(), Some(103));
        let mut none = EventKind::Revocation {
            task: "t".into(),
            vm: "v".into(),
            round: 1,
            provider: "p".into(),
            region: "r".into(),
        };
        none.shift_decision_id(100);
        assert_eq!(none.decision_id(), None);
        let off = EventKind::Admission { job: "j".into(), wait_secs: 0.0, decision: None };
        assert!(!off.to_json().to_string_compact().contains("decision"));
    }

    #[test]
    fn json_carries_the_kind_tag_and_structured_fields() {
        let j = EventKind::Revocation {
            task: "server".into(),
            vm: "vm126".into(),
            round: 3,
            provider: "Cloud A".into(),
            region: "Utah".into(),
        }
        .to_json();
        let s = j.to_string_compact();
        assert!(s.contains("\"kind\":\"revocation\""), "{s}");
        assert!(s.contains("\"provider\":\"Cloud A\""), "{s}");
        assert!(s.contains("\"round\":3"), "{s}");
    }
}
