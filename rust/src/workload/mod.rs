//! First-class multi-job workloads (§6 future work, executed over time).
//!
//! A [`Workload`] is a set of FL jobs with arrival times and an admission
//! policy, executed on one shared multi-cloud by a discrete-event engine:
//! every placement decision — initial mappings at admission *and* the
//! Dynamic Scheduler's replacement choices after spot revocations — competes
//! for the same residual provider/region GPU and vCPU quotas, tracked by a
//! time-indexed [`QuotaLedger`].
//!
//! Engine semantics (all deterministic):
//!
//! * Jobs are admitted greedily in policy order ([`AdmissionPolicy`]): a job
//!   whose mapping is infeasible under the residual quota stays queued and
//!   re-solves whenever capacity is released (a job completes, or a spot
//!   revocation inside a running job returns a VM to the pool); jobs behind
//!   it may backfill.
//! * A job infeasible even on an *idle* environment (its `budget_round` /
//!   `deadline_round` / the quotas exclude every placement) is rejected at
//!   arrival — unless its market's price can still change, in which case it
//!   stays queued and admission is retried at each future price step; only
//!   a job priced out at every remaining price level is rejected.
//! * An admitted job runs through the standard [`crate::framework`] pipeline
//!   with its Initial Mapping pinned to the admission-time solution and its
//!   Dynamic Scheduler wrapped so replacement candidates are filtered by the
//!   residual shared quota at the revocation instant.
//! * All jobs share one market timeline: each admitted job's spot-market
//!   model is re-anchored on the cluster clock
//!   ([`crate::market::MarketSpec::shifted`]), so a recorded interruption
//!   or price step hits every job by its cluster instant, not per-job
//!   local replays.
//! * Admission-order causality: a job's execution is a pure function of the
//!   jobs admitted before it, so the whole workload is reproducible from its
//!   seeds regardless of host parallelism.
//!
//! Quota-safety invariant: every reservation interval is feasibility-checked
//! against all previously committed intervals at every instant it covers, so
//! by induction over commit order no provider/region bound is ever exceeded
//! at any simulated instant (enforced end-to-end by
//! `tests/workload_parity.rs`).
//!
//! [`Workload::single`] is the degenerate one-job case and reproduces
//! [`crate::coordinator::simulate`] bit-for-bit; [`spec`] parses the
//! `multi-fedls workload --spec` TOML (arrival processes, per-job overrides,
//! campaign grids over admission/arrival/budget/deadline axes).

pub mod spec;

pub use spec::{ArrivalProcess, WorkloadPoint, WorkloadSpec};

use std::sync::{Arc, Mutex};

use crate::cloud::quota::QuotaTracker;
use crate::cloud::{Catalog, VmTypeId};
use crate::cloudsim::{MultiCloud, RevocationModel};
use crate::coordinator::multijob::AdmissionPolicy;
use crate::coordinator::sim::{environment_for, SimConfig};
use crate::dynsched::{self, CurrentMap, DynSchedPolicy, FaultyTask, Selection};
use crate::framework::{
    modules, CachedPreSched, DynScheduler, EnvCache, FixedMapper, Framework, PaperDynSched,
};
use crate::mapping::problem::MappingProblem;
use crate::mapping::MappingSolution;
use crate::simul::SimTime;
use crate::sweep::MetricAgg;

/// Expected spot-price multiplier for one job's mapping problem at cluster
/// instant `at_secs`: the market re-anchored on the shared cluster clock
/// (see [`crate::market::MarketSpec::shifted`]), averaged over the same
/// planning horizon `framework::exec` uses
/// ([`SimConfig::planning_horizon_secs`]). Exactly 1.0 for the default
/// market.
fn planning_price_factor_at(cfg: &SimConfig, at_secs: f64) -> f64 {
    cfg.market.shifted(at_secs).planning_price_factor(cfg.planning_horizon_secs())
}

/// The record of a job that was never admitted (its budget/deadline/quota
/// excluded every placement at every reachable price level).
fn rejected_record(jr: &JobRequest) -> JobRecord {
    JobRecord {
        name: jr.name.clone(),
        arrival_secs: jr.arrival_secs,
        admitted_at: None,
        completed_at: None,
        wait_secs: 0.0,
        cost: 0.0,
        revocations: 0,
        rounds_completed: 0,
        fl_exec_secs: 0.0,
        predicted_round_makespan: 0.0,
        predicted_round_cost: 0.0,
        server: String::new(),
        clients: Vec::new(),
    }
}

/// One job in a workload: a complete simulator configuration plus its
/// arrival instant on the shared cluster clock.
#[derive(Debug, Clone)]
pub struct JobRequest {
    pub name: String,
    pub arrival_secs: f64,
    pub cfg: SimConfig,
}

/// A set of jobs sharing one multi-cloud, with an admission policy.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub jobs: Vec<JobRequest>,
    pub admission: AdmissionPolicy,
}

/// One committed reservation: `job` holds one VM of type `vm` over
/// `[start, end)` on the cluster clock (`end = INFINITY` while running).
#[derive(Debug, Clone)]
pub struct Reservation {
    pub job: usize,
    pub vm: VmTypeId,
    pub start: f64,
    pub end: f64,
}

/// Time-indexed shared-quota accounting for one workload execution.
///
/// Usage over time is a sum of interval indicators, so it only increases at
/// reservation starts; checking feasibility of an addition over `[start, ∞)`
/// therefore reduces to checking `start` itself plus every later
/// reservation start.
#[derive(Debug)]
pub struct QuotaLedger {
    catalog: Catalog,
    reservations: Vec<Reservation>,
}

impl QuotaLedger {
    fn new(catalog: Catalog) -> QuotaLedger {
        QuotaLedger { catalog, reservations: Vec::new() }
    }

    fn instants_from(&self, start: f64) -> Vec<f64> {
        let mut instants = vec![start];
        for r in &self.reservations {
            if r.start > start && r.end > r.start {
                instants.push(r.start);
            }
        }
        instants
    }

    /// Would additionally holding one VM of each type in `add` over
    /// `[start, ∞)` keep every provider/region bound satisfied at every
    /// instant?
    fn fits(&self, add: &[VmTypeId], start: f64) -> bool {
        for t in self.instants_from(start) {
            let mut q = QuotaTracker::new();
            for r in &self.reservations {
                if r.start <= t && t < r.end && q.allocate(&self.catalog, r.vm).is_err() {
                    return false; // committed state over quota: impossible
                }
            }
            for &vm in add {
                if q.allocate(&self.catalog, vm).is_err() {
                    return false;
                }
            }
        }
        true
    }

    /// Peak (GPUs, vCPUs) usage over `[start, ∞)`, per provider and per
    /// region — used to shrink the mapping solver's catalog to residual
    /// capacity (conservative per dimension, hence always quota-safe).
    fn peak_usage(&self, start: f64) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
        let mut prov = vec![(0u32, 0u32); self.catalog.providers.len()];
        let mut reg = vec![(0u32, 0u32); self.catalog.regions.len()];
        for t in self.instants_from(start) {
            let mut p_now = vec![(0u32, 0u32); prov.len()];
            let mut r_now = vec![(0u32, 0u32); reg.len()];
            for r in &self.reservations {
                if r.start <= t && t < r.end {
                    let spec = self.catalog.vm(r.vm);
                    let pi = self.catalog.provider_of(r.vm).0;
                    let ri = self.catalog.region_of(r.vm).0;
                    p_now[pi].0 += spec.gpus;
                    p_now[pi].1 += spec.vcpus;
                    r_now[ri].0 += spec.gpus;
                    r_now[ri].1 += spec.vcpus;
                }
            }
            for i in 0..prov.len() {
                prov[i].0 = prov[i].0.max(p_now[i].0);
                prov[i].1 = prov[i].1.max(p_now[i].1);
            }
            for i in 0..reg.len() {
                reg[i].0 = reg[i].0.max(r_now[i].0);
                reg[i].1 = reg[i].1.max(r_now[i].1);
            }
        }
        (prov, reg)
    }

    /// Any reservation still live at or after `start`?
    fn any_live_after(&self, start: f64) -> bool {
        self.reservations.iter().any(|r| r.end > start)
    }

    fn commit(&mut self, job: usize, vm: VmTypeId, start: f64) {
        self.reservations.push(Reservation { job, vm, start, end: f64::INFINITY });
    }

    /// Close one open reservation of `(job, vm)` at `at` — a spot revocation
    /// returning that VM's capacity to the shared pool.
    fn release_one(&mut self, job: usize, vm: VmTypeId, at: f64) {
        if let Some(r) = self
            .reservations
            .iter_mut()
            .find(|r| r.job == job && r.vm == vm && r.end.is_infinite())
        {
            r.end = at;
        }
    }

    /// Close every remaining open reservation of `job` at `at` (teardown).
    fn end_job(&mut self, job: usize, at: f64) {
        for r in self.reservations.iter_mut() {
            if r.job == job && r.end.is_infinite() {
                r.end = at;
            }
        }
    }
}

/// Wraps a job's Dynamic Scheduler so replacement choices compete for the
/// workload's residual shared quota: the revoked VM's capacity returns to
/// the pool at the revocation instant, candidates that do not fit the
/// residual quota (given every other job's committed reservations) are
/// filtered out before the inner scheduler ranks them, and the chosen
/// replacement is committed back to the ledger. Types skipped only because
/// of a transient quota shortage stay in the task's candidate set.
struct QuotaAwareDynSched {
    inner: Arc<dyn DynScheduler>,
    ledger: Arc<Mutex<QuotaLedger>>,
    job: usize,
    /// Cluster-clock offset of this job's simulation (its admission time).
    offset: f64,
}

impl DynScheduler for QuotaAwareDynSched {
    fn name(&self) -> &'static str {
        "quota-aware"
    }

    fn select(
        &self,
        p: &MappingProblem,
        map: &CurrentMap,
        faulty: FaultyTask,
        candidate_set: &[VmTypeId],
        revoked: VmTypeId,
        policy: DynSchedPolicy,
        at: SimTime,
    ) -> (Option<Selection>, Vec<VmTypeId>) {
        let t = self.offset + at.secs();
        let mut ledger = self.ledger.lock().expect("quota ledger poisoned");
        ledger.release_one(self.job, revoked, t);
        let filtered: Vec<VmTypeId> =
            candidate_set.iter().copied().filter(|&v| ledger.fits(&[v], t)).collect();
        let quota_blocked: Vec<VmTypeId> =
            candidate_set.iter().copied().filter(|v| !filtered.contains(v)).collect();
        let (selection, inner_set) =
            self.inner.select(p, map, faulty, &filtered, revoked, policy, at);
        // Candidate set handed back on success: keep quota-blocked types as
        // candidates for later events (their shortage is transient), but
        // drop whatever the inner scheduler itself removed — so a
        // remove-revoked ban is never silently undone.
        let final_set: Vec<VmTypeId> = candidate_set
            .iter()
            .copied()
            .filter(|v| inner_set.contains(v) || quota_blocked.contains(v))
            .collect();
        match selection {
            Some(sel) => {
                ledger.commit(self.job, sel.vm, t);
                (Some(sel), final_set)
            }
            None if !quota_blocked.is_empty() => {
                // Exhaustion attributable to the quota filter (candidates
                // existed but none fit the residual shared quota): restart
                // on the type whose capacity was just freed — it always
                // fits, and the shortage is transient, so aborting the
                // whole workload would be wrong.
                let expected_makespan = dynsched::recompute_makespan(p, map, faulty, revoked);
                let expected_cost =
                    dynsched::recompute_cost(p, map, faulty, revoked, expected_makespan);
                ledger.commit(self.job, revoked, t);
                let sel = Selection {
                    vm: revoked,
                    expected_makespan,
                    expected_cost,
                    value: p.objective_value(expected_cost, expected_makespan),
                    candidates_considered: 0,
                };
                (Some(sel), final_set)
            }
            None => {
                // Genuine exhaustion — the inner scheduler saw the full
                // candidate set and found nothing. Propagate, so the job
                // fails exactly like `coordinator::simulate` would.
                (None, inner_set)
            }
        }
    }
}

/// Per-job outcome of one workload execution.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub name: String,
    pub arrival_secs: f64,
    /// `None` = rejected (infeasible even on an idle environment).
    pub admitted_at: Option<f64>,
    pub completed_at: Option<f64>,
    pub wait_secs: f64,
    pub cost: f64,
    pub revocations: u32,
    pub rounds_completed: u32,
    pub fl_exec_secs: f64,
    pub predicted_round_makespan: f64,
    pub predicted_round_cost: f64,
    pub server: String,
    pub clients: Vec<String>,
}

/// Workload-level summary metrics of one execution.
#[derive(Debug, Clone)]
pub struct WorkloadStats {
    /// Cluster-clock span from the earliest arrival to the last completion.
    pub makespan_secs: f64,
    /// Mean admission wait over admitted jobs.
    pub mean_wait_secs: f64,
    pub admitted: usize,
    /// Admitted jobs that could not start at their arrival instant.
    pub queued: usize,
    /// Jobs whose budget/deadline/quota excluded every placement outright.
    pub rejected: usize,
    pub total_cost: f64,
}

impl WorkloadStats {
    pub fn from_records(records: &[JobRecord]) -> WorkloadStats {
        let mut first_arrival = f64::INFINITY;
        let mut last_completion: f64 = 0.0;
        let mut wait_sum = 0.0;
        let mut admitted = 0usize;
        let mut queued = 0usize;
        let mut rejected = 0usize;
        let mut total_cost = 0.0;
        for r in records {
            match r.admitted_at {
                Some(_) => {
                    admitted += 1;
                    if r.wait_secs > 1e-9 {
                        queued += 1;
                    }
                    wait_sum += r.wait_secs;
                    first_arrival = first_arrival.min(r.arrival_secs);
                    last_completion = last_completion.max(r.completed_at.unwrap_or(0.0));
                    total_cost += r.cost;
                }
                None => rejected += 1,
            }
        }
        WorkloadStats {
            makespan_secs: if admitted > 0 { last_completion - first_arrival } else { 0.0 },
            mean_wait_secs: if admitted > 0 { wait_sum / admitted as f64 } else { 0.0 },
            admitted,
            queued,
            rejected,
            total_cost,
        }
    }
}

/// Everything one workload execution produced.
#[derive(Debug, Clone)]
pub struct WorkloadOutcome {
    pub jobs: Vec<JobRecord>,
    /// The complete shared-quota reservation timeline (for audits: sweeping
    /// it proves no bound was exceeded at any simulated instant).
    pub reservations: Vec<Reservation>,
    pub stats: WorkloadStats,
}

impl Workload {
    /// The degenerate one-job workload: `cfg` verbatim (seed included),
    /// arriving at t = 0 under FIFO admission. Reproduces
    /// [`crate::coordinator::simulate`] bit-for-bit
    /// (`tests/workload_parity.rs`).
    pub fn single(cfg: SimConfig) -> Workload {
        let name = cfg.app.name.to_string();
        Workload {
            name: name.clone(),
            jobs: vec![JobRequest { name, arrival_secs: 0.0, cfg }],
            admission: AdmissionPolicy::Fifo,
        }
    }

    /// Execute the workload with a private environment cache.
    pub fn run(&self) -> anyhow::Result<WorkloadOutcome> {
        self.run_with_cache(&Arc::new(EnvCache::new()))
    }

    /// Execute the workload; Pre-Scheduling reports come from (and feed)
    /// the shared `cache`, so campaigns measure each environment once.
    pub fn run_with_cache(&self, cache: &Arc<EnvCache>) -> anyhow::Result<WorkloadOutcome> {
        anyhow::ensure!(!self.jobs.is_empty(), "workload has no jobs");
        let (catalog, ground_truth) = environment_for(&self.jobs[0].cfg.app);
        for j in &self.jobs {
            let (c, _) = environment_for(&j.cfg.app);
            anyhow::ensure!(
                c.name == catalog.name,
                "all jobs in a workload must share one environment ({} vs {})",
                c.name,
                catalog.name
            );
            anyhow::ensure!(
                j.arrival_secs.is_finite() && j.arrival_secs >= 0.0,
                "job {} has invalid arrival time {}",
                j.name,
                j.arrival_secs
            );
        }
        let mc = MultiCloud::new(catalog.clone(), ground_truth, RevocationModel::none(), 1);
        let slowdowns = cache.get_or_measure(&mc);
        let ledger = Arc::new(Mutex::new(QuotaLedger::new(catalog.clone())));

        let n = self.jobs.len();
        let mut records: Vec<Option<JobRecord>> = vec![None; n];
        let mut solo: Vec<Option<MappingSolution>> = vec![None; n];
        let mut pending: Vec<usize> = Vec::new();
        // (time, Some(job) = arrival | None = capacity-release trigger).
        let mut events: Vec<(f64, Option<usize>)> =
            self.jobs.iter().enumerate().map(|(i, j)| (j.arrival_secs, Some(i))).collect();

        while !events.is_empty() {
            let t = events.iter().map(|e| e.0).fold(f64::INFINITY, f64::min);
            // Drain every event at exactly `t`, then run one admission pass.
            let mut arrivals: Vec<usize> = Vec::new();
            let mut k = 0;
            while k < events.len() {
                if events[k].0 == t {
                    if let (_, Some(job)) = events.swap_remove(k) {
                        arrivals.push(job);
                    }
                } else {
                    k += 1;
                }
            }
            arrivals.sort_unstable();
            for j in arrivals {
                let jr = &self.jobs[j];
                let profile = jr.cfg.app.profile();
                let p = MappingProblem {
                    catalog: &catalog,
                    slowdowns: slowdowns.as_ref(),
                    job: &profile,
                    alpha: jr.cfg.alpha,
                    market: jr.cfg.scenario.client_market(),
                    spot_price_factor: planning_price_factor_at(&jr.cfg, t),
                    budget_round: jr.cfg.budget_round,
                    deadline_round: jr.cfg.deadline_round,
                };
                match modules::mapper_for(jr.cfg.mapper).map(&p) {
                    Some(sol) => {
                        solo[j] = Some(sol);
                        pending.push(j);
                    }
                    None if jr.cfg.budget_round.is_finite()
                        && jr.cfg.market.next_price_step_after(t).is_some() =>
                    {
                        // Infeasible at the *current* price level, but the
                        // price can still change and the job is budget-
                        // capped (prices enter feasibility only through the
                        // budget): queue without a solo solution and let
                        // the price-step retries re-solve at each level.
                        pending.push(j);
                    }
                    None => {
                        // Infeasible even on an idle environment, at a
                        // price level that will never change: reject.
                        records[j] = Some(rejected_record(jr));
                    }
                }
            }

            // Admission pass in policy order; later jobs may backfill past a
            // blocked one (greedy, like the static multijob planner).
            let mut order = pending.clone();
            match self.admission {
                AdmissionPolicy::Fifo => order.sort_by(|&a, &b| {
                    self.jobs[a]
                        .arrival_secs
                        .total_cmp(&self.jobs[b].arrival_secs)
                        .then(a.cmp(&b))
                }),
                AdmissionPolicy::ShortestMakespanFirst => order.sort_by(|&a, &b| {
                    // Jobs queued without a solo solution (priced out at
                    // arrival) sort last until a price change admits them.
                    let m = |j: usize| {
                        solo[j].as_ref().map_or(f64::INFINITY, |s| s.eval.makespan)
                    };
                    m(a).total_cmp(&m(b)).then(a.cmp(&b))
                }),
            }
            let mut admitted_now: Vec<usize> = Vec::new();
            for j in order {
                if let Some((completion, releases)) = self.try_admit(
                    j,
                    t,
                    &catalog,
                    slowdowns.as_ref(),
                    &solo,
                    &ledger,
                    cache,
                    &mut records,
                )? {
                    admitted_now.push(j);
                    for rt in releases {
                        if rt > t {
                            events.push((rt, None));
                        }
                    }
                    events.push((completion, None));
                }
            }
            pending.retain(|j| !admitted_now.contains(j));

            // A queued job's admission feasibility can change without a
            // capacity release when its market's price moves, so always
            // keep a retry event at the earliest future price step across
            // pending jobs — a feasible price window between two release
            // events must not be missed. When no events remain at all and
            // every pending market is settled, the leftovers are priced
            // out for good: reject them (their budget excludes every
            // placement at every remaining price level).
            if !pending.is_empty() {
                let next_step = pending
                    .iter()
                    .filter_map(|&j| self.jobs[j].cfg.market.next_price_step_after(t))
                    .fold(f64::INFINITY, f64::min);
                if next_step.is_finite() {
                    if !events.iter().any(|e| e.0 == next_step) {
                        events.push((next_step, None));
                    }
                } else if events.is_empty() {
                    for &j in &pending {
                        records[j] = Some(rejected_record(&self.jobs[j]));
                    }
                    pending.clear();
                }
            }
        }
        anyhow::ensure!(
            pending.is_empty(),
            "workload engine stalled with {} queued jobs",
            pending.len()
        );

        let jobs: Vec<JobRecord> =
            records.into_iter().map(|r| r.expect("every job recorded")).collect();
        let reservations = ledger.lock().expect("quota ledger poisoned").reservations.clone();
        let stats = WorkloadStats::from_records(&jobs);
        Ok(WorkloadOutcome { jobs, reservations, stats })
    }

    /// Try to admit job `j` at instant `t` against the residual quota.
    /// Returns `Some((completion_time, capacity_release_times))` on success.
    #[allow(clippy::too_many_arguments)]
    fn try_admit(
        &self,
        j: usize,
        t: f64,
        catalog: &Catalog,
        slowdowns: &crate::presched::SlowdownReport,
        solo: &[Option<MappingSolution>],
        ledger: &Arc<Mutex<QuotaLedger>>,
        cache: &Arc<EnvCache>,
        records: &mut [Option<JobRecord>],
    ) -> anyhow::Result<Option<(f64, Vec<f64>)>> {
        let jr = &self.jobs[j];
        let contended = ledger.lock().expect("quota ledger poisoned").any_live_after(t);
        // The cached arrival-time solution is exact on an idle environment
        // as long as nothing repriced since arrival: always at the arrival
        // instant itself (the `Workload::single` bit-parity path), and at
        // any instant under a constant-price market (the planning factor is
        // identically 1.0, so re-solving would reproduce it verbatim).
        let reuse_solo = !contended
            && (t == jr.arrival_secs
                || matches!(jr.cfg.market.price, crate::market::PriceSpec::Constant));
        let sol: Option<MappingSolution> = if reuse_solo {
            solo[j].clone()
        } else {
            // Re-solve at the admission instant: against the residual
            // capacity when contended (shrink every quota bound by the
            // ledger's peak usage from `t` on — the reduced catalog keeps
            // providers/regions/VM types in identical order, so the
            // slowdown report's index keys carry over unchanged, same
            // invariant as `coordinator::multijob`), and in any case at
            // the spot price in effect *now*, not at arrival — a queued
            // job must not be admitted against a stale price level.
            let mut reduced = catalog.clone();
            if contended {
                let (pprov, preg) =
                    ledger.lock().expect("quota ledger poisoned").peak_usage(t);
                for (pi, prov) in reduced.providers.iter_mut().enumerate() {
                    if let Some(maxg) = prov.max_gpus {
                        prov.max_gpus = Some(maxg.saturating_sub(pprov[pi].0));
                    }
                    if let Some(maxc) = prov.max_vcpus {
                        prov.max_vcpus = Some(maxc.saturating_sub(pprov[pi].1));
                    }
                }
                for (ri, region) in reduced.regions.iter_mut().enumerate() {
                    if let Some(maxg) = region.max_gpus {
                        region.max_gpus = Some(maxg.saturating_sub(preg[ri].0));
                    }
                    if let Some(maxc) = region.max_vcpus {
                        region.max_vcpus = Some(maxc.saturating_sub(preg[ri].1));
                    }
                }
            }
            let profile = jr.cfg.app.profile();
            let p = MappingProblem {
                catalog: &reduced,
                slowdowns,
                job: &profile,
                alpha: jr.cfg.alpha,
                market: jr.cfg.scenario.client_market(),
                spot_price_factor: planning_price_factor_at(&jr.cfg, t),
                budget_round: jr.cfg.budget_round,
                deadline_round: jr.cfg.deadline_round,
            };
            modules::mapper_for(jr.cfg.mapper).map(&p)
        };
        let Some(sol) = sol else { return Ok(None) };
        let mut vms = sol.mapping.clients.clone();
        vms.push(sol.mapping.server);
        {
            let mut lg = ledger.lock().expect("quota ledger poisoned");
            if !lg.fits(&vms, t) {
                return Ok(None);
            }
            for &vm in &vms {
                lg.commit(j, vm, t);
            }
        }
        let fw = Framework::builder()
            .pre_sched(CachedPreSched::new(cache.clone()))
            .mapper(FixedMapper::new(sol.clone()))
            .dynsched(QuotaAwareDynSched {
                inner: Arc::new(PaperDynSched),
                ledger: ledger.clone(),
                job: j,
                offset: t,
            })
            .build();
        // The job simulates on its own local clock (t = 0 at admission);
        // re-anchor the market so recorded interruptions, price steps, and
        // the seasonal phase stay on the shared cluster timeline. A no-op
        // for the default market and for t = 0 (the `Workload::single`
        // bit-parity path).
        let mut run_cfg = jr.cfg.clone();
        run_cfg.market = jr.cfg.market.shifted(t);
        let out = fw.run(&run_cfg)?;
        let completion = t + out.total_secs;
        let mut releases: Vec<f64> = Vec::new();
        {
            let mut lg = ledger.lock().expect("quota ledger poisoned");
            lg.end_job(j, completion);
            for r in lg.reservations.iter() {
                if r.job == j && r.end < completion {
                    releases.push(r.end);
                }
            }
        }
        records[j] = Some(JobRecord {
            name: jr.name.clone(),
            arrival_secs: jr.arrival_secs,
            admitted_at: Some(t),
            completed_at: Some(completion),
            wait_secs: t - jr.arrival_secs,
            cost: out.total_cost,
            revocations: out.n_revocations,
            rounds_completed: out.rounds_completed,
            fl_exec_secs: out.fl_exec_secs,
            predicted_round_makespan: out.predicted_round_makespan,
            predicted_round_cost: out.predicted_round_cost,
            server: out.initial_server.clone(),
            clients: out.initial_clients.clone(),
        });
        Ok(Some((completion, releases)))
    }
}

/// Run independent workload realizations (campaign trials) across a worker
/// pool, returning outcomes in input order — bit-identical for any worker
/// count (the pool is [`crate::sweep::run_indexed`]).
pub fn run_trials(
    trials: &[Workload],
    jobs: usize,
    cache: &Arc<EnvCache>,
) -> anyhow::Result<Vec<WorkloadOutcome>> {
    crate::sweep::run_indexed(trials.len(), jobs, |i| trials[i].run_with_cache(cache))
}

/// Aggregates of one workload configuration over repeated trials.
#[derive(Debug, Clone)]
pub struct WorkloadAgg {
    pub trials: usize,
    pub makespan: MetricAgg,
    pub mean_wait: MetricAgg,
    pub total_cost: MetricAgg,
    pub admitted: MetricAgg,
    pub queued: MetricAgg,
    pub rejected: MetricAgg,
    pub jobs: Vec<JobAgg>,
}

/// Per-job aggregates over a point's trials (completion uses 0 for trials
/// where the job was rejected).
#[derive(Debug, Clone)]
pub struct JobAgg {
    pub name: String,
    pub wait: MetricAgg,
    pub completion: MetricAgg,
    pub cost: MetricAgg,
    pub revocations: MetricAgg,
}

impl WorkloadAgg {
    pub fn from_outcomes(outs: &[WorkloadOutcome]) -> WorkloadAgg {
        assert!(!outs.is_empty(), "WorkloadAgg over zero trials");
        let col = |f: &dyn Fn(&WorkloadOutcome) -> f64| -> MetricAgg {
            MetricAgg::from_samples(&outs.iter().map(f).collect::<Vec<_>>())
        };
        let n_jobs = outs[0].jobs.len();
        let mut jobs = Vec::with_capacity(n_jobs);
        for ji in 0..n_jobs {
            let jcol = |f: &dyn Fn(&JobRecord) -> f64| -> MetricAgg {
                MetricAgg::from_samples(&outs.iter().map(|o| f(&o.jobs[ji])).collect::<Vec<_>>())
            };
            jobs.push(JobAgg {
                name: outs[0].jobs[ji].name.clone(),
                wait: jcol(&|r| r.wait_secs),
                completion: jcol(&|r| r.completed_at.unwrap_or(0.0)),
                cost: jcol(&|r| r.cost),
                revocations: jcol(&|r| r.revocations as f64),
            });
        }
        WorkloadAgg {
            trials: outs.len(),
            makespan: col(&|o| o.stats.makespan_secs),
            mean_wait: col(&|o| o.stats.mean_wait_secs),
            total_cost: col(&|o| o.stats.total_cost),
            admitted: col(&|o| o.stats.admitted as f64),
            queued: col(&|o| o.stats.queued as f64),
            rejected: col(&|o| o.stats.rejected as f64),
            jobs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::Scenario;

    fn aws_job(seed: u64) -> SimConfig {
        let mut cfg = SimConfig::new(apps::til_aws_gcp(), Scenario::AllOnDemand, seed);
        cfg.checkpoints_enabled = false;
        cfg
    }

    fn batch(cfgs: Vec<SimConfig>) -> Workload {
        Workload {
            name: "test".into(),
            jobs: cfgs
                .into_iter()
                .enumerate()
                .map(|(i, cfg)| JobRequest {
                    name: format!("job-{i}"),
                    arrival_secs: 0.0,
                    cfg,
                })
                .collect(),
            admission: AdmissionPolicy::Fifo,
        }
    }

    #[test]
    fn single_job_workload_completes() {
        let out = Workload::single(aws_job(4)).run().unwrap();
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.queued, 0);
        assert_eq!(out.stats.rejected, 0);
        let j = &out.jobs[0];
        assert_eq!(j.admitted_at, Some(0.0));
        assert!(j.completed_at.unwrap() > 0.0);
        assert_eq!(j.server, "vm313");
        // Reservations: one per task, all spanning the whole execution.
        assert_eq!(out.reservations.len(), 3);
        for r in &out.reservations {
            assert_eq!(r.start, 0.0);
            assert!((r.end - j.completed_at.unwrap()).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_of_three_shares_quota() {
        // Three 2-client TIL jobs on AWS+GCP (4+4 GPUs): all admitted, but
        // never more GPUs in flight than the quota allows.
        let out = batch(vec![aws_job(1), aws_job(2), aws_job(3)]).run().unwrap();
        assert_eq!(out.stats.admitted, 3);
        assert_eq!(out.stats.rejected, 0);
        for j in &out.jobs {
            assert_eq!(j.rounds_completed, 10);
        }
    }

    #[test]
    fn saturated_quota_queues_and_drains() {
        // Six jobs contend for the AWS+GCP quotas at t = 0. Whether they all
        // fit (CPU fallbacks) or some queue, every one must eventually run —
        // and any queued job must start only after an earlier release.
        let out = batch((0..6).map(aws_job).collect()).run().unwrap();
        assert_eq!(out.stats.admitted, 6, "every job eventually runs");
        if out.stats.queued > 0 {
            // Queued jobs start strictly after an earlier completion.
            let first_done = out
                .jobs
                .iter()
                .filter_map(|j| j.completed_at)
                .fold(f64::INFINITY, f64::min);
            for j in out.jobs.iter().filter(|j| j.wait_secs > 1e-9) {
                assert!(j.admitted_at.unwrap() >= first_done - 1e-9);
            }
        }
    }

    #[test]
    fn infeasible_budget_rejects_job() {
        let mut bad = aws_job(7);
        bad.budget_round = 1e-6;
        let out = batch(vec![aws_job(1), bad]).run().unwrap();
        assert_eq!(out.stats.admitted, 1);
        assert_eq!(out.stats.rejected, 1);
        assert!(out.jobs[1].admitted_at.is_none());
    }

    #[test]
    fn workload_is_deterministic() {
        let w = batch((0..4).map(aws_job).collect());
        let a = w.run().unwrap();
        let b = w.run().unwrap();
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(ja.cost.to_bits(), jb.cost.to_bits());
            assert_eq!(
                ja.completed_at.unwrap().to_bits(),
                jb.completed_at.unwrap().to_bits()
            );
        }
        assert_eq!(a.stats.total_cost.to_bits(), b.stats.total_cost.to_bits());
    }

    #[test]
    fn sjf_admits_short_job_first_under_contention() {
        // Four long jobs and one short one: under SJF the short job must
        // never be the last to start, however the quota contention resolves.
        let mut cfgs: Vec<SimConfig> = (0..5).map(aws_job).collect();
        for c in cfgs.iter_mut().take(4) {
            c.app.exec_bl_secs = 5000.0; // four slow jobs
        }
        cfgs[4].app.exec_bl_secs = 100.0; // one fast job
        let mut w = batch(cfgs);
        w.admission = AdmissionPolicy::ShortestMakespanFirst;
        let out = w.run().unwrap();
        // The fast job must not be the last to start.
        let fast_admit = out.jobs[4].admitted_at.unwrap();
        let latest_admit =
            out.jobs.iter().filter_map(|j| j.admitted_at).fold(0.0f64, f64::max);
        assert!(fast_admit <= latest_admit);
        assert_eq!(out.stats.admitted, 5);
    }

    #[test]
    fn workload_agg_aggregates_per_job() {
        let w = batch(vec![aws_job(1), aws_job(2)]);
        let outs = run_trials(
            &[w.clone(), w],
            2,
            &Arc::new(EnvCache::new()),
        )
        .unwrap();
        let agg = WorkloadAgg::from_outcomes(&outs);
        assert_eq!(agg.trials, 2);
        assert_eq!(agg.jobs.len(), 2);
        assert_eq!(agg.admitted.mean, 2.0);
        assert!(agg.total_cost.mean > 0.0);
    }

    #[test]
    fn mixed_environments_are_rejected() {
        let a = aws_job(1);
        let mut b = SimConfig::new(apps::til(), Scenario::AllOnDemand, 2);
        b.checkpoints_enabled = false;
        let err = batch(vec![a, b]).run();
        assert!(err.is_err(), "cloudlab + aws-gcp in one workload must fail");
    }
}
